"""Benchmark suite: paper tables/figures, kernels, roofline aggregation."""
