"""Benchmark trajectory: commit-keyed JSONL history + the regression gate.

``BENCH_*.json`` artifacts are single snapshots — overwrite one and the
old number is gone, so a perf or numerics regression ships silently. Every
bench writer therefore *also* appends one flat-metrics row per run to
``BENCH_history/<name>.jsonl`` (append-only, one JSON object per line,
stable key order), stamped with the shared :func:`benchmarks.run.bench_meta`
provenance block (git commit, device kind, jax version).

``repro-stats bench`` (``repro.launch.stats``) diffs two rows with the
per-metric tolerance table below and exits non-zero on regression — the CI
gate. Tolerances are direction-aware and honest about noise:

* **deterministic** metrics (tokens/step, occupancy, greedy agreement, KV
  compression) are wall-clock free — same trace, same value on any
  machine — and gate tight (5% / 1%);
* **wall-clock** metrics (GFLOP/s, ttft/itl percentiles) vary with the
  machine the row was produced on, so the committed-baseline gate allows an
  order of magnitude before failing: it catches "the kernel got 20x
  slower" (a real regression always lands far beyond 10x when the tile or
  dataflow breaks), never "the CI runner is slower than the dev box".

Metrics present in only one row are reported informationally, never fatal
— benches grow columns.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
from typing import Dict, List, Optional

__all__ = [
    "HISTORY_DIR",
    "Tolerance",
    "DEFAULT_TOLERANCES",
    "Finding",
    "append_row",
    "load_rows",
    "history_path",
    "diff_rows",
]

HISTORY_DIR = "BENCH_history"


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Gate rule for metrics matching ``pattern`` (fnmatch).

    ``direction`` says which way is good: ``"higher"`` fails when current <
    baseline * (1 - allowance), ``"lower"`` fails when current > baseline *
    (1 + allowance).
    """

    pattern: str
    direction: str  # "higher" | "lower"
    allowance: float

    def limit(self, baseline: float) -> float:
        if self.direction == "higher":
            return baseline * (1.0 - self.allowance)
        return baseline * (1.0 + self.allowance)

    def regressed(self, baseline: float, current: float) -> bool:
        if self.direction == "higher":
            return current < self.limit(baseline)
        return current > self.limit(baseline)


# Order matters: first pattern match wins.
DEFAULT_TOLERANCES: List[Tolerance] = [
    # deterministic (wall-clock free) — tight
    Tolerance("*greedy_agreement*", "higher", 0.01),
    Tolerance("*tokens_per_step*", "higher", 0.05),
    Tolerance("*occupancy*", "higher", 0.05),
    Tolerance("*kv_bytes_ratio*", "higher", 0.05),
    Tolerance("*speedup_tokens_per_step*", "higher", 0.05),
    # goodput is a fraction of requests meeting deliberately generous SLOs;
    # it should sit at ~1.0 — a big drop means a latency cliff, not jitter
    Tolerance("*goodput*", "higher", 0.9),
    # wall-clock — generous (machine-to-machine variance is real)
    Tolerance("gflops_tuned/*", "higher", 0.9),
    Tolerance("gflops_heuristic/*", "higher", 0.9),
    Tolerance("*queue_p*", "lower", 9.0),
    Tolerance("*attach_p*", "lower", 9.0),
    Tolerance("*chunk_prefill_p*", "lower", 9.0),
    Tolerance("*ttft_p99*", "lower", 9.0),
    Tolerance("*ttft_p50*", "lower", 9.0),
    Tolerance("*itl_p99*", "lower", 9.0),
    Tolerance("*itl_p50*", "lower", 9.0),
    Tolerance("*tokens_per_sec*", "higher", 0.9),
]


@dataclasses.dataclass
class Finding:
    """One metric's verdict from :func:`diff_rows`."""

    metric: str
    baseline: Optional[float]
    current: Optional[float]
    limit: Optional[float]
    status: str  # "ok" | "regression" | "missing" | "new" | "untracked"

    def row(self) -> str:
        def f(v):
            return "null" if v is None else f"{v:.6g}"

        return (f"{self.status:<10} {self.metric:<52} "
                f"base={f(self.baseline):<12} cur={f(self.current):<12} "
                f"limit={f(self.limit)}")


def history_path(name: str, directory: str = HISTORY_DIR) -> str:
    return os.path.join(directory, f"{name}.jsonl")


def append_row(
    name: str,
    metrics: Dict[str, Optional[float]],
    meta: Dict[str, str],
    *,
    directory: str = HISTORY_DIR,
) -> str:
    """Append one run's flat metrics row; returns the file path.

    ``metrics`` values are floats or ``None`` (a percentile with no
    samples). Keys inside each block are sorted so rows diff cleanly.
    """
    path = history_path(name, directory)
    os.makedirs(directory, exist_ok=True)
    row = {
        "meta": {k: meta[k] for k in sorted(meta)},
        "metrics": {k: metrics[k] for k in sorted(metrics)},
    }
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=False) + "\n")
    return path


def load_rows(name: str, directory: str = HISTORY_DIR) -> List[Dict]:
    path = history_path(name, directory)
    rows: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _tolerance_for(
    metric: str, tolerances: List[Tolerance]
) -> Optional[Tolerance]:
    for tol in tolerances:
        if fnmatch.fnmatch(metric, tol.pattern):
            return tol
    return None


def diff_rows(
    baseline: Dict,
    current: Dict,
    *,
    tolerances: Optional[List[Tolerance]] = None,
) -> List[Finding]:
    """Compare two history rows metric-by-metric.

    Findings cover the union of metric names: ``regression`` only for
    metrics present (and non-null) in both rows and matched by a tolerance
    rule; one-sided or unmatched metrics are informational.
    """
    tols = tolerances if tolerances is not None else DEFAULT_TOLERANCES
    base_m = baseline.get("metrics", {})
    cur_m = current.get("metrics", {})
    findings: List[Finding] = []
    for metric in sorted(set(base_m) | set(cur_m)):
        b, c = base_m.get(metric), cur_m.get(metric)
        if metric not in cur_m or c is None:
            findings.append(Finding(metric, b, c, None, "missing"))
            continue
        if metric not in base_m or b is None:
            findings.append(Finding(metric, b, c, None, "new"))
            continue
        tol = _tolerance_for(metric, tols)
        if tol is None:
            findings.append(Finding(metric, b, c, None, "untracked"))
            continue
        limit = tol.limit(float(b))
        status = "regression" if tol.regressed(float(b), float(c)) else "ok"
        findings.append(Finding(metric, float(b), float(c), limit, status))
    return findings
