"""Kernel-level benchmark: O-POPE Pallas GEMM vs XLA dot (wall time + check).

On this CPU container the Pallas kernel runs in interpret mode (Python
executor — wall time is NOT indicative of TPU performance; correctness and
the block-shape machinery are what is exercised). The XLA path is compiled
and its wall time is the CPU reference. TPU-side performance is covered by
the roofline analysis in EXPERIMENTS.md.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.opope_gemm import opope_gemm
from repro.kernels.ref import reference_matmul

Row = Tuple[str, float, str]


def _time(fn, *args, n=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(
        *args
    ).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def bench_kernel() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for m, k, n in [(256, 256, 256), (512, 512, 512)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

        xla = jax.jit(lambda a, b: reference_matmul(a, b))
        us_xla = _time(xla, a, b)
        rows.append((f"kernel/xla_us/{m}x{k}x{n}", us_xla, "compiled CPU"))

        t0 = time.perf_counter()
        out = opope_gemm(a, b, block_m=128, block_n=128, block_k=128,
                         interpret=True)
        out.block_until_ready()
        us_pal = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(out - xla(a, b))))
        rows.append((f"kernel/pallas_interpret_us/{m}x{k}x{n}", us_pal,
                     f"interpreter; max_err={err:.2e}"))
        assert err < 1e-3
    return rows
