"""Kernel-level benchmark: every available matmul backend (wall time + check).

Backends are enumerated from the ``repro.kernels.ops`` registry, so a newly
registered backend shows up here with no benchmark change. On this CPU
container the Pallas kernel runs in interpret mode (Python executor — wall
time is NOT indicative of TPU performance; correctness and the block-shape
machinery are what is exercised). The XLA path is compiled and its wall time
is the CPU reference. TPU-side performance is covered by the roofline
analysis in EXPERIMENTS.md.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import reference_matmul

Row = Tuple[str, float, str]


def _time(fn, *args, n=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(
        *args
    ).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def bench_kernel() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    backends = ops.available_backends()
    for m, k, n in [(256, 256, 256), (512, 512, 512)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        want = jax.jit(lambda a, b: reference_matmul(a, b))(a, b)

        for backend in backends:
            if backend == "pallas_interpret":
                # Python executor: one un-jitted call, no averaging needed.
                t0 = time.perf_counter()
                out = ops.matmul(a, b, backend=backend)
                out.block_until_ready()
                us = (time.perf_counter() - t0) * 1e6
                note = "interpreter"
            else:
                fn = jax.jit(lambda a, b, _be=backend: ops.matmul(a, b, backend=_be))
                us = _time(fn, a, b)
                out = fn(a, b)
                note = "compiled"
            err = float(jnp.max(jnp.abs(out - want)))
            rows.append((f"kernel/{backend}_us/{m}x{k}x{n}", us,
                         f"{note}; max_err={err:.2e}"))
            if ops.grad_backend_of(backend) == backend:
                # fp-contract backends reproduce the reference exactly (up
                # to reassociation); quantized backends (those with a
                # separate grad backend) carry int8 resolution error and are
                # gated by their own benchmark (quant_bench).
                assert err < 1e-3
            else:
                assert err < 0.05 * float(jnp.max(jnp.abs(want)))
    return rows
