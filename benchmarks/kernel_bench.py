"""Kernel-level benchmark: every available matmul backend, heuristic vs tuned.

Emits ``BENCH_kernels.json`` — the machine-readable kernel perf trajectory:
per (backend x shape) one row with GFLOP/s and achieved-vs-roofline
utilization for both tile selections, **heuristic** (the backend's registered
``tile_fn``) and **tuned** (the winner of the autotuner's candidate sweep,
``repro.tune.search``). Both columns come from the same sweep under the same
measurement protocol, and the heuristic tile is always one of the measured
candidates — so ``tuned >= heuristic`` GFLOP/s holds row-by-row (ties when
the heuristic already wins), which CI asserts.

Each tunable row also races the fused-epilogue writeback against the
post-hoc elementwise pass at the tuned tile
(``repro.tune.search.probe_epilogue_fusion``; bias + silu, the canonical MLP
writeback): ``us_epilogue_fused`` / ``us_epilogue_posthoc`` /
``us_epilogue_decided``, with the persisted verdict in ``epilogue_fused``
and the registry's answer in ``fusion_source``. The decided configuration is
``min(fused, post-hoc)`` from one probe, so decided >= unfused throughput
holds row-by-row — asserted here the same way as tuned >= heuristic.

On this CPU container the Pallas backends run in interpret mode: wall time
is NOT indicative of TPU performance (correctness, tile machinery and the
relative heuristic-vs-tuned ordering are what is exercised), and the
roofline utilization column is reported against the TPU-v5e reference
specs — meaningful on a real TPU, a trajectory placeholder here. The XLA
rows are compiled and are the CPU reference.

Usage::

    PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] \
        [--out BENCH_kernels.json] [--write-table]

``--write-table`` persists the sweep's winners into the active tuning table
(``$REPRO_TUNE_TABLE`` or the committed default) — how the committed table
is (re)generated.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.roofline import TPU_V5E, gemm_bytes
from repro.kernels import ops
from repro.kernels.ref import reference_grouped_matmul, reference_matmul
from repro.tune import (
    GemmShape,
    PROBE_EPILOGUE,
    TUNABLE_BACKENDS,
    TuningTable,
    active_table_path,
    device_kind,
    probe_epilogue_fusion,
    tune_shape,
)
from repro.tune.search import median_time_us

try:  # package layout (benchmarks.kernel_bench) vs direct script run
    from .run import bench_meta
    from . import history as bench_history
except ImportError:  # pragma: no cover - script-mode fallback
    from run import bench_meta
    import history as bench_history

Row = Tuple[str, float, str]

# (m, k, n) dense and (g, m, k, n) grouped benchmark shape sets.
DENSE_SHAPES = [(256, 256, 256), (512, 512, 512)]
GROUPED_SHAPES = [(4, 64, 256, 256)]
SMOKE_DENSE = [(128, 128, 128)]
SMOKE_GROUPED = [(2, 32, 128, 128)]


def _roofline_gflops(shape: GemmShape, q8: bool) -> float:
    """Roofline-bound GFLOP/s for this GEMM on the reference hw (TPU v5e):
    ``min(peak, HBM_bw * arithmetic_intensity)`` at honest operand widths."""
    groups = max(1, shape.g)
    flops = 2.0 * shape.m * shape.k * shape.n * groups
    if q8:
        per_group = gemm_bytes(
            shape.m, shape.k, shape.n,
            a_dtype="int8", out_dtype="float32",
            scale_elems=shape.m + shape.n,
        )
    else:
        per_group = gemm_bytes(shape.m, shape.k, shape.n, a_dtype=shape.dtype)
    intensity = flops / (per_group * groups)
    return min(TPU_V5E.peak_flops, TPU_V5E.hbm_bw * intensity) / 1e9


def _check_correctness(backend: str, shape: GemmShape) -> float:
    """Max abs error of the backend vs the fp32 reference on this shape."""
    rng = np.random.default_rng(0)
    if shape.family == "grouped":
        a = jnp.asarray(
            rng.standard_normal((shape.g, shape.m, shape.k)), jnp.float32
        )
        b = jnp.asarray(
            rng.standard_normal((shape.g, shape.k, shape.n)), jnp.float32
        )
        got = ops.grouped_matmul(a, b, backend=backend)
        want = reference_grouped_matmul(a, b)
    else:
        a = jnp.asarray(rng.standard_normal((shape.m, shape.k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((shape.k, shape.n)), jnp.float32)
        got = ops.matmul(a, b, backend=backend)
        want = reference_matmul(a, b)
    err = float(jnp.max(jnp.abs(got - want)))
    if ops.grad_backend_of(backend) == backend:
        # fp-contract backends reproduce the reference up to reassociation;
        # quantized backends carry int8 resolution error (gated at 5% of the
        # output magnitude here, tightly in quant_bench).
        assert err < 1e-3, (backend, shape, err)
    else:
        assert err < 0.05 * float(jnp.max(jnp.abs(want))), (backend, shape, err)
    return err


def _time_untiled(backend: str, shape: GemmShape, *, iters: int) -> float:
    """Steady-state us of a backend with no tile knob (the XLA paths)."""
    rng = np.random.default_rng(0)
    if shape.family == "grouped":
        a = jnp.asarray(
            rng.standard_normal((shape.g, shape.m, shape.k)), jnp.float32
        )
        b = jnp.asarray(
            rng.standard_normal((shape.g, shape.k, shape.n)), jnp.float32
        )
        fn = jax.jit(
            lambda a, b, _be=backend: ops.grouped_matmul(a, b, backend=_be)
        )
    else:
        a = jnp.asarray(rng.standard_normal((shape.m, shape.k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((shape.k, shape.n)), jnp.float32)
        fn = jax.jit(lambda a, b, _be=backend: ops.matmul(a, b, backend=_be))
    return median_time_us(lambda: fn(a, b), iters=iters, warmup=1)


def bench_kernels_json(
    *,
    smoke: bool = False,
    top_k: int = 3,
    iters: int = 2,
    write_table: bool = False,
) -> Dict[str, object]:
    dense = SMOKE_DENSE if smoke else DENSE_SHAPES
    grouped = SMOKE_GROUPED if smoke else GROUPED_SHAPES
    shapes = [GemmShape("dense", m, k, n) for m, k, n in dense] + [
        GemmShape("grouped", m, k, n, g) for g, m, k, n in grouped
    ]
    backends = ops.available_backends()
    table = TuningTable()
    rows: List[Dict[str, object]] = []
    for shape in shapes:
        flops = 2.0 * shape.m * shape.k * shape.n * max(1, shape.g)
        for backend in backends:
            q8 = ops.family_of(backend) == "q8"
            roof = _roofline_gflops(shape, q8)
            err = _check_correctness(backend, shape)
            if backend in TUNABLE_BACKENDS:
                interpret = TUNABLE_BACKENDS[backend]
                entry, cands = tune_shape(
                    backend, shape, top_k=top_k,
                    iters=1 if interpret else iters,
                    probe_epilogue=False,
                )
                # One epilogue probe at the winning tile feeds both the
                # fused/unfused columns and the persisted verdict, so the
                # JSON and the table can never disagree on one run.
                probe = (
                    probe_epilogue_fusion(
                        backend, shape, entry.block,
                        iters=1 if interpret else iters,
                    )
                    if ops.epilogue_capable(backend) else None
                )
                if probe is not None:
                    entry = dataclasses.replace(
                        entry, fuse_epilogue=probe.fuse
                    )
                    # decided = min(fused, post-hoc) by construction: the
                    # recorded verdict never loses to the unfused pass.
                    assert probe.decided_us <= probe.posthoc_us, probe
                table.put(entry)
                heur = next(c for c in cands if c.is_heuristic)
                row = {
                    "tile_heuristic": list(heur.block),
                    "tile_tuned": list(entry.block),
                    "us_heuristic": heur.us,
                    "us_tuned": entry.us,
                    "gflops_heuristic": heur.gflops,
                    "gflops_tuned": entry.gflops,
                    "tunable": True,
                    "candidates_timed": len(cands),
                    "us_epilogue_fused": probe.fused_us if probe else None,
                    "us_epilogue_posthoc": probe.posthoc_us if probe else None,
                    "us_epilogue_decided": probe.decided_us if probe else None,
                    "epilogue_fused": probe.fuse if probe else None,
                }
            else:
                us = _time_untiled(backend, shape, iters=iters)
                gf = flops / us / 1e3
                row = {
                    "tile_heuristic": None,
                    "tile_tuned": None,
                    "us_heuristic": us,
                    "us_tuned": us,
                    "gflops_heuristic": gf,
                    "gflops_tuned": gf,
                    "tunable": False,
                    "candidates_timed": 1,
                    # XLA backends run epilogues post-hoc only (the registry
                    # applies one fused-by-XLA pass) — no fused lane to race.
                    "us_epilogue_fused": None,
                    "us_epilogue_posthoc": None,
                    "us_epilogue_decided": None,
                    "epilogue_fused": None,
                }
            row.update(
                backend=backend,
                family=shape.family,
                g=shape.g, m=shape.m, k=shape.k, n=shape.n,
                dtype="int8" if q8 else shape.dtype,
                max_abs_err_vs_ref=err,
                roofline_gflops=roof,
                utilization_heuristic=row["gflops_heuristic"] / roof,
                utilization_tuned=row["gflops_tuned"] / roof,
            )
            rows.append(row)
    if write_table:
        path = active_table_path()
        try:
            existing = TuningTable.load(path)
            existing.merge(table)
            table = existing
        except Exception:
            pass
        table.save(path)
        ops.clear_tile_cache()  # so tile_source below sees the new table
    # tile_source is the registry's own answer, not an assumption: "tuned"
    # only when the ACTIVE table (after an optional --write-table) really
    # serves this cell — a consumer cross-checking ops.tile_source() must
    # see the same value.
    for row in rows:
        row["tile_source"] = (
            ops.tile_source(
                row["backend"], row["m"], row["k"], row["n"], groups=row["g"]
            )
            if row["tunable"] else "heuristic"
        )
        row["fusion_source"] = (
            ops.fusion_source(
                row["backend"], row["m"], row["k"], row["n"], groups=row["g"]
            )
            if row["epilogue_fused"] is not None else "default"
        )
    return {
        "schema": 1,
        "meta": bench_meta(),
        "epilogue_probe": list(PROBE_EPILOGUE),
        "device_kind": device_kind(),
        "roofline_reference": TPU_V5E.name,
        "interpret_note": (
            "Pallas rows on non-TPU platforms run the Pallas interpreter: "
            "wall time is not TPU-indicative; the tuned-vs-heuristic ordering "
            "and the tile machinery are what this trajectory tracks."
        ),
        "smoke": smoke,
        "generated_unix": time.time(),
        "rows": rows,
        "table_written": active_table_path() if write_table else None,
    }


def bench_kernel() -> List[Row]:
    """CSV rows for benchmarks/run.py (the JSON artifact is the real
    deliverable now; this keeps the driver's one-line-per-metric view)."""
    report = bench_kernels_json(smoke=True, iters=1)
    rows: List[Row] = []
    for r in report["rows"]:
        name = (
            f"kernel/{r['backend']}_us/"
            + (f"{r['g']}x" if r["family"] == "grouped" else "")
            + f"{r['m']}x{r['k']}x{r['n']}"
        )
        rows.append((
            name,
            r["us_tuned"],
            f"tuned {r['tile_tuned']} vs heuristic {r['tile_heuristic']} "
            f"({r['us_heuristic']:.3g}us); max_err={r['max_abs_err_vs_ref']:.2e}",
        ))
    return rows


def history_metrics(report: Dict[str, object]) -> Dict[str, float]:
    """Flatten a kernel report into the BENCH_history row schema: one
    ``gflops_{tuned,heuristic}/<backend>/<family>:<shape>`` entry per row
    (the regression gate's keys) plus the tuned roofline utilization."""
    metrics: Dict[str, float] = {}
    for r in report["rows"]:
        sid = (
            (f"{r['g']}x" if r["family"] == "grouped" else "")
            + f"{r['m']}x{r['k']}x{r['n']}"
        )
        key = f"{r['backend']}/{r['family']}:{sid}"
        metrics[f"gflops_tuned/{key}"] = r["gflops_tuned"]
        metrics[f"gflops_heuristic/{key}"] = r["gflops_heuristic"]
        metrics[f"utilization_tuned/{key}"] = r["utilization_tuned"]
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape set (CI)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--write-table", action="store_true",
                    help="persist sweep winners into the active tuning table")
    ap.add_argument("--history-dir", default=bench_history.HISTORY_DIR,
                    help="append a commit-keyed row here (see history.py)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history append")
    args = ap.parse_args()
    report = bench_kernels_json(
        smoke=args.smoke, top_k=args.top_k, iters=args.iters,
        write_table=args.write_table,
    )
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    if not args.no_history:
        hp = bench_history.append_row(
            "kernel", history_metrics(report), report["meta"],
            directory=args.history_dir,
        )
        print(f"history row -> {hp}")
    worst = min(
        (r["gflops_tuned"] / r["gflops_heuristic"] for r in report["rows"]),
        default=1.0,
    )
    probed = [r for r in report["rows"] if r["epilogue_fused"] is not None]
    worst_ep = min(
        (r["us_epilogue_posthoc"] / r["us_epilogue_decided"] for r in probed),
        default=1.0,
    )
    fused_n = sum(1 for r in probed if r["epilogue_fused"])
    print(f"wrote {args.out}: {len(report['rows'])} rows on "
          f"{report['device_kind']}; min tuned/heuristic GFLOP/s ratio "
          f"{worst:.3f} (>= 1.0 by construction); epilogue probe "
          f"({'+'.join(report['epilogue_probe'])}): fused wins "
          f"{fused_n}/{len(probed)}, min posthoc/decided time ratio "
          f"{worst_ep:.3f} (>= 1.0 by construction)")


if __name__ == "__main__":
    main()
