"""One benchmark per paper table/figure. Each returns rows of
``(name, value, derived)`` printed as CSV by benchmarks.run.

* fig5  — area + peak GFLOPS scaling across mesh sizes x MAC kinds (§III-B)
* fig6  — utilization across GEMM sizes x mesh sizes (§III-C)
* fig7  — Table I workload runtimes on 4 accelerator cycle models with the
          cluster-level L1 double-buffered tiling (§III-D)
* table2 — GFLOPS / GFLOPS/mm2 / TFLOPS/W vs published values
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.dataflows import ACCELERATORS
from repro.core.engine import EngineConfig, simulate_gemm
from repro.core.sota import (
    PUBLISHED_TABLE2,
    buffer_share,
    fig5_area_sweep,
    fig5_geomean_scaling,
    table2_model,
)
from repro.core.tiling import ClusterConfig, tiled_gemm_cycles

from .workloads import TABLE_I

Row = Tuple[str, float, str]


def bench_fig5_area_scaling() -> List[Row]:
    rows: List[Row] = []
    for key, rec in fig5_area_sweep().items():
        rows.append((f"fig5/area/{key}", rec["area_mm2"], "mm2"))
    for p in (4, 8, 16, 32):
        rows.append(
            (f"fig5/buffer_share/{p}x{p}", 100 * buffer_share(EngineConfig(p=p)),
             "percent (<2 at 32x32 per paper)")
        )
    rows.append(
        ("fig5/geomean_scaling/fp16", fig5_geomean_scaling("fp16"),
         "x per 4x MACs (paper band 3.27-3.79)")
    )
    return rows


def bench_fig6_utilization() -> List[Row]:
    rows: List[Row] = []
    # Headline claim
    r = simulate_gemm(EngineConfig(p=4), 64, 256, 128)
    rows.append(("fig6/util/64x256x128@4x4", 100 * r.utilization,
                 "percent (paper: 99.97)"))
    # Utilization across Table I workloads x mesh sizes
    for name, (m, k, n) in TABLE_I.items():
        for p in (4, 8, 16, 32):
            u = simulate_gemm(EngineConfig(p=p), m, k, n).utilization
            rows.append((f"fig6/util/{name}@{p}x{p}", 100 * u, "percent"))
    # K sweep (K >= 2p condition)
    for k in (8, 16, 32, 64, 256, 1024):
        u = simulate_gemm(EngineConfig(p=16), 64, k, 64).utilization
        rows.append((f"fig6/util/K{k}@16x16_64x64", 100 * u, "percent"))
    return rows


def bench_fig7_runtime() -> List[Row]:
    """Cluster-level runtimes: per-accelerator engine cycles under the L1
    double-buffered tiling; the paper reports O-POPE up to 1.86x faster.

    Fairness: EVERY accelerator gets a per-workload tile-plan search over the
    64 kB budget (each dataflow prefers different tile aspect ratios), so the
    comparison reflects dataflow + frequency, not tiling luck.
    """
    rows: List[Row] = []
    worst = 0.0
    for name, (m, k, n) in TABLE_I.items():
        times = {}
        for acc_name, acc in ACCELERATORS.items():
            us = min(
                _tiled_runtime_us(acc, m, k, n, plan)
                for plan in _candidate_plans(m, k, n)
            )
            times[acc_name] = us
            rows.append((f"fig7/runtime_us/{name}/{acc_name}", us, "us"))
        speedup = max(times.values()) / times["o-pope"]
        worst = max(worst, speedup)
        rows.append((f"fig7/speedup/{name}", speedup, "x vs slowest baseline"))
    rows.append(("fig7/max_speedup", worst, "x (paper: up to 1.86)"))
    return rows


def _candidate_plans(m: int, k: int, n: int, budget: int = 64 * 1024):
    """Tile-plan candidates under the L1 budget (16-bit elements)."""
    import math

    from repro.core.tiling import TilingPlan, choose_tile

    plans = [choose_tile(EngineConfig(p=16), m, k, n)]
    for tm in (32, 64, 128, 256):
        for tk in (32, 64, 128, 256):
            # largest tn fitting the budget
            tn_budget = (budget - tm * tk * 2) // ((tm + tk) * 2)
            tn = min(n, max(32, (tn_budget // 32) * 32))
            p = TilingPlan(min(tm, m), min(tk, k), tn, 2)
            if 0 < p.total_bytes <= budget:
                plans.append(p)
    return plans


def _tiled_runtime_us(acc, m: int, k: int, n: int, plan) -> float:
    """L1-tiled runtime: per-tile engine cycles overlapped with DMA."""
    import math

    cluster = ClusterConfig()
    mt = math.ceil(m / plan.tm)
    nt = math.ceil(n / plan.tn)
    kt = math.ceil(k / plan.tk)
    total = math.ceil(plan.total_bytes / cluster.dma_bytes_per_cycle)
    for i in range(mt):
        tm = min(plan.tm, m - i * plan.tm)
        for j in range(nt):
            tn = min(plan.tn, n - j * plan.tn)
            for kk in range(kt):
                tk = min(plan.tk, k - kk * plan.tk)
                eng = acc.cycles(tm, tk, tn).total_cycles
                dma_bytes = (tm * tk + tk * tn) * plan.elem_bytes
                if kk == kt - 1:
                    dma_bytes += 2 * tm * tn * plan.elem_bytes
                dma = math.ceil(dma_bytes / cluster.dma_bytes_per_cycle)
                total += max(eng, dma) + cluster.reprogram_cycles
    return total / (acc.freq_ghz * 1e3)


def bench_table2() -> List[Row]:
    rows: List[Row] = []
    model = table2_model()
    for name, rec in model.items():
        pub = PUBLISHED_TABLE2[name]
        rows.append((f"table2/gflops/{name}", rec["gflops"],
                     f"published {pub[0]}"))
        rows.append((f"table2/gflops_per_mm2/{name}", rec["gflops_per_mm2"],
                     f"published {pub[1]}"))
        if pub[2]:
            rows.append((f"table2/tflops_per_w/{name}", rec["tflops_per_w"],
                         f"published {pub[2]}"))
    return rows
