"""Mixed-precision benchmark: quantized GEMM backends, precision policy, and
quantized-KV continuous serving. Emits ``BENCH_quant.json``.

Sections:

* **formats** — quantize/dequantize round-trip error per format (int8,
  fp8_e4m3, fp8_e5m2) at 1 byte/value.
* **gemm** — every quantized backend vs the fp32 ``xla`` reference on the
  same operands: max-abs error, dtype-aware bytes moved
  (:func:`repro.core.gemm_bytes` — int8 operands count 1 byte, scale
  sidecars included), achieved arithmetic intensity, wall time.
* **grouped** — every grouped backend (``grouped_matmul``: the MoE-expert
  shape family) vs the fp32 grouped reference: max-abs error, dtype-aware
  bytes with per-group scale side-bands, intensity, wall time.
* **policy** — the mlp-q8 :class:`PrecisionPolicy` on the trained reduced
  model: forward loss delta vs the all-fp32 reference (the accuracy price of
  quantizing exactly the MLP linears).
* **moe** — quantized experts end to end: the reduced deepseek MoE trained
  on the same cyclic task, ``PrecisionPolicy(moe="q8")`` loss delta and
  greedy-decode token agreement (the policy now reaches the routed
  per-expert grouped GEMMs).
* **serving** — the PR 2 serving trace (same seeded generator, arrival
  pattern, prompt lengths and generation budgets as
  ``benchmarks/serving_bench.py``) through ``ContinuousEngine`` twice: fp32
  K/V lanes vs ``kv_format="int8"``. Reports tokens/sec, tokens/step,
  K/V bytes per slot for both, their ratio, and greedy-token agreement.

**Why the model is trained first:** greedy-token agreement is only a
meaningful accuracy metric when argmax margins are real. An untrained model
emits near-uniform logits whose argmax flips under fp32-vs-fp32 reordering
noise, let alone quantization. The bench therefore fits the reduced model on
a deterministic cyclic-sequence task (seconds on CPU) and replays the PR 2
trace with in-distribution prompt values — same trace structure, decisive
logits — so disagreements measure quantization, not dice rolls.

Run::

    PYTHONPATH=src python benchmarks/quant_bench.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

try:  # package layout (benchmarks.quant_bench) vs direct script run
    from .run import bench_meta
    from . import history as bench_history
except ImportError:  # pragma: no cover - script-mode fallback
    from run import bench_meta
    import history as bench_history


def trained_model(cfg, *, steps: int, seed: int = 0, seq_len: int = 32):
    """Fit the reduced model on cyclic sequences t[i] = (a + stride*i) % V.

    ``seq_len`` must cover the positions serving will decode at (a model
    trained on short sequences extrapolates RoPE positions with low
    confidence, and argmax agreement degrades for position reasons unrelated
    to quantization)."""
    import jax
    import jax.numpy as jnp

    from repro.models import api
    from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state

    params = api.init_params(cfg, jax.random.key(seed))
    opt_cfg = AdamWConfig(peak_lr=5e-3, warmup_steps=20, total_steps=steps)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, batch)
        )(params)
        params, opt, _ = apply_updates(params, g, opt, opt_cfg)
        return params, opt, loss

    def batch(key, b=16, s=seq_len + 1):
        a = jax.random.randint(key, (b, 1), 0, cfg.vocab)
        st = jax.random.randint(jax.random.fold_in(key, 1), (b, 1), 1, 5)
        t = (a + st * jnp.arange(s)[None, :]) % cfg.vocab
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    loss = None
    for i in range(steps):
        params, opt, loss = step(params, opt, batch(jax.random.key(100 + i)))
    return params, float(loss)


def cyclic_prompt_batch(vocab: int, n_prompts: int, prompt_len: int, seed: int):
    """[n_prompts, prompt_len] int32 prompts from the trained cyclic task."""
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    starts = rng.integers(0, vocab, size=n_prompts)
    strides = rng.integers(1, 5, size=n_prompts)
    return jnp.asarray(
        (starts[:, None] + strides[:, None] * np.arange(prompt_len)[None, :])
        % vocab,
        jnp.int32,
    )


def greedy_decode(cfg, params, prompts, gen: int, backend=None):
    """Greedy-decode ``gen`` tokens per prompt row through the policy-aware
    prefill/decode path; returns [B, gen]. Shared by the MoE bench section
    and the quantized-expert regression test (one agreement contract, one
    decode loop)."""
    import jax
    import jax.numpy as jnp

    from repro.models import api

    decode = jax.jit(
        lambda params, tok, caches, pos: api.decode(
            cfg, params, tok, caches, pos, backend=backend
        )
    )
    logits, caches = api.prefill(
        cfg, params, {"tokens": prompts}, max_len=prompts.shape[1] + gen + 1,
        cache_dtype=jnp.float32, backend=backend,
    )
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    pos = jnp.asarray(prompts.shape[1], jnp.int32)
    for i in range(gen - 1):
        logits, caches = decode(params, tok, caches, pos + i)
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def cyclic_prompts(trace, vocab: int, seed: int):
    """Rewrite a trace's prompt VALUES to the trained task's distribution,
    keeping its structure (rids, arrivals, prompt lengths, budgets)."""
    import dataclasses

    import numpy as np

    rng = np.random.default_rng(seed)
    out = []
    for r in trace:
        a, s = int(rng.integers(0, vocab)), int(rng.integers(1, 5))
        out.append(
            dataclasses.replace(
                r, prompt=[(a + s * t) % vocab for t in range(len(r.prompt))]
            )
        )
    return out


def bench_formats() -> Dict:
    import jax.numpy as jnp
    import numpy as np

    from repro import quant

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    out = {}
    for name in sorted(quant.FORMATS):
        qt = quant.quantize(x, name)
        err = float(jnp.max(jnp.abs(qt.dequantize() - x)))
        out[name] = {
            "roundtrip_max_err": err,
            "bytes_per_value": jnp.dtype(quant.FORMATS[name].dtype).itemsize,
        }
    return out


def bench_gemm(smoke: bool) -> List[Dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gemm_bytes, gemm_intensity
    from repro.kernels import ops
    from repro.kernels.ref import reference_matmul

    shapes = [(128, 256, 128)] if smoke else [(256, 512, 256), (512, 512, 512)]
    rng = np.random.default_rng(0)
    rows: List[Dict] = []
    for m, k, n in shapes:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        want = jax.jit(lambda a, b: reference_matmul(a, b))(a, b)
        # pallas_q8 resolves through the registry: compiled on TPU, else its
        # interpret/xla_q8 degradation chain (with its RuntimeWarning).
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            resolved_pallas_q8 = ops.resolve_backend("pallas_q8")
        for backend in ["xla", "xla_q8", resolved_pallas_q8]:
            quantized = backend.endswith("q8") or "q8" in backend
            fn = jax.jit(
                lambda a, b, _be=backend: ops.matmul(a, b, backend=_be)
            )
            out = fn(a, b)
            out.block_until_ready()
            t0 = time.perf_counter()
            reps = 1 if "interpret" in backend else 5
            for _ in range(reps):
                fn(a, b).block_until_ready()
            us = (time.perf_counter() - t0) / reps * 1e6
            err = float(jnp.max(jnp.abs(out - want)))
            bytes_moved = gemm_bytes(
                m, k, n,
                a_dtype=jnp.int8 if quantized else a.dtype,
                b_dtype=jnp.int8 if quantized else b.dtype,
                out_dtype=a.dtype,
                scale_elems=(m + n) if quantized else 0,
            )
            rows.append(
                {
                    "backend": backend,
                    "m": m, "k": k, "n": n,
                    "max_abs_err_vs_fp32": err,
                    "bytes_moved": bytes_moved,
                    "intensity_flops_per_byte": gemm_intensity(
                        m, k, n,
                        a_dtype=jnp.int8 if quantized else a.dtype,
                        b_dtype=jnp.int8 if quantized else b.dtype,
                        out_dtype=a.dtype,
                        scale_elems=(m + n) if quantized else 0,
                    ),
                    "wall_us": us,
                }
            )
    return rows


def bench_grouped(smoke: bool) -> List[Dict]:
    """Every grouped backend vs the fp32 grouped reference: one launch for G
    same-shape GEMMs (the MoE expert shape family), per-group q8 scales
    counted as fp32 side-band bytes (G * (M + N) elements)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gemm_bytes, gemm_intensity
    from repro.kernels import ops
    from repro.kernels.ref import reference_grouped_matmul

    shapes = [(4, 64, 128, 128)] if smoke else [(8, 128, 256, 128)]
    rng = np.random.default_rng(0)
    rows: List[Dict] = []
    for g, m, k, n in shapes:
        a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
        want = jax.jit(lambda a, b: reference_grouped_matmul(a, b))(a, b)
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            resolved_pallas = ops.resolve_grouped_backend("pallas")
            resolved_pallas_q8 = ops.resolve_grouped_backend("pallas_q8")
        backends = ["xla", "xla_q8"]
        for extra in (resolved_pallas, resolved_pallas_q8):
            if extra not in backends:
                backends.append(extra)
        for backend in backends:
            quantized = ops.family_of(backend) == "q8"
            fn = jax.jit(
                lambda a, b, _be=backend: ops.grouped_matmul(a, b, backend=_be)
            )
            out = fn(a, b)
            out.block_until_ready()
            t0 = time.perf_counter()
            reps = 1 if "interpret" in backend else 5
            for _ in range(reps):
                fn(a, b).block_until_ready()
            us = (time.perf_counter() - t0) / reps * 1e6
            per_group = dict(
                a_dtype=jnp.int8 if quantized else a.dtype,
                b_dtype=jnp.int8 if quantized else b.dtype,
                out_dtype=a.dtype,
                scale_elems=(m + n) if quantized else 0,
            )
            rows.append(
                {
                    "backend": backend,
                    "g": g, "m": m, "k": k, "n": n,
                    "max_abs_err_vs_fp32": float(jnp.max(jnp.abs(out - want))),
                    "bytes_moved": g * gemm_bytes(m, k, n, **per_group),
                    "intensity_flops_per_byte": gemm_intensity(m, k, n, **per_group),
                    "wall_us": us,
                }
            )
    return rows


def bench_moe(*, smoke: bool, train_steps: int, seed: int = 0) -> Dict:
    """Quantized MoE experts end to end: train the reduced deepseek MoE on
    the cyclic task, then compare the all-fp32 path against
    ``PrecisionPolicy(moe="q8")`` — which now reaches the routed per-expert
    grouped GEMMs, not just the shared-expert MLP — on forward loss and on
    greedy decode agreement (prefill + step decode through the policy)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import api
    from repro.quant import PrecisionPolicy

    cfg = get_config("deepseek-moe-16b").reduced()
    params, final_loss = trained_model(
        cfg, steps=train_steps, seed=seed, seq_len=48
    )
    pol = PrecisionPolicy(rules={"moe": "q8"}, name="moe-q8")

    t = (11 + 3 * jnp.arange(33)[None, :]) % cfg.vocab
    batch = {
        "tokens": jnp.broadcast_to(t[:, :-1], (4, 32)).astype(jnp.int32),
        "labels": jnp.broadcast_to(t[:, 1:], (4, 32)).astype(jnp.int32),
    }
    l_fp = float(api.loss_fn(cfg, params, batch))
    l_q = float(api.loss_fn(cfg, params, batch, backend=pol))

    n_prompts, gen = (4, 8) if smoke else (8, 16)
    prompts = cyclic_prompt_batch(cfg.vocab, n_prompts, 12, seed)
    got_fp = np.asarray(greedy_decode(cfg, params, prompts, gen))
    got_q = np.asarray(greedy_decode(cfg, params, prompts, gen, backend=pol))
    total = got_fp.size
    agree = int((got_fp == got_q).sum())
    return {
        "arch": cfg.name,
        "train_steps": train_steps,
        "final_train_loss": final_loss,
        "policy": pol.describe(),
        "loss_fp32": l_fp,
        "loss_quant": l_q,
        "loss_abs_delta": abs(l_fp - l_q),
        "greedy_agreement": agree / total if total else 0.0,
        "compared_tokens": total,
    }


def bench_policy(cfg, params) -> Dict:
    import jax.numpy as jnp

    from repro.models import api
    from repro.quant import mlp_q8_policy

    pol = mlp_q8_policy()
    t = (7 + 3 * jnp.arange(33)[None, :]) % cfg.vocab
    batch = {
        "tokens": jnp.broadcast_to(t[:, :-1], (4, 32)).astype(jnp.int32),
        "labels": jnp.broadcast_to(t[:, 1:], (4, 32)).astype(jnp.int32),
    }
    l_fp = float(api.loss_fn(cfg, params, batch))
    l_q = float(api.loss_fn(cfg, params, batch, backend=pol))
    return {
        "policy": pol.describe(),
        "loss_fp32": l_fp,
        "loss_quant": l_q,
        "loss_abs_delta": abs(l_fp - l_q),
    }


def bench_serving(cfg, params, *, smoke: bool, seed: int, kv_format: str) -> Dict:
    import jax.numpy as jnp

    from repro.serve import ContinuousEngine, poisson_trace

    if smoke:
        n_requests, n_slots, max_len = 8, 2, 80
        prompt_lens, gen_lens = (6, 12, 17), (4, 16, 48)
    else:
        n_requests, n_slots, max_len = 16, 4, 160
        prompt_lens, gen_lens = (6, 12, 17, 24, 32), (8, 24, 64, 96)
    # The PR 2 trace (same generator/seed/parameters as serving_bench), with
    # prompt values rewritten into the trained task's distribution.
    trace = poisson_trace(
        n_requests, seed=seed, vocab=cfg.vocab,
        prompt_lens=prompt_lens, gen_lens=gen_lens,
    )
    trace = cyclic_prompts(trace, cfg.vocab, seed)

    common = dict(
        cfg=cfg, params=params, n_slots=n_slots, max_len=max_len,
        cache_dtype=jnp.float32,
    )
    eng_fp = ContinuousEngine(**common)
    eng_q = ContinuousEngine(**common, kv_format=kv_format)
    # Warmup absorbs compiles so wall-clock measures steady-state serving.
    eng_fp.serve(trace)
    eng_q.serve(trace)
    rep_fp = eng_fp.timed_serve(trace)
    rep_q = eng_q.timed_serve(trace)

    agree = total = 0
    for rid in rep_fp.outputs:
        a, b = rep_fp.outputs[rid], rep_q.outputs[rid]
        total += len(a)
        agree += sum(1 for x, y in zip(a, b) if x == y)

    def row(rep):
        return {
            "useful_tokens": rep.generated_tokens,
            "decode_steps": rep.decode_steps,
            "tokens_per_sec": rep.tokens_per_sec,
            "tokens_per_step": rep.tokens_per_step,
            "mean_occupancy": rep.mean_occupancy,
            "kv_bytes_per_slot": rep.kv_bytes_per_slot,
        }

    return {
        "kv_format": kv_format,
        "n_requests": n_requests,
        "n_slots": n_slots,
        "max_len": max_len,
        "fp32": row(rep_fp),
        "quant": row(rep_q),
        "kv_bytes_ratio": rep_fp.kv_bytes_per_slot / rep_q.kv_bytes_per_slot,
        "greedy_agreement": agree / total if total else 0.0,
        "compared_tokens": total,
    }


def history_metrics(result: Dict) -> Dict:
    """Flatten the quant comparison into the BENCH_history row schema.
    Deterministic accuracy/compression metrics only — the gemm wall times in
    this bench run too few reps to gate on."""
    s = result["serving"]
    mo = result["moe"]
    return {
        "serving.greedy_agreement": s["greedy_agreement"],
        "serving.kv_bytes_ratio": s["kv_bytes_ratio"],
        "serving.quant_tokens_per_step": s["quant"]["tokens_per_step"],
        "moe.greedy_agreement": mo["greedy_agreement"],
        "policy.loss_abs_delta": result["policy"]["loss_abs_delta"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-format", default="int8")
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--out", default="BENCH_quant.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI (still asserts the targets)")
    ap.add_argument("--history-dir", default=bench_history.HISTORY_DIR,
                    help="append a commit-keyed row here (see history.py)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history append")
    args = ap.parse_args()

    from repro.configs import get_config

    cfg = get_config(args.arch).reduced()
    max_len = 80 if args.smoke else 160
    params, final_loss = trained_model(
        cfg, steps=args.train_steps, seed=args.seed, seq_len=max_len
    )

    result = {
        "meta": bench_meta(),
        "arch": cfg.name,
        "seed": args.seed,
        "smoke": args.smoke,
        "train_steps": args.train_steps,
        "final_train_loss": final_loss,
        "formats": bench_formats(),
        "gemm": bench_gemm(args.smoke),
        "grouped": bench_grouped(args.smoke),
        "policy": bench_policy(cfg, params),
        "moe": bench_moe(
            smoke=args.smoke, train_steps=max(args.train_steps * 2 // 3, 50),
            seed=args.seed,
        ),
        "serving": bench_serving(
            cfg, params, smoke=args.smoke, seed=args.seed,
            kv_format=args.kv_format,
        ),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    if not args.no_history:
        hist = bench_history.append_row(
            "quant", history_metrics(result), result["meta"],
            directory=args.history_dir,
        )
        print(f"[quant_bench] history row -> {hist}")

    s = result["serving"]
    print(f"[quant_bench] {cfg.name}: trained {args.train_steps} steps "
          f"(loss {final_loss:.3f})")
    for row in result["gemm"]:
        print(f"  gemm {row['backend']:<20} {row['m']}x{row['k']}x{row['n']} "
              f"err={row['max_abs_err_vs_fp32']:.2e} "
              f"bytes={row['bytes_moved']:.3e} "
              f"AI={row['intensity_flops_per_byte']:.1f} fl/B")
    for row in result["grouped"]:
        print(f"  grouped {row['backend']:<18} {row['g']}x[{row['m']}x{row['k']}"
              f"x{row['n']}] err={row['max_abs_err_vs_fp32']:.2e} "
              f"bytes={row['bytes_moved']:.3e} "
              f"AI={row['intensity_flops_per_byte']:.1f} fl/B")
    print(f"  policy loss delta: {result['policy']['loss_abs_delta']:.2e}")
    mo = result["moe"]
    print(f"  moe {mo['arch']}: trained {mo['train_steps']} steps "
          f"(loss {mo['final_train_loss']:.3f}), "
          f"q8-expert loss delta {mo['loss_abs_delta']:.2e}, "
          f"greedy agreement {mo['greedy_agreement']:.4f} "
          f"over {mo['compared_tokens']} tokens")
    print(f"  serving kv bytes/slot: fp32 {s['fp32']['kv_bytes_per_slot']:.0f} "
          f"-> {s['kv_format']} {s['quant']['kv_bytes_per_slot']:.0f} "
          f"({s['kv_bytes_ratio']:.2f}x smaller)")
    print(f"  greedy agreement: {s['greedy_agreement']:.4f} "
          f"over {s['compared_tokens']} tokens -> {args.out}")
    if s["kv_bytes_ratio"] < 3.5:
        raise SystemExit(
            f"K/V bytes-per-slot ratio {s['kv_bytes_ratio']:.2f} < 3.5"
        )
    if s["greedy_agreement"] < 0.99:
        raise SystemExit(
            f"greedy-token agreement {s['greedy_agreement']:.4f} < 0.99"
        )
    if mo["greedy_agreement"] < 0.99:
        raise SystemExit(
            f"quantized-MoE greedy agreement {mo['greedy_agreement']:.4f} < 0.99"
        )


if __name__ == "__main__":
    main()
