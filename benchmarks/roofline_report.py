"""Roofline report: aggregates the dry-run JSON records into the §Roofline
table (one row per arch x shape x mesh) and emits benchmark rows.

Reads experiments/dryrun/{single,multi}/*.json written by
``python -m repro.launch.dryrun``. Missing records are reported as absent
rather than failing (so `benchmarks.run` works before the matrix has run).
"""

from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def bench_roofline() -> List[Row]:
    rows: List[Row] = []
    recs = load_records()
    if not recs:
        return [("roofline/records", 0.0, "run repro.launch.dryrun first")]
    ok = [r for r in recs if r.get("status") == "ok"]
    rows.append(("roofline/cells_ok", float(len(ok)), f"of {len(recs)}"))
    fits = sum(1 for r in ok if r["memory"]["fits_16gb"])
    rows.append(("roofline/cells_fit_16gb", float(fits), f"of {len(ok)}"))
    for r in ok:
        key = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        rl = r["roofline"]
        rows.append((f"roofline/fraction/{key}", rl["roofline_fraction"],
                     f"dom={rl['dominant']}"))
    return rows


def markdown_table(mesh: str = "single", dryrun_dir: str = DRYRUN_DIR) -> str:
    """The §Roofline markdown table for EXPERIMENTS.md."""
    recs = [
        r for r in load_records(dryrun_dir)
        if r.get("mesh") == mesh and r.get("status") == "ok"
    ]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | roofline frac | GB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rl = r["roofline"]
        lines.append(
            "| {arch} | {shape} | {c:.4f} | {m:.4f} | {x:.4f} | {dom} | "
            "{mf:.3e} | {ur:.3f} | {fr:.4f} | {gb:.2f} | {fit} |".format(
                arch=r["arch"], shape=r["shape"],
                c=rl["compute_s"], m=rl["memory_s"], x=rl["collective_s"],
                dom=rl["dominant"], mf=r["model_flops_total"],
                ur=rl["useful_compute_ratio"], fr=rl["roofline_fraction"],
                gb=r["memory"]["hbm_need_bytes"] / 1e9,
                fit="yes" if r["memory"]["fits_16gb"] else "NO",
            )
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    print(markdown_table(sys.argv[1] if len(sys.argv) > 1 else "single"))
