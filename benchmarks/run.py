"""Benchmark driver: one function per paper table/figure + kernel + roofline.

Prints ``name,value,derived`` CSV (value is us_per_call for timing rows and
the natural unit otherwise — unit stated in the derived column).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from .kernel_bench import bench_kernel
    from .paper_benchmarks import (
        bench_fig5_area_scaling,
        bench_fig6_utilization,
        bench_fig7_runtime,
        bench_table2,
    )
    from .roofline_report import bench_roofline

    benches = [
        bench_fig5_area_scaling,
        bench_fig6_utilization,
        bench_fig7_runtime,
        bench_table2,
        bench_kernel,
        bench_roofline,
    ]
    print("name,value,derived")
    failures = 0
    for bench in benches:
        try:
            for name, value, derived in bench():
                print(f"{name},{value:.6g},{derived}")
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{bench.__name__},nan,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
