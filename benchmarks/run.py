"""Benchmark driver: one function per paper table/figure + kernel + roofline.

Prints ``name,value,derived`` CSV (value is us_per_call for timing rows and
the natural unit otherwise — unit stated in the derived column).
"""

from __future__ import annotations

import subprocess
import sys
import traceback


def bench_meta() -> dict:
    """Shared provenance block every BENCH_* writer embeds (and every
    BENCH_history row carries): which commit, device and jax produced the
    numbers. Key order is fixed so regenerated artifacts diff cleanly."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        commit = "unknown"
    try:
        from repro.tune.table import device_kind

        device = device_kind()
    except Exception:
        device = "unknown"
    try:
        import jax

        jax_version = jax.__version__
    except Exception:
        jax_version = "unknown"
    return {
        "git_commit": commit,
        "device_kind": device,
        "jax_version": jax_version,
    }


def main() -> None:
    from .kernel_bench import bench_kernel
    from .paper_benchmarks import (
        bench_fig5_area_scaling,
        bench_fig6_utilization,
        bench_fig7_runtime,
        bench_table2,
    )
    from .roofline_report import bench_roofline

    benches = [
        bench_fig5_area_scaling,
        bench_fig6_utilization,
        bench_fig7_runtime,
        bench_table2,
        bench_kernel,
        bench_roofline,
    ]
    print("name,value,derived")
    failures = 0
    for bench in benches:
        try:
            for name, value, derived in bench():
                print(f"{name},{value:.6g},{derived}")
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{bench.__name__},nan,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
