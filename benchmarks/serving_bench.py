"""Serving benchmark: static lockstep batching vs continuous batching.

Replays one mixed-length request trace (seeded, deterministic) through both
engines and emits ``BENCH_serving.json``:

* **static** — FIFO groups of ``n_slots`` requests through ``ServeEngine``:
  the whole group decodes until its *longest* generation finishes, so every
  early-finishing lane idles (the utilization collapse the paper's
  low-occupancy baselines exhibit at the MAC level).
* **continuous** — the same trace through ``ContinuousEngine``: finished
  requests free their slot mid-flight and queued requests join, keeping
  decode lanes (the serving analogue of the paper's FPUs) busy.

Metrics per engine: useful tokens/sec (wall-clock, after a warmup pass that
absorbs compiles), useful tokens per decode step (deterministic, wall-clock
free), and mean decode-slot occupancy.

A second section replays a **shared-system-prompt** Poisson trace through
the continuous engine with the prefix cache off vs on
(:func:`bench_prefix_cache`): cache-on must keep greedy outputs bitwise
identical and drop TTFT p50 (joins resume from cached prefix K/V instead of
re-prefilling it). Both sections land in ``BENCH_serving.json`` and one
BENCH_history row (``continuous.*`` + ``prefix.*`` columns). Run::

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

try:  # package layout (benchmarks.serving_bench) vs direct script run
    from .run import bench_meta
    from . import history as bench_history
except ImportError:  # pragma: no cover - script-mode fallback
    from run import bench_meta
    import history as bench_history

# Deadline SLOs for goodput accounting. Deliberately generous for a CPU CI
# box — the gated signal is "goodput stays ~1.0 under these objectives",
# i.e. no request falls off a latency cliff, not a hardware-tuned target.
SLO_TTFT_MS = 5000.0
SLO_ITL_MS = 2000.0


def run_static(engine, requests, n_slots: int) -> Dict:
    """FIFO groups of ``n_slots`` through one lockstep ``ServeEngine``.

    Prompts inside a group are right-padded to the group max (throughput
    measurement only). Useful tokens = each request's own budget; the group
    decodes max(budget) steps, so every early-finishing lane idles — the
    waste being measured. The engine (and its compiled steps) is reused
    across groups and across the warmup pass.
    """
    import jax.numpy as jnp

    ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
    groups = [ordered[i : i + n_slots] for i in range(0, len(ordered), n_slots)]
    useful = sum(r.max_new_tokens for r in requests)
    # Same conventions as ContinuousEngine's counters: each request's first
    # token comes from prefill logits (not a decode dispatch), so a group
    # running `gen` tokens performs `gen - 1` decode steps, and a request's
    # lane is *busy* for its own max_new - 1 of them.
    decode_steps = 0
    busy_lane_steps = 0
    lane_steps = 0
    t0 = time.perf_counter()
    for g in groups:
        plen = max(len(r.prompt) for r in g)
        toks = np.zeros((len(g), plen), np.int32)
        for i, r in enumerate(g):
            toks[i, : len(r.prompt)] = np.asarray(r.prompt, np.int32)
        gen = max(r.max_new_tokens for r in g)
        out = engine.generate({"tokens": jnp.asarray(toks)}, gen)
        out.block_until_ready()
        decode_steps += gen - 1
        busy_lane_steps += sum(r.max_new_tokens - 1 for r in g)
        lane_steps += len(g) * (gen - 1)
    wall = time.perf_counter() - t0
    return {
        "engine": "static",
        "useful_tokens": useful,
        "decode_steps": decode_steps,
        "wall_time_s": wall,
        "tokens_per_sec": useful / wall if wall else 0.0,
        "tokens_per_step": useful / decode_steps if decode_steps else 0.0,
        "mean_occupancy": busy_lane_steps / lane_steps if lane_steps else 0.0,
    }


def _report_row(name: str, report, engine) -> Dict:
    return {
        "engine": name,
        "useful_tokens": report.generated_tokens,
        "decode_steps": report.decode_steps,
        "prefill_batches": report.prefill_batches,
        "wall_time_s": report.wall_time_s,
        "tokens_per_sec": report.tokens_per_sec,
        "tokens_per_step": report.tokens_per_step,
        "mean_occupancy": report.mean_occupancy,
        "decode_compilations": engine.decode_compilations(),
        "ttft_p50": report.ttft_p50,
        "ttft_p99": report.ttft_p99,
        "itl_p50": report.itl_p50,
        "itl_p99": report.itl_p99,
        "goodput": report.goodput,
        "queue_p50": report.queue_p50,
        "queue_p99": report.queue_p99,
        "attach_p50": report.attach_p50,
        "attach_p99": report.attach_p99,
        "chunk_prefill_p50": report.chunk_prefill_p50,
        "chunk_prefill_p99": report.chunk_prefill_p99,
        "slot_hwm": report.slot_hwm,
    }


def run_continuous(engine, requests) -> Dict:
    return _report_row("continuous", engine.timed_serve(requests), engine)


def serving_config(arch: str):
    """Reduced (CPU-sized) config scaled to *serving scale*: wide enough that
    a decode step is real compute (milliseconds), so the wall-clock
    comparison measures batching policy rather than dispatch overhead."""
    import dataclasses

    from repro.configs import get_config

    cfg = get_config(arch).reduced()
    return dataclasses.replace(
        cfg,
        name=cfg.name.replace("-reduced", "-serving"),
        head_dim=64,
        d_model=cfg.n_heads * 64,
        d_ff=1024 if cfg.d_ff else 0,
        vocab=8192,
    )


def bench_serving(
    arch: str = "chatglm3-6b",
    *,
    n_requests: int = 16,
    n_slots: int = 4,
    max_len: int = 160,
    seed: int = 0,
    prompt_lens=(6, 12, 17, 24, 32),
    gen_lens=(8, 24, 64, 96),
    warmup: bool = True,
) -> Dict:
    """Run both engines on one trace; returns the comparison dict."""
    import jax
    import jax.numpy as jnp

    from repro.models import api
    from repro.serve import ContinuousEngine, ServeEngine, poisson_trace

    cfg = serving_config(arch)
    params = api.init_params(cfg, jax.random.key(seed))
    cache_dtype = jnp.float32
    trace = poisson_trace(
        n_requests, seed=seed, vocab=cfg.vocab,
        prompt_lens=prompt_lens, gen_lens=gen_lens,
    )
    assert all(len(r.prompt) + r.max_new_tokens <= max_len for r in trace)

    # Both engines size their caches to the same max_len, and both reuse
    # their compiled steps across the warmup pass and the timed run.
    static_eng = ServeEngine(
        cfg=cfg, params=params, max_len=max_len, cache_dtype=cache_dtype
    )
    cont_eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=n_slots, max_len=max_len,
        cache_dtype=cache_dtype,
        slo_ttft_ms=SLO_TTFT_MS, slo_itl_ms=SLO_ITL_MS,
    )
    if warmup:
        # Replay the full trace once first: both engines hit every compiled
        # shape (static group shapes / continuous prefill buckets), so the
        # timed pass measures steady-state serving, not compiles.
        run_static(static_eng, trace, n_slots)
        run_continuous(cont_eng, trace)

    static = run_static(static_eng, trace, n_slots)
    continuous = run_continuous(cont_eng, trace)
    return {
        "meta": bench_meta(),
        "arch": cfg.name,
        "n_requests": n_requests,
        "n_slots": n_slots,
        "max_len": max_len,
        "seed": seed,
        "static": static,
        "continuous": continuous,
        "speedup_tokens_per_sec": continuous["tokens_per_sec"] / static["tokens_per_sec"],
        "speedup_tokens_per_step": continuous["tokens_per_step"] / static["tokens_per_step"],
        "occupancy_gain": continuous["mean_occupancy"] - static["mean_occupancy"],
    }


def bench_prefix_cache(
    arch: str = "chatglm3-6b",
    *,
    n_requests: int = 12,
    n_slots: int = 4,
    max_len: int = 288,
    seed: int = 0,
    prefix_len: int = 192,
    tail_lens=(8, 12, 16),
    gen_lens=(8, 16, 24),
    chunk: int = 32,
    warmup: bool = True,
) -> Dict:
    """Shared-system-prompt Poisson trace through the continuous engine,
    prefix cache off vs on (cache-on also chunk-prefills the suffix).

    Greedy tokens must agree bitwise — the cache changes *where* prefix K/V
    comes from, never its values — and cache-on TTFT should drop: joins
    resume from the cached prefix instead of re-prefilling it.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import api
    from repro.serve import ContinuousEngine, shared_prefix_trace

    cfg = serving_config(arch)
    params = api.init_params(cfg, jax.random.key(seed))
    trace = shared_prefix_trace(
        n_requests, seed=seed, vocab=cfg.vocab, prefix_len=prefix_len,
        tail_lens=tail_lens, gen_lens=gen_lens, mean_interarrival=2.0,
    )
    assert all(len(r.prompt) + r.max_new_tokens <= max_len for r in trace)

    off_eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=n_slots, max_len=max_len,
        cache_dtype=jnp.float32, prefill_chunk=None, prefix_cache=False,
        slo_ttft_ms=SLO_TTFT_MS, slo_itl_ms=SLO_ITL_MS,
    )
    on_eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=n_slots, max_len=max_len,
        cache_dtype=jnp.float32, prefill_chunk=chunk, prefix_cache=True,
        prefix_block=chunk,
        slo_ttft_ms=SLO_TTFT_MS, slo_itl_ms=SLO_ITL_MS,
    )
    if warmup:
        # One full replay each: every compiled shape (prefill buckets, chunk
        # steps, decode) is hot, and the warmup also populates the trie — the
        # timed cache-on pass measures steady-state hits, which is the
        # regime a long-lived server sits in.
        off_eng.timed_serve(trace)
        on_eng.timed_serve(trace)

    off_rep = off_eng.timed_serve(trace)
    # The timed cache-on run doubles as the trace-export fixture: reset the
    # lifecycle recorder so the exported timeline holds exactly this run's
    # spans, then check each request's phase chain sums to its TTFT sample.
    from repro.obs import tracing

    tracing.reset()
    on_rep = on_eng.timed_serve(trace)
    decomposition, chrome = trace_decomposition(tracing.snapshot())
    off = _report_row("cache_off", off_rep, off_eng)
    on = _report_row("cache_on", on_rep, on_eng)
    on["prefix_cache"] = on_eng.prefix_cache_stats()
    # Bitwise greedy agreement, request by request, from the timed runs.
    agreement = sum(
        1 for r in trace if off_rep.outputs[r.rid] == on_rep.outputs[r.rid]
    ) / len(trace)
    return {
        "meta": bench_meta(),
        "arch": cfg.name,
        "n_requests": n_requests,
        "n_slots": n_slots,
        "prefix_len": prefix_len,
        "chunk": chunk,
        "seed": seed,
        "cache_off": off,
        "cache_on": on,
        "greedy_agreement": agreement,
        "ttft_p50_ratio": (
            on["ttft_p50"] / off["ttft_p50"]
            if on["ttft_p50"] and off["ttft_p50"] else None
        ),
        "trace_decomposition": decomposition,
        "_chrome_trace": chrome,  # popped by main(), written to --trace-out
    }


def trace_decomposition(snap: Dict) -> tuple:
    """Validate the exported timeline against the engine's own latency
    accounting: for every retired request, the pre-decode phase durations
    (queue + prefix_attach + chunk_prefill, or queue + prefill) must sum to
    the ``ttft_s`` stamped on its first-token instant — the exact value the
    engine observed into ``serve.ttft_seconds``. Returns
    ``({"requests", "max_abs_err_ms", "enabled"}, chrome_doc)``; the chrome
    doc is structurally validated too (span pairing, non-negative dur)."""
    from repro.obs import tracing

    if not snap.get("requests"):
        return {"requests": 0, "max_abs_err_ms": None,
                "enabled": tracing.enabled()}, None
    pre = ("queue", "prefix_attach", "chunk_prefill", "prefill")
    max_err = 0.0
    checked = 0
    for req in snap["requests"]:
        ft = next(
            (i for i in req["instants"] if i["name"] == "first_token"), None
        )
        if ft is None:
            continue
        total = sum(
            p["t1"] - p["t0"] for p in req["phases"]
            if p["name"] in pre and p["t1"] is not None
        )
        max_err = max(max_err, abs(total - ft["ttft_s"]))
        checked += 1
    chrome = tracing.chrome_trace(snap)
    tracing.validate_chrome_trace(chrome)
    return {
        "requests": checked,
        "max_abs_err_ms": max_err * 1e3,
        "enabled": True,
    }, chrome


def history_metrics(result: Dict, prefix: Dict = None) -> Dict:
    """Flatten a serving comparison into the BENCH_history row schema.
    Percentiles may be None (no samples) — history keeps the null."""
    c = result["continuous"]
    row = {
        "continuous.tokens_per_step": c["tokens_per_step"],
        "continuous.tokens_per_sec": c["tokens_per_sec"],
        "continuous.mean_occupancy": c["mean_occupancy"],
        "continuous.ttft_p50": c["ttft_p50"],
        "continuous.ttft_p99": c["ttft_p99"],
        "continuous.itl_p50": c["itl_p50"],
        "continuous.itl_p99": c["itl_p99"],
        "continuous.goodput": c.get("goodput"),
        "continuous.queue_p50": c.get("queue_p50"),
        "continuous.queue_p99": c.get("queue_p99"),
        "continuous.slot_hwm": c.get("slot_hwm"),
        "speedup_tokens_per_step": result["speedup_tokens_per_step"],
        "occupancy_gain": result["occupancy_gain"],
    }
    if prefix is not None:
        on, off = prefix["cache_on"], prefix["cache_off"]
        row.update({
            "prefix.ttft_p50_on": on["ttft_p50"],
            "prefix.ttft_p50_off": off["ttft_p50"],
            "prefix.ttft_p50_ratio": prefix["ttft_p50_ratio"],
            "prefix.tokens_per_sec_on": on["tokens_per_sec"],
            "prefix.greedy_agreement": prefix["greedy_agreement"],
            "prefix.hits": (on.get("prefix_cache") or {}).get("hits"),
            "prefix.goodput_on": on.get("goodput"),
            "prefix.attach_p50_on": on.get("attach_p50"),
        })
    return row


def _ms(v) -> str:
    """None-safe ms rendering: an empty trace has no percentile, not 0 ms."""
    return "n/a" if v is None else f"{v * 1e3:.2f}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace-event timeline of the timed cache-on "
                    "run (load in Perfetto / chrome://tracing); default: "
                    "<--out stem>_trace.json, next to --out")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI (still asserts the win)")
    ap.add_argument("--history-dir", default=bench_history.HISTORY_DIR,
                    help="append a commit-keyed row here (see history.py)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history append")
    args = ap.parse_args()

    kw = {}
    pkw = {}
    if args.smoke:
        # Decode-heavy, high-variance generation lengths: the regime where
        # static batching pins whole groups on the longest request.
        kw = dict(n_requests=8, n_slots=2, max_len=80,
                  prompt_lens=(6, 12, 17), gen_lens=(4, 16, 48))
        # 3 slots keep the queue shallow: queue wait is identical cache-on
        # and cache-off, so it only dilutes the TTFT ratio the gate checks.
        pkw = dict(n_requests=6, n_slots=3, max_len=128, prefix_len=64,
                   tail_lens=(6, 10), gen_lens=(4, 8), chunk=16)
    result = bench_serving(
        args.arch, seed=args.seed, **(
            kw or dict(n_requests=args.n_requests, n_slots=args.slots,
                       max_len=args.max_len)
        )
    )
    prefix = bench_prefix_cache(args.arch, seed=args.seed, **pkw)
    chrome = prefix.pop("_chrome_trace", None)
    result["prefix_cache"] = prefix
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    trace_out = args.trace_out or (
        os.path.splitext(args.out)[0] + "_trace.json"
    )
    if chrome is not None:
        with open(trace_out, "w") as f:
            json.dump(chrome, f)
        print(f"[serving_bench] chrome trace "
              f"({len(chrome['traceEvents'])} events) -> {trace_out}")
    if not args.no_history:
        hist = bench_history.append_row(
            "serving", history_metrics(result, prefix), result["meta"],
            directory=args.history_dir,
        )
        print(f"[serving_bench] history row -> {hist}")

    s, c = result["static"], result["continuous"]
    print(f"[serving_bench] {result['arch']}: {result['n_requests']} requests, "
          f"{result['n_slots']} slots")
    for row in (s, c):
        print(f"  {row['engine']:<11} {row['tokens_per_sec']:8.1f} tok/s  "
              f"{row['tokens_per_step']:5.2f} tok/step  "
              f"occupancy {row['mean_occupancy']:.3f}")
    print(f"  continuous latency: ttft p50/p99 {_ms(c['ttft_p50'])}/"
          f"{_ms(c['ttft_p99'])} ms, itl p50/p99 {_ms(c['itl_p50'])}/"
          f"{_ms(c['itl_p99'])} ms")
    print(f"  continuous/static: {result['speedup_tokens_per_sec']:.2f}x wall, "
          f"{result['speedup_tokens_per_step']:.2f}x per-step, "
          f"+{result['occupancy_gain']:.3f} occupancy -> {args.out}")
    pon, poff = prefix["cache_on"], prefix["cache_off"]
    stats = pon.get("prefix_cache") or {}
    print(f"  prefix cache ({prefix['n_requests']} reqs, shared "
          f"{prefix['prefix_len']}-token prompt): ttft p50 "
          f"{_ms(poff['ttft_p50'])} -> {_ms(pon['ttft_p50'])} ms, "
          f"{stats.get('hits', 0)} hits, greedy agreement "
          f"{prefix['greedy_agreement']:.2f}")
    decomp = prefix.get("trace_decomposition") or {}
    if decomp.get("requests"):
        print(f"  trace decomposition: {decomp['requests']} requests, "
              f"phase-sum vs ttft max err "
              f"{decomp['max_abs_err_ms']:.4f} ms")
    if not (
        result["speedup_tokens_per_step"] > 1.0
        and result["occupancy_gain"] > 0.0
    ):
        raise SystemExit("continuous batching did not beat static batching")
    if prefix["greedy_agreement"] != 1.0:
        raise SystemExit("prefix cache changed greedy outputs")
    if not (
        pon["ttft_p50"] is not None
        and poff["ttft_p50"] is not None
        and pon["ttft_p50"] < poff["ttft_p50"]
    ):
        raise SystemExit("prefix cache did not improve TTFT p50")
    if decomp.get("enabled"):
        # The exported timeline must agree with the engine's own latency
        # accounting: each request's pre-decode phases sum to its TTFT sample.
        if not decomp.get("requests"):
            raise SystemExit(
                "tracing enabled but no requests carried a first_token "
                "instant — trace export is broken"
            )
        if decomp["max_abs_err_ms"] > 1.0:
            raise SystemExit(
                f"trace phase decomposition drifted from measured TTFT: "
                f"max err {decomp['max_abs_err_ms']:.3f} ms > 1 ms"
            )


if __name__ == "__main__":
    main()
