"""Design-space exploration with the O-POPE engine model.

Sweeps mesh size, pipeline depth and workload shape to show the paper's two
central trade-offs: (1) K >= 2p hides the output-tile swap; (2) pipeline
depth L trades per-PE tile footprint against frequency (the registers ARE
the buffers, so deeper pipelines need M,N multiples of sqrt(L)*p to stay
utilized).

Run: ``PYTHONPATH=src python examples/engine_design_space.py``
"""

from repro.core.engine import EngineConfig, simulate_gemm
from repro.core.sota import area_model_mm2
from repro.core.tiling import tiled_gemm_cycles


def main() -> None:
    print("== utilization vs K (p=16, M=N=64): the K >= 2p condition ==")
    for k in (8, 16, 32, 64, 128, 512):
        u = simulate_gemm(EngineConfig(p=16), 64, k, 64).utilization
        bar = "#" * int(40 * u)
        print(f"  K={k:4d}  {100 * u:6.2f}%  {bar}")

    print("== utilization vs pipeline depth (64x256x128 on p=4) ==")
    for L in (1, 4, 16):
        cfg = EngineConfig(p=4, pipe_depth=L)
        u = simulate_gemm(cfg, 64, 256, 128).utilization
        print(f"  L={L:2d} (tile {cfg.tile_m}x{cfg.tile_n})  {100 * u:6.2f}%")

    print("== area/perf across mesh sizes (FP16 MACs, 1 GHz) ==")
    for p in (4, 8, 16, 32):
        cfg = EngineConfig(p=p)
        a = area_model_mm2(cfg)
        print(f"  {p:2d}x{p:<2d}  {a['total']:7.4f} mm2  "
              f"{cfg.peak_gflops:7.1f} GFLOPS  "
              f"buffers {100 * a['input_buffers'] / a['total']:.2f}%")

    print("== cluster-level tiled GEMM (2048x1024x2048) ==")
    res = tiled_gemm_cycles(EngineConfig(p=16), 2048, 1024, 2048)
    print(f"  plan {res['plan'].tm}x{res['plan'].tk}x{res['plan'].tn}  "
          f"util {100 * res['utilization']:.2f}%  bound: {res['bound']}")


if __name__ == "__main__":
    main()
