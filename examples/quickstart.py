"""Quickstart: the O-POPE GEMM three ways + the paper's headline numbers.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, simulate_gemm
from repro.core.sota import table2_model
from repro.kernels import ops
from repro.kernels.opope_gemm import opope_gemm
from repro.kernels.ref import reference_matmul


def main() -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 512)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((512, 128)), jnp.bfloat16)
    c = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)

    # 1. The Pallas kernel (interpret mode on CPU; Mosaic on a real TPU),
    #    with the paper's accumulator-preload path fusing "+ C" for free.
    out = opope_gemm(a, b, c, out_dtype=jnp.float32, interpret=True)
    want = reference_matmul(a, b, c, out_dtype=jnp.float32)
    print("pallas kernel max err vs oracle:",
          float(jnp.max(jnp.abs(out - want))))

    # 2. The framework entry point every model layer uses (backend-routed).
    y = ops.matmul(a, b, backend="xla")
    print("ops.matmul:", y.shape, y.dtype)

    # 3. The cycle-accurate engine model: the paper's 99.97% headline.
    r = simulate_gemm(EngineConfig(p=4), 64, 256, 128)
    print(f"O-POPE 4x4 on 64x256x128: utilization {100 * r.utilization:.2f}% "
          f"(paper: 99.97%), {r.total_cycles} cycles")

    # 4. Table II reproduction.
    for name, row in table2_model().items():
        print(f"  {name:10s} {row['gflops']:6.1f} GFLOPS "
              f"{row['gflops_per_mm2']:7.1f} GFLOPS/mm2 "
              f"{row['tflops_per_w']:.2f} TFLOPS/W")


if __name__ == "__main__":
    main()
