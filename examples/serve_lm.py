"""Batched serving example: prefill a prompt batch, decode with the KV cache.

Run: ``PYTHONPATH=src python examples/serve_lm.py [--arch jamba-v0.1-52b]``
(reduced configs; the production decode shapes are exercised by the dry-run)
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = api.init_params(cfg, jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.n_img_tokens, cfg.d_model),
            jnp.float32,
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.enc_seq, cfg.d_model),
            jnp.float32,
        )

    eng = ServeEngine(cfg=cfg, params=params,
                      max_len=args.prompt_len + args.gen,
                      cache_dtype=jnp.float32)
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s on CPU)")
    print("[serve] sample:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
