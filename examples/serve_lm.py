"""Continuous-batching serving example: mixed-length requests arrive over
time, join free decode slots mid-flight, and stream tokens as they retire.

Run: ``PYTHONPATH=src python examples/serve_lm.py [--arch chatglm3-6b]``
(reduced configs on CPU; ``--engine static`` runs the lockstep baseline).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.serve import (
    ContinuousEngine,
    ServeEngine,
    gen_len_spread,
    poisson_trace,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slot pool size (continuous engine)")
    ap.add_argument("--gen", type=int, default=24,
                    help="max generation length in the trace")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean request interarrival in decode steps (0=burst)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = api.init_params(cfg, jax.random.key(args.seed))
    max_len = 32 + args.gen

    engine = args.engine
    if engine == "continuous" and cfg.family in ("audio", "vlm"):
        # Continuous batching serves token-prompt LMs; audio needs encoder
        # frames and vlm per-request image embeddings.
        print(f"[serve] {cfg.family} family: falling back to the static engine")
        engine = "static"

    if engine == "static":
        b = args.slots
        batch = {"tokens": jax.random.randint(
            jax.random.key(1), (b, 24), 0, cfg.vocab)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.key(2), (b, cfg.n_img_tokens, cfg.d_model),
                jnp.float32,
            )
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                jax.random.key(2), (b, cfg.enc_seq, cfg.d_model), jnp.float32
            )
        eng = ServeEngine(cfg=cfg, params=params, max_len=max_len,
                          cache_dtype=jnp.float32)
        t0 = time.perf_counter()
        out = eng.generate(batch, args.gen)
        dt = time.perf_counter() - t0
        print(f"[serve:static] {out.shape} tokens in {dt:.2f}s "
              f"({out.size / dt:.1f} tok/s on CPU)")
        print("[serve:static] sample:", out[0, :12].tolist())
        return

    gens = gen_len_spread(args.gen)
    trace = poisson_trace(
        args.n_requests, seed=args.seed, vocab=cfg.vocab,
        prompt_lens=(6, 12, 17, 24), gen_lens=gens,
        mean_interarrival=args.rate,
    )

    eng = ContinuousEngine(cfg=cfg, params=params, n_slots=args.slots,
                           max_len=max_len, cache_dtype=jnp.float32)
    streamed = []
    report = eng.timed_serve(
        trace, on_token=lambda rid, tok: streamed.append((rid, tok))
    )
    print(f"[serve:continuous] {cfg.name}: {report.generated_tokens} tokens "
          f"for {len(trace)} requests in {report.wall_time_s:.2f}s "
          f"({report.tokens_per_sec:.1f} tok/s on CPU)")
    print(f"[serve:continuous] decode steps {report.decode_steps}, "
          f"prefill batches {report.prefill_batches}, "
          f"mean slot occupancy {report.mean_occupancy:.3f} "
          f"(the serving analogue of the paper's FPU utilization)")
    for r in trace[:4]:
        print(f"[serve:continuous] rid={r.rid} arrival={r.arrival:>3} "
              f"prompt={len(r.prompt):>2} -> {report.outputs[r.rid][:8]}...")
    print(f"[serve:continuous] streamed {len(streamed)} tokens live")


if __name__ == "__main__":
    main()
