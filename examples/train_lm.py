"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A scaled chatglm3-family config (~100M params) learns a Markov token stream
through the full stack — O-POPE matmul path, AdamW, checkpointing, fault-
tolerant loop. Loss falls from ln(4096) toward the stream's ~ln(4) entropy
floor.

Run: ``PYTHONPATH=src python examples/train_lm.py [--steps 300]``
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config
from repro.data import MarkovLMDataset, make_batch_fn
from repro.models import api
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train


def make_100m_config():
    base = get_config("chatglm3-6b")
    return dataclasses.replace(
        base,
        name="chatglm3-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv=2,
        head_dim=64,
        d_ff=3072,
        vocab=8192,
        param_dtype="float32",
        q_chunk=128,
        kv_chunk=128,
        loss_chunk=128,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = make_100m_config()
    n_params = api.param_count(cfg)
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params")

    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=30, total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoopConfig(
            total_steps=args.steps, ckpt_every=100, ckpt_dir=ckpt_dir,
            log_every=25,
        )
        res = train(cfg, opt, loop, make_batch_fn(ds),
                    init_key=jax.random.key(0))
    print(f"[example] loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"(floor ~1.39)")


if __name__ == "__main__":
    main()
