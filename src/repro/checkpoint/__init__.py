"""Checkpoint substrate: atomic sharded save/restore + async writer."""
from .checkpoint import AsyncCheckpointer, latest_step, restore, save
__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save"]
