"""Crash-consistent sharded checkpointing with async writes and auto-resume.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (named by
the '/'-joined tree path, escaped) plus ``manifest.json`` (treedef, shapes,
dtypes, step). Writes go to ``step_<N>.tmp/`` and are atomically renamed
after fsync — a partially-written checkpoint is never visible, so
``latest_step`` always resumes from a complete one (fault tolerance:
kill -9 mid-write loses at most one checkpoint interval; tested).

``AsyncCheckpointer`` moves serialization + IO off the training thread; at
most one write is in flight (a new save waits for the previous). Restore
re-places leaves with target shardings — including onto a *different* mesh
(elastic re-scale path, tested 8 -> 4 devices).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _esc(path_str: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "__", path_str)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves_with_paths:
        ps = _path_str(path)
        fn = _esc(ps) + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"path": ps, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest complete checkpoint step, or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs), optionally placing with ``shardings`` (same tree
    structure). Works across mesh shapes: full arrays load host-side first."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        ps = _path_str(path)
        if ps not in by_path:
            raise KeyError(f"checkpoint missing leaf {ps!r}")
        arr = np.load(os.path.join(d, by_path[ps]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {ps}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Single-flight background checkpoint writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # Device->host copy happens here (synchronously) so the caller can
        # donate/overwrite device buffers; IO runs in the background.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for m in (
                re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.ckpt_dir)
            )
            if m
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
