"""Single version-resolution choke point for drifted JAX APIs.

The repo targets JAX 0.4.35 through current releases. A handful of APIs the
substrate depends on were renamed or reshaped across 0.4.x -> 0.6.x:

===============================  ==========================  =====================
API                              0.4.x                       0.5+/0.6+
===============================  ==========================  =====================
Pallas TPU compiler params       ``pltpu.TPUCompilerParams`` ``pltpu.CompilerParams``
Mesh axis types                  (absent)                    ``jax.sharding.AxisType``
``jax.make_mesh`` axis_types kw  (absent)                    present
Ambient mesh setter              ``with mesh:`` (resource    ``jax.set_mesh`` /
                                 env context manager)        ``jax.sharding.use_mesh``
Ambient mesh getter              (absent)                    ``jax.sharding.get_abstract_mesh``
``compiled.cost_analysis()``     one-element ``list``        ``dict``
``memory_analysis()`` peak       (absent)                    ``peak_memory_in_bytes``
===============================  ==========================  =====================

**Repo rule (see README):** no module outside this one may touch a
version-divergent JAX API directly. Everything routes through the shims
below, so a new JAX release is absorbed by editing exactly one file. The
acceptance grep for this rule is::

    grep -rn "CompilerParams\\|AxisType\\|get_abstract_mesh" src/repro \\
        --include="*.py" | grep -v compat.py   # must return no hits

All resolution is lazy and cached: importing this module never initializes
JAX device state (the dry-run sets ``XLA_FLAGS`` before the first device
query and must keep that window open).
"""

from __future__ import annotations

import functools
import inspect
import re
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple

import jax

__all__ = [
    "jax_version",
    "tpu_compiler_params",
    "get_mesh_axis_types",
    "make_mesh",
    "set_mesh",
    "current_abstract_mesh",
    "mesh_axis_sizes",
    "normalize_cost_analysis",
    "normalize_memory_analysis",
]


def jax_version() -> Tuple[int, ...]:
    """Installed JAX version as an int tuple (dev/rc suffixes dropped)."""
    parts = []
    for p in jax.__version__.split("."):
        m = re.match(r"\d+", p)
        if not m:
            break
        parts.append(int(m.group(0)))
    return tuple(parts)


# --------------------------------------------------------------------------
# Pallas TPU compiler params: TPUCompilerParams (0.4.x) -> CompilerParams.
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _compiler_params_cls():
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise AttributeError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        f"TPUCompilerParams (jax {jax.__version__})"
    )


def tpu_compiler_params(
    *, dimension_semantics: Optional[Sequence[str]] = None, **kwargs: Any
):
    """Mosaic compiler-params object under whichever name this JAX uses.

    Accepts the same keywords as the underlying class; ``dimension_semantics``
    is the one every kernel in the repo passes.
    """
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return _compiler_params_cls()(**kwargs)


# --------------------------------------------------------------------------
# Mesh construction: AxisType and the make_mesh axis_types kwarg are 0.5+.
# --------------------------------------------------------------------------


def get_mesh_axis_types(n_axes: int, kind: str = "auto") -> Optional[tuple]:
    """``(AxisType.<kind>,) * n_axes`` — or None when this JAX predates
    ``jax.sharding.AxisType`` (0.4.x, where all axes are implicitly auto)."""
    axis_type_cls = getattr(jax.sharding, "AxisType", None)
    if axis_type_cls is None:
        return None
    member = {"auto": "Auto", "explicit": "Explicit", "manual": "Manual"}[kind]
    return (getattr(axis_type_cls, member),) * n_axes


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Any = "auto",
    devices=None,
):
    """``jax.make_mesh`` that tolerates the missing ``axis_types`` kwarg.

    ``axis_types`` may be an AxisType kind name ("auto"/"explicit"/"manual")
    or an explicit tuple; on 0.4.x it is dropped (the only behaviour that
    version supports is auto).
    """
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if isinstance(axis_types, str):
        axis_types = get_mesh_axis_types(len(axis_names), axis_types)
    if axis_types is not None and _make_mesh_supports_axis_types():
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


@functools.lru_cache(maxsize=None)
def _make_mesh_supports_axis_types() -> bool:
    # Feature-detect the kwarg instead of catching TypeError around the call:
    # a malformed axis_types value also raises TypeError, and that error must
    # surface, not silently downgrade the mesh to default axis types.
    return "axis_types" in inspect.signature(jax.make_mesh).parameters


# --------------------------------------------------------------------------
# Ambient mesh: jax.set_mesh / get_abstract_mesh are 0.5+; on 0.4.x the
# equivalent is the resource-env context manager (``with mesh:``) plus a
# module-level stack so the getter below can answer.
# --------------------------------------------------------------------------

_MESH_STACK: list = []


@contextmanager
def set_mesh(mesh) -> Iterator[Any]:
    """Context manager installing ``mesh`` as the ambient mesh.

    Resolves to ``jax.set_mesh`` when present, then ``jax.sharding.use_mesh``
    (the 0.5.x-era context manager), then the physical mesh's own
    resource-env context (the classic pjit idiom). The mesh is also recorded
    on a module-level stack so :func:`current_abstract_mesh` can answer even
    when the installed getter does not see this setter's effect.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
        return
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    _MESH_STACK.append(mesh)
    try:
        with (use_mesh(mesh) if use_mesh is not None else mesh):
            yield mesh
    finally:
        _MESH_STACK.pop()


def current_abstract_mesh():
    """The ambient mesh, or None when none is set.

    On 0.5+ this is ``jax.sharding.get_abstract_mesh()`` (an AbstractMesh,
    possibly empty). On 0.4.x it falls back to the physical mesh installed by
    :func:`set_mesh` (or by a raw ``with mesh:`` resource env). Callers must
    treat "None / empty axis_names" as "no mesh".
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        ambient = getter()
        if getattr(ambient, "axis_names", ()) or ():
            return ambient
        # Empty abstract mesh but a mesh on our stack: the installed setter
        # (``with mesh:`` fallback) doesn't feed this getter — answer from
        # the stack instead of reporting "no mesh".
        return _MESH_STACK[-1] if _MESH_STACK else ambient
    if _MESH_STACK:
        return _MESH_STACK[-1]
    try:  # raw `with mesh:` without our set_mesh — best-effort recovery
        from jax._src import mesh as _mesh_lib

        physical = _mesh_lib.thread_resources.env.physical_mesh
        if physical is not None and not physical.empty:
            return physical
    except Exception:
        pass
    return None


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """``{axis_name: size}`` for Mesh and AbstractMesh across versions."""
    if mesh is None:
        return {}
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, (int(s) for s in sizes)))
    return {str(k): int(v) for k, v in dict(getattr(mesh, "shape", {})).items()}


# --------------------------------------------------------------------------
# cost_analysis: dict on recent JAX, one-element list of dicts on 0.4.x.
# --------------------------------------------------------------------------


def normalize_cost_analysis(compiled_or_result) -> Dict[str, float]:
    """Uniform dict view of ``compiled.cost_analysis()``.

    Accepts either the compiled executable or the raw ``cost_analysis()``
    return value; None (backends that report nothing) becomes ``{}``.
    """
    result = compiled_or_result
    if hasattr(result, "cost_analysis"):
        result = result.cost_analysis()
    if result is None:
        return {}
    if isinstance(result, (list, tuple)):
        result = result[0] if result else {}
    return dict(result)


def normalize_memory_analysis(compiled_or_stats) -> Dict[str, int]:
    """Uniform dict view of ``compiled.memory_analysis()``.

    ``peak_bytes`` is the buffer-assignment high-water mark where the
    runtime reports one (``peak_memory_in_bytes``, newer JAX); on 0.4.x it is
    bounded above by arguments + outputs + temps - aliased bytes.
    """
    stats = compiled_or_stats
    if hasattr(stats, "memory_analysis"):
        stats = stats.memory_analysis()

    def grab(name: str) -> int:
        return int(getattr(stats, name, 0) or 0)

    out = {
        "argument_bytes": grab("argument_size_in_bytes"),
        "output_bytes": grab("output_size_in_bytes"),
        "temp_bytes": grab("temp_size_in_bytes"),
        "alias_bytes": grab("alias_size_in_bytes"),
    }
    peak = getattr(stats, "peak_memory_in_bytes", None)
    if peak is None:
        peak = (
            out["argument_bytes"]
            + out["output_bytes"]
            + out["temp_bytes"]
            - out["alias_bytes"]
        )
    out["peak_bytes"] = max(int(peak), 0)
    return out
