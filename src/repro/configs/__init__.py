"""Assigned-architecture registry: ``get_config(name)`` / ``ARCHS``."""

from .base import (
    ArchConfig,
    BlockDef,
    MambaSpec,
    MoESpec,
    SHAPES,
    ShapeConfig,
    applicable_shapes,
    shape_by_name,
)
from .chatglm3_6b import CONFIG as chatglm3_6b
from .gemma2_9b import CONFIG as gemma2_9b
from .stablelm_12b import CONFIG as stablelm_12b
from .qwen2_5_32b import CONFIG as qwen2_5_32b
from .grok_1_314b import CONFIG as grok_1_314b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .xlstm_125m import CONFIG as xlstm_125m
from .llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from .whisper_base import CONFIG as whisper_base

ARCHS = {
    c.name: c
    for c in (
        chatglm3_6b,
        gemma2_9b,
        stablelm_12b,
        qwen2_5_32b,
        grok_1_314b,
        deepseek_moe_16b,
        jamba_v0_1_52b,
        xlstm_125m,
        llava_next_mistral_7b,
        whisper_base,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS",
    "get_config",
    "ArchConfig",
    "BlockDef",
    "MambaSpec",
    "MoESpec",
    "SHAPES",
    "ShapeConfig",
    "applicable_shapes",
    "shape_by_name",
]
