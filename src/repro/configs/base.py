"""Architecture / shape configuration schema.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances. A config fully
determines the model pytree, the block program (``pattern`` — the repeating
period of heterogeneous layers that the layer-scan iterates), the sharding
rules, and the applicable shape cells (``supports_long`` / ``has_decoder``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = [
    "MoESpec",
    "MambaSpec",
    "BlockDef",
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: Optional[int] = None  # defaults to n_shared * d_ff_expert
    capacity_factor: float = 1.25
    dispatch: str = "onehot"  # paper-faithful baseline; "sort" = optimized
    group_size: int = 512  # routing group (per-group capacity, local sorts)
    # Dropless routing (capacity = group size, so no assignment can overflow).
    # Token-choice capacity dropping makes autoregressive decode diverge from
    # teacher forcing (drop decisions depend on the whole token group, which
    # a decode step cannot see); consistency-critical configs set this.
    dropless: bool = False


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    expand: int = 2
    d_state: int = 16
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One layer of the repeating period."""

    mixer: str  # attn | attn_local | mamba | mlstm | slstm | none
    ffn: str  # mlp | moe | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    pattern: Tuple[BlockDef, ...]
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_frac: float = 1.0  # chatglm3 2-D RoPE: 0.5
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding window for attn_local blocks
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    qkv_bias: bool = False  # qwen2.5
    parallel_block: bool = False  # stablelm: attn + mlp share the residual
    tie_embeddings: bool = True
    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    # encoder-decoder (whisper): encoder depth & fixed source length
    n_enc_layers: int = 0
    enc_seq: int = 0
    # VLM (llava): number of stub patch-embedding tokens prepended
    n_img_tokens: int = 0
    supports_long: bool = False  # runs the long_500k cell (SSM/hybrid only)
    param_dtype: str = "bfloat16"
    # execution knobs (hillclimb surface)
    q_chunk: int = 512
    kv_chunk: int = 1024
    scan_chunk: int = 64  # mamba / mlstm chunk length
    loss_chunk: int = 512  # vocab-CE token chunking
    grad_accum: int = 1
    remat: bool = True
    remat_policy: str = "full"  # full | dots | none (§Perf knob)
    attn_seq_shard: bool = False  # context-parallel attention core (§Perf)
    moment_dtype: str = "float32"  # grok: bfloat16 to fit HBM

    def __post_init__(self) -> None:
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"pattern length {len(self.pattern)}"
            )
        if self.n_heads % max(self.n_kv, 1):
            raise ValueError(f"{self.name}: n_heads % n_kv != 0")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all 10 assigned archs decode (whisper via its decoder)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (one period, small dims)."""
        hd = 16
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv if self.n_kv <= n_heads else n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        moe = (
            dataclasses.replace(
                self.moe,
                n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                d_ff_expert=32,
                d_ff_shared=32 if self.moe.n_shared else None,
                # Smoke tests check prefill+decode against teacher forcing;
                # with an untrained (imbalanced) router, capacity dropping
                # would make those paths disagree by construction.
                dropless=True,
            )
            if self.moe
            else None
        )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=len(self.pattern),
            d_model=n_heads * hd,
            n_heads=n_heads,
            n_kv=n_kv,
            head_dim=hd,
            d_ff=96 if self.d_ff else 0,
            vocab=256,
            window=min(self.window, 16) if self.window else None,
            moe=moe,
            n_enc_layers=1 if self.n_enc_layers else 0,
            enc_seq=24 if self.enc_seq else 0,
            n_img_tokens=8 if self.n_img_tokens else 0,
            param_dtype="float32",
            q_chunk=16,
            kv_chunk=16,
            scan_chunk=8,
            loss_chunk=32,
            grad_accum=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned cells for this arch (long_500k only for SSM/hybrid)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.supports_long:
            continue
        out.append(s)
    return tuple(out)
