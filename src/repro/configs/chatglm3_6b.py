"""chatglm3-6b [dense]: 28L d=4096 32H (GQA kv=2) ff=13696 V=65024.

2-D RoPE (rotary over half the head dim), GQA. [arXiv:2406.12793; hf]
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv=2,
    d_ff=13696,
    vocab=65024,
    pattern=(BlockDef("attn", "mlp"),),
    rope_frac=0.5,  # 2-D RoPE: rotate half the head dimensions
    norm="rmsnorm",
    tie_embeddings=False,
    supports_long=False,
)
