"""deepseek-moe-16b [moe]: 28L d=2048 16H ff=1408 V=102400, 64e top-6 + 2 shared.

Fine-grained experts (d_ff_expert=1408), 2 shared experts always active.
First layer is a dense MLP (the HF config's first_k_dense_replace=1 is
folded into the pattern as layer 0 dense + 27 MoE layers is approximated by
a uniform MoE pattern; deviation noted in DESIGN.md). [arXiv:2401.06066; hf]
EP: 64 experts / 16-way model axis = 4 per shard.
"""

from .base import ArchConfig, BlockDef, MoESpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=102400,
    pattern=(BlockDef("attn", "moe"),),
    moe=MoESpec(
        n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, d_ff_shared=2816
    ),
    norm="rmsnorm",
    tie_embeddings=True,
    supports_long=False,
)
