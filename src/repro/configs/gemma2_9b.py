"""gemma2-9b [dense]: 42L d=3584 16H (GQA kv=8) ff=14336 V=256000.

Alternating local(4096-window)/global attention, attn softcap 50, final
logit softcap 30. [arXiv:2408.00118; hf] Global layers are full attention ->
long_500k skipped.
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,  # gemma2-9b uses 256-wide heads (16 x 256 = 4096 > d_model)
    pattern=(BlockDef("attn_local", "mlp"), BlockDef("attn", "mlp")),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    tie_embeddings=True,
    supports_long=False,
)
