"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) ff=32768 V=131072, 8e top-2.

[hf:xai-org/grok-1; unverified] E=8 does not divide the 16-way model axis ->
TP inside experts (d_ff 32768/16); bf16 optimizer moments + 8x grad
accumulation to fit 16 GB/chip (DESIGN.md §4; fit proven by memory_analysis).
Full attention -> long_500k skipped.
"""

from .base import ArchConfig, BlockDef, MoESpec

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=32768,  # dense-equivalent width; experts use d_ff_expert below
    vocab=131072,
    pattern=(BlockDef("attn", "moe"),),
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=32768),
    norm="rmsnorm",
    tie_embeddings=True,
    supports_long=False,
    grad_accum=8,
    moment_dtype="bfloat16",
)
