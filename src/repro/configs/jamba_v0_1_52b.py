"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) ff=14336, 16e top-2 MoE.

Mamba:attention 7:1 interleave; MoE every other layer (e:2 in the paper's
notation). Period of 8: attention at position 4 (matching the HF config's
attn_layer_offset=4), MoE on odd positions. [arXiv:2403.19887; hf]
Hybrid (mamba state + 4 attention layers) -> RUNS long_500k.
"""

from .base import ArchConfig, BlockDef, MambaSpec, MoESpec

_P = (
    BlockDef("mamba", "mlp"),
    BlockDef("mamba", "moe"),
    BlockDef("mamba", "mlp"),
    BlockDef("mamba", "moe"),
    BlockDef("attn", "mlp"),
    BlockDef("mamba", "moe"),
    BlockDef("mamba", "mlp"),
    BlockDef("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=65536,
    pattern=_P,
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaSpec(expand=2, d_state=16, d_conv=4),
    norm="rmsnorm",
    tie_embeddings=True,
    supports_long=True,
)
