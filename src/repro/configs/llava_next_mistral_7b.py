"""llava-next-mistral-7b [vlm]: mistral-7b backbone, anyres stub frontend.

32L d=4096 32H (GQA kv=8) ff=14336 V=32000. The vision tower is a stub:
input_specs() provides precomputed patch embeddings [B, 2880, d_model]
(base 576 + 4 anyres tiles x 576). The multimodal projector is real.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Full attention -> long_500k skipped.
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    pattern=(BlockDef("attn", "mlp"),),
    norm="rmsnorm",
    tie_embeddings=False,
    n_img_tokens=2880,
    supports_long=False,
)
