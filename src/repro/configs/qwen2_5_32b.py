"""qwen2.5-32b [dense]: 64L d=5120 40H (GQA kv=8) ff=27648 V=152064.

GQA with QKV bias. [hf:Qwen/Qwen2.5-32B; hf] 40 heads are not divisible by
the 16-way model axis -> heads stay unsharded; TP lands on d_ff / d_model
(DESIGN.md §4). Full attention -> long_500k skipped.
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=27648,
    vocab=152064,
    pattern=(BlockDef("attn", "mlp"),),
    qkv_bias=True,
    norm="rmsnorm",
    tie_embeddings=False,
    supports_long=False,
)
