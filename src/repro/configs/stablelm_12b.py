"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) ff=13824 V=100352.

Parallel attention+MLP block (StableLM-2 style), LayerNorm.
[hf:stabilityai/stablelm-2-12b; hf] Full attention -> long_500k skipped.
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    d_ff=13824,
    vocab=100352,
    pattern=(BlockDef("attn", "mlp"),),
    parallel_block=True,
    norm="layernorm",
    tie_embeddings=False,
    supports_long=False,
)
