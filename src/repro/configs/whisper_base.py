"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H ff=2048 V=51865.

Enc-dec with conv frontend STUB: input_specs() provides precomputed frame
embeddings [B, 1500, 512]. Vocab 51865 is not divisible by the 16-way model
axis -> embedding unsharded on vocab (the model is 72M params; irrelevant).
[arXiv:2212.04356; unverified]
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    pattern=(BlockDef("attn", "mlp"),),
    norm="layernorm",
    rope_frac=0.0,  # whisper uses absolute positions, no RoPE
    tie_embeddings=True,
    n_enc_layers=6,
    enc_seq=1500,
    supports_long=False,
)
