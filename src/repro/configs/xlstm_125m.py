"""xlstm-125m [ssm]: 12L d=768 4H V=50304; alternating sLSTM/mLSTM blocks.

[arXiv:2405.04517; unverified] 1:1 alternation (the paper sweeps ratios);
mLSTM chunkwise-parallel for train/prefill, exact recurrence for decode.
Sequence-independent state -> RUNS long_500k.
"""

from .base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv=4,
    d_ff=3072,  # post-up-proj FFN width (assignment lists d_ff=0: the xLSTM
    # block has no separate FFN; we keep ffn="none" below and use
    # this only for the reduced smoke config sizing)
    vocab=50304,
    pattern=(BlockDef("slstm", "none"), BlockDef("mlstm", "none")),
    norm="layernorm",
    tie_embeddings=True,
    supports_long=True,
)
