"""O-POPE core: the paper's contribution as reusable models and analyses.

* :mod:`repro.core.engine` — cycle-accurate O-POPE engine model (§II/§III-C).
* :mod:`repro.core.dataflows` — Gemmini / RedMulE / Sauria baseline models.
* :mod:`repro.core.tiling` — L1 double-buffered tiling (§II-C, Fig. 7 setup).
* :mod:`repro.core.sota` — published PPA constants + Table II / Fig. 5 models.
* :mod:`repro.core.roofline` — TPU v5e three-term roofline for the dry-run.
* :mod:`repro.core.hlo_analysis` — collective-traffic extraction from HLO.
"""

from .engine import (
    EngineConfig,
    CycleReport,
    simulate_gemm,
    simulate_gemm_cycle_accurate,
    OPOPE_16x16_FP16,
)
from .dataflows import ACCELERATORS, AcceleratorModel
from .tiling import ClusterConfig, TilingPlan, choose_tile, tiled_gemm_cycles
from .roofline import (
    TPU_V5E,
    HardwareSpec,
    RooflineTerms,
    dtype_width,
    gemm_bytes,
    gemm_intensity,
    model_flops,
    roofline_terms,
    tensor_bytes,
)
from .hlo_analysis import CollectiveStats, collective_bytes, parse_hlo_collectives

__all__ = [
    "EngineConfig",
    "CycleReport",
    "simulate_gemm",
    "simulate_gemm_cycle_accurate",
    "OPOPE_16x16_FP16",
    "ACCELERATORS",
    "AcceleratorModel",
    "ClusterConfig",
    "TilingPlan",
    "choose_tile",
    "tiled_gemm_cycles",
    "TPU_V5E",
    "HardwareSpec",
    "RooflineTerms",
    "roofline_terms",
    "model_flops",
    "dtype_width",
    "tensor_bytes",
    "gemm_bytes",
    "gemm_intensity",
    "CollectiveStats",
    "collective_bytes",
    "parse_hlo_collectives",
]
