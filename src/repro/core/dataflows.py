"""Cycle models of the state-of-the-art GEMM accelerators O-POPE compares to.

The paper (§III-D, Table II, Fig. 7) compares a 16x16 FP16 O-POPE against
Gemmini (weight-stationary systolic), RedMulE (input-stationary inner-product
rows) and Sauria (output-stationary systolic with explicit input buffering),
all configured with 256 FP16 MAC units in 12 nm.

These baselines were evaluated in the paper with vendor RTL simulation; here
each is modelled with a documented, calibrated cycle model that reproduces

* the published peak GFLOPS (Table II) — set by the per-design max frequency
  in 12 nm: O-POPE 1.0 GHz, RedMulE 0.75 GHz, Sauria 0.65 GHz, Gemmini
  0.55 GHz (peak = 2 * 256 * f), and
* the qualitative runtime ordering of Fig. 7 (O-POPE up to ~1.86x faster),
  driven by frequency * utilization under each dataflow's overheads.

The models are approximations of published microarchitectures, NOT RTL; they
are labelled as such everywhere they are reported.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict

from .engine import CycleReport, EngineConfig, simulate_gemm

__all__ = [
    "AcceleratorModel",
    "gemmini_ws_cycles",
    "redmule_cycles",
    "sauria_cycles",
    "opope_cycles",
    "ACCELERATORS",
]


@dataclasses.dataclass(frozen=True)
class AcceleratorModel:
    """A named cycle model with its max frequency in GF 12LP+."""

    name: str
    freq_ghz: float
    n_macs: int
    cycles: Callable[[int, int, int], CycleReport]

    @property
    def peak_gflops(self) -> float:
        return 2.0 * self.n_macs * self.freq_ghz

    def runtime_us(self, m: int, k: int, n: int) -> float:
        return self.cycles(m, k, n).total_cycles / (self.freq_ghz * 1e3)

    def utilization(self, m: int, k: int, n: int) -> float:
        return self.cycles(m, k, n).utilization


def _report(
    name: str, m: int, k: int, n: int, total: int, compute: int, freq: float
) -> CycleReport:
    cfg = EngineConfig(p=16, freq_ghz=freq, name=name)
    return CycleReport(
        m=m,
        k=k,
        n=n,
        total_cycles=total,
        compute_cycles=compute,
        stall_cycles=max(0, total - compute),
        prologue_cycles=0,
        epilogue_cycles=0,
        useful_macs=m * k * n,
        n_tiles=math.ceil(m / 16) * math.ceil(n / 16),
        engine=cfg,
    )


def gemmini_ws_cycles(m: int, k: int, n: int, dim: int = 16) -> CycleReport:
    """Gemmini weight-stationary systolic array (Genc et al., DAC'21).

    For each (K-tile, N-tile) weight block of ``dim x dim``: weights preload
    double-buffered behind the previous pass; activation rows stream with the
    wavefronts of consecutive passes overlapped, so a pass costs ``m`` plus a
    small inter-pass bubble; the skew fill/drain is paid once per call.
    Gemmini's published utilization on large GEMMs is ~90+% — the runtime gap
    to O-POPE is dominated by its 0.55 GHz ceiling (the paper's thesis).
    """
    kt = math.ceil(k / dim)
    nt = math.ceil(n / dim)
    per_pass = m + dim // 4  # stream M rows + inter-pass bubble
    total = 80 + kt * nt * per_pass + 2 * dim  # skew fill + final drain
    compute = kt * nt * m
    return _report("gemmini-ws", m, k, n, total, compute, 0.55)


def redmule_cycles(
    m: int, k: int, n: int, h: int = 16, w: int = 16, pipe: int = 3
) -> CycleReport:
    """RedMulE input-stationary inner-product engine (Tortorella et al., FGCS'23).

    The H x W CE array computes H output rows over W-chained FMAs; the K
    dimension is consumed in chunks of ``w * (pipe + 1)`` elements and the
    input buffering (which scales with #FPUs x pipeline depth — the overhead
    O-POPE eliminates) refills with a bubble of ``w`` cycles per K chunk at
    tile boundaries. M quantizes to H, N to W.
    """
    kc = w * (pipe + 1)  # K chunk absorbed per accumulation pass
    mt = math.ceil(m / h)
    nt = math.ceil(n / w)
    kt = math.ceil(k / kc)
    per_tile = kt * (kc + w // 4)  # chunk compute + refill bubble
    total = 60 + mt * nt * per_tile + h  # 60: HWPE config; h: first fill
    compute = mt * nt * kt * kc
    return _report("redmule", m, k, n, total, compute, 0.75)


def sauria_cycles(m: int, k: int, n: int, dim: int = 16) -> CycleReport:
    """Sauria output-stationary systolic array (Fornt et al., TVLSI'23).

    Output tile of ``dim x dim`` stays in the array; A/B stream through with a
    skewed wavefront: per tile ``K + 2*dim`` cycles (fill + drain), plus an
    explicit output drain of ``dim`` cycles per tile that is only partially
    overlapped (the paper's motivation: limited FPU pipelining caps frequency
    at 0.65 GHz in 12 nm rather than costing utilization).
    """
    mt = math.ceil(m / dim)
    nt = math.ceil(n / dim)
    per_tile = k + dim + dim // 2  # K stream + skew fill + partially-hidden drain
    total = 60 + mt * nt * per_tile
    compute = mt * nt * k
    return _report("sauria", m, k, n, total, compute, 0.65)


def opope_cycles(m: int, k: int, n: int, p: int = 16) -> CycleReport:
    """O-POPE at 1 GHz (the paper's engine; see :mod:`repro.core.engine`)."""
    return simulate_gemm(EngineConfig(p=p, freq_ghz=1.0, name="o-pope"), m, k, n)


ACCELERATORS: Dict[str, AcceleratorModel] = {
    "o-pope": AcceleratorModel("o-pope", 1.0, 256, opope_cycles),
    "redmule": AcceleratorModel("redmule", 0.75, 256, redmule_cycles),
    "sauria": AcceleratorModel("sauria", 0.65, 256, sauria_cycles),
    "gemmini": AcceleratorModel("gemmini", 0.55, 256, gemmini_ws_cycles),
}
