"""Cycle-accurate model of the O-POPE engine.

This module reproduces the paper's §III-C runtime analysis. Two models are
provided and cross-validated against each other in the test suite:

* :func:`simulate_gemm` — an exact closed-form tile-sequence model derived from
  the dataflow in §II (Fig. 1c/1d, Fig. 3). Fast; used everywhere.
* :func:`simulate_gemm_cycle_accurate` — a literal per-cycle streamer/engine
  state machine implementing the same published schedule. Slow; used on small
  GEMMs to validate the closed form (hypothesis property tests).

The dataflow being modelled
---------------------------

An O-POPE instance is a ``p x p`` mesh of PEs. Each PE contains one FMA whose
pipeline has ``L`` stages (paper default L=4) plus ``L`` accumulator registers.
The ``L`` pipeline slots carry ``L`` *independent* accumulation chains, i.e. a
``rm x rn`` output sub-tile per PE with ``rm*rn == L`` (2x2 for L=4), so the
engine's output-stationary C tile is ``(rm*p) x (rn*p)`` (``2p x 2p``).

Per ``L``-cycle group the engine consumes one A vector and one B vector of
``r*p`` elements each (each element reused ``r`` times) and performs one rank-1
update of the full C tile: ``L*p^2`` MACs in ``L`` cycles = ``p^2`` MACs/cycle.
A C tile therefore takes ``L*K`` cycles of compute for ``K`` rank-1 updates.

The streamer moves ``2p`` elements/cycle total. While computing, A+B consume
one ``2p``-element vector every 2 cycles (50% of bandwidth, §II-C); the other
50% (``p`` elems/cycle) moves the output-stationary tile: storing the previous
tile's results and preloading the next tile's initial C values. Hiding the
``2 * (2p)^2`` swap elements under ``L*K`` compute cycles requires
``L*K >= 8p^2/p = 8p``, i.e. ``K >= 2p`` — the paper's utilization condition.

Stalls occur only (a) during the first tile's accumulator preload (C share of
bandwidth = ``p`` elems/cycle → ``4p`` cycles for a full ``4p^2``-element tile),
(b) during the last tile's writeback (dedicated ``2p`` elems/cycle → ``2p``
cycles), and (c) for controller programming (``cfg_cycles``). With the default
``cfg_cycles=15`` the model lands exactly on the paper's headline number:
``64x256x128`` on a 4x4 mesh → ``131072 / (15+16+131072+8) = 99.970%``.

Partial tiles (M or N not a multiple of ``r*p``) still pay the full ``L*K``
compute cycles — the pipeline must rotate through all ``L`` accumulator slots —
which is precisely the paper's tile-quantization utilization loss (§III-C).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

__all__ = [
    "EngineConfig",
    "CycleReport",
    "simulate_gemm",
    "simulate_gemm_cycle_accurate",
    "tile_grid",
    "OPOPE_16x16_FP16",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Design-time parameters of an O-POPE instance (paper §II)."""

    p: int = 16  # mesh side: p x p PEs (power of two in the paper)
    pipe_depth: int = 4  # L: FPU pipeline stages == accumulator registers / PE
    elem_bits: int = 16  # q: operand width (FP16 default)
    acc_bits: int = 16  # accumulator width (q; 2q for widening MACs)
    freq_ghz: float = 1.0  # paper: 1 GHz @ 0.72 V, GF 12LP+
    cfg_cycles: int = 15  # controller/streamer programming overhead per call
    name: str = "opope"

    def __post_init__(self) -> None:
        if self.p < 1:
            raise ValueError(f"mesh side must be >= 1, got {self.p}")
        r = math.isqrt(self.pipe_depth)
        if r * r != self.pipe_depth:
            raise ValueError(
                f"pipe_depth must be a perfect square (rm*rn sub-tile), got "
                f"{self.pipe_depth}"
            )

    # --- derived quantities -------------------------------------------------
    @property
    def r(self) -> int:
        """Per-PE sub-tile side (2 for L=4)."""
        return math.isqrt(self.pipe_depth)

    @property
    def tile_m(self) -> int:
        """Output-stationary C tile rows (2p for L=4)."""
        return self.r * self.p

    @property
    def tile_n(self) -> int:
        return self.r * self.p

    @property
    def n_macs(self) -> int:
        """MAC units == p^2 (one FPU per PE)."""
        return self.p * self.p

    @property
    def streamer_elems_per_cycle(self) -> int:
        """Total streamer bandwidth in elements/cycle (2p x q bits, §II-C)."""
        return 2 * self.p

    @property
    def c_elems_per_cycle_overlapped(self) -> int:
        """C-tile movement bandwidth while A/B streams run (50%, §II-C)."""
        return self.p

    @property
    def peak_gflops(self) -> float:
        """Peak GFLOPS at the configured frequency (2 flops per MAC)."""
        return 2.0 * self.n_macs * self.freq_ghz

    @property
    def input_buffer_bits(self) -> int:
        """Two (2p x q)-bit input vector buffers (§II-B): sqrt(#PE) scaling."""
        return 2 * (2 * self.p * self.elem_bits)

    @property
    def accumulator_bits(self) -> int:
        """L accumulator registers per PE (§II-A)."""
        return self.n_macs * self.pipe_depth * self.acc_bits


@dataclasses.dataclass(frozen=True)
class CycleReport:
    """Result of a GEMM simulation on one engine configuration."""

    m: int
    k: int
    n: int
    total_cycles: int
    compute_cycles: int  # sum over tiles of L*K (includes quantization waste)
    stall_cycles: int  # C-swap stalls not hidden under compute
    prologue_cycles: int  # cfg + first-tile accumulator preload
    epilogue_cycles: int  # last-tile writeback
    useful_macs: int  # M*K*N
    n_tiles: int
    engine: EngineConfig

    @property
    def ideal_cycles(self) -> float:
        return self.useful_macs / self.engine.n_macs

    @property
    def utilization(self) -> float:
        """FPU utilization: useful MAC-cycles / available FPU-cycles."""
        return self.useful_macs / (self.engine.n_macs * self.total_cycles)

    @property
    def runtime_us(self) -> float:
        return self.total_cycles / (self.engine.freq_ghz * 1e3)

    @property
    def achieved_gflops(self) -> float:
        return 2.0 * self.useful_macs / (self.total_cycles / self.engine.freq_ghz)

    def breakdown(self) -> Dict[str, int]:
        return {
            "total": self.total_cycles,
            "compute": self.compute_cycles,
            "stall": self.stall_cycles,
            "prologue": self.prologue_cycles,
            "epilogue": self.epilogue_cycles,
        }


def tile_grid(cfg: EngineConfig, m: int, n: int) -> List[Tuple[int, int]]:
    """Row-major sequence of (tile_rows, tile_cols) C tiles for an M x N output.

    Partial edge tiles carry their true element counts (for C movement) even
    though they cost a full ``L*K`` compute cycles.
    """
    tiles: List[Tuple[int, int]] = []
    for i0 in range(0, m, cfg.tile_m):
        tm = min(cfg.tile_m, m - i0)
        for j0 in range(0, n, cfg.tile_n):
            tn = min(cfg.tile_n, n - j0)
            tiles.append((tm, tn))
    return tiles


def simulate_gemm(cfg: EngineConfig, m: int, k: int, n: int) -> CycleReport:
    """Closed-form cycle count for ``C[m,n] (+)= A[m,k] @ B[k,n]`` on O-POPE.

    Exact under the published schedule (see module docstring): per-tile compute
    of ``L*K`` cycles; the streamer stores tile ``j-1`` and preloads tile
    ``j+1`` during tile ``j``'s compute window at ``p`` elements/cycle, adding
    a stall whenever that movement does not fit.
    """
    if min(m, k, n) < 1:
        raise ValueError(f"GEMM dims must be positive, got {(m, k, n)}")
    tiles = tile_grid(cfg, m, n)
    n_tiles = len(tiles)
    L = cfg.pipe_depth
    per_tile_compute = L * k
    c_bw = cfg.c_elems_per_cycle_overlapped

    prologue = cfg.cfg_cycles + math.ceil(tiles[0][0] * tiles[0][1] / c_bw)
    compute = 0
    stall = 0
    for j in range(n_tiles):
        # C movement overlapped with tile j's compute window:
        work_elems = 0
        if j >= 1:
            work_elems += tiles[j - 1][0] * tiles[j - 1][1]  # store previous
        if j + 1 < n_tiles:
            work_elems += tiles[j + 1][0] * tiles[j + 1][1]  # preload next
        move_cycles = math.ceil(work_elems / c_bw)
        compute += per_tile_compute
        stall += max(0, move_cycles - per_tile_compute)
    # Last tile writeback at the full dedicated C bandwidth (no A/B traffic).
    epilogue = math.ceil(
        tiles[-1][0] * tiles[-1][1] / cfg.streamer_elems_per_cycle
    )

    total = prologue + compute + stall + epilogue
    return CycleReport(
        m=m,
        k=k,
        n=n,
        total_cycles=total,
        compute_cycles=compute,
        stall_cycles=stall,
        prologue_cycles=prologue,
        epilogue_cycles=epilogue,
        useful_macs=m * k * n,
        n_tiles=n_tiles,
        engine=cfg,
    )


def simulate_gemm_cycle_accurate(
    cfg: EngineConfig, m: int, k: int, n: int
) -> CycleReport:
    """Literal per-cycle streamer/engine state machine (validation model).

    Implements the same published schedule as :func:`simulate_gemm` but by
    stepping individual cycles and streamer vector slots:

    * the streamer issues one ``2p``-element vector per cycle;
    * while a tile computes, every L-cycle group reserves 2 slots for the A and
      B vectors of the next rank-1 update; remaining slots go to C movement
      (store of the previous tile, then preload of the next tile);
    * a tile may begin computing only after its initial C values are fully
      preloaded into the accumulator registers (tile 0) or after the previous
      tile's compute finished (accumulator swap is a single-cycle couple/
      decouple, Fig. 2);
    * if the next tile's preload has not finished when the accumulators swap,
      the engine stalls until it has.

    O(total_cycles) in Python — use for small GEMMs only.
    """
    tiles = tile_grid(cfg, m, n)
    n_tiles = len(tiles)
    L = cfg.pipe_depth
    c_bw = cfg.c_elems_per_cycle_overlapped  # p elems/cycle while computing

    t = cfg.cfg_cycles
    # --- first tile preload: interleaved A/B + 2xC vector groups -> C moves
    # at p elems/cycle (2 of 4 slots per L-cycle group, Fig. 3).
    first_elems = tiles[0][0] * tiles[0][1]
    t += math.ceil(first_elems / c_bw)

    store_backlog = 0  # elements of the *previous* tile awaiting store
    preload_done_elems = 0  # elements of the *next* tile already preloaded
    stall = 0
    compute = 0
    for j in range(n_tiles):
        next_elems = tiles[j + 1][0] * tiles[j + 1][1] if j + 1 < n_tiles else 0
        # Compute window: L*k cycles; each cycle the streamer moves up to
        # c_bw C-elements (store backlog first, then preload of tile j+1 —
        # both share the accumulator registers, so stores must drain first).
        for _ in range(L * k):
            t += 1
            compute += 1
            budget = c_bw
            s = min(store_backlog, budget)
            store_backlog -= s
            budget -= s
            preload_done_elems = min(next_elems, preload_done_elems + budget)
        # Accumulator swap (Fig. 2): before tile j's results can enter the
        # accumulator registers, tile j-1's results must be fully drained and
        # tile j+1's initial values fully preloaded. Stall otherwise.
        while store_backlog > 0 or preload_done_elems < next_elems:
            t += 1
            stall += 1
            budget = c_bw
            s = min(store_backlog, budget)
            store_backlog -= s
            budget -= s
            preload_done_elems = min(next_elems, preload_done_elems + budget)
        store_backlog = tiles[j][0] * tiles[j][1]
        preload_done_elems = 0
    # Epilogue: drain the last tile at full streamer bandwidth.
    epi = math.ceil(store_backlog / cfg.streamer_elems_per_cycle)
    t += epi

    return CycleReport(
        m=m,
        k=k,
        n=n,
        total_cycles=t,
        compute_cycles=compute,
        stall_cycles=stall,
        prologue_cycles=cfg.cfg_cycles + math.ceil(first_elems / c_bw),
        epilogue_cycles=epi,
        useful_macs=m * k * n,
        n_tiles=n_tiles,
        engine=cfg,
    )


# The configuration evaluated head-to-head in the paper's Table II.
OPOPE_16x16_FP16 = EngineConfig(p=16, pipe_depth=4, elem_bits=16, name="o-pope")
