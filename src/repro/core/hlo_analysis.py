"""Parse compiled HLO text for roofline inputs.

``compiled.cost_analysis()`` supplies per-device FLOPs and bytes, but XLA does
not report collective traffic there. This module extracts it from
``compiled.as_text()`` (post-SPMD, so all quantities are per device): every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction is located and its operand/result sizes
summed.

Wire-byte convention (documented for the roofline): for each collective we
take ``max(bytes_in, bytes_out)`` of the instruction as its traffic. This is
the standard single-shot lower bound — e.g. an all-gather moves its (larger)
output, a reduce-scatter its (larger) input, an all-reduce its full buffer
(ring algorithms move ~2x; we report the multiplier-free bound and note it).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List

__all__ = ["CollectiveStats", "collective_bytes", "parse_hlo_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")\[([0-9,]*)\]"
)
_COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# e.g.  %ar = (f32[128]) all-reduce(f32[128] %x), replica_groups=...
_INSTR_RE = re.compile(
    r"=\s*[^=]*?\b(" + "|".join(_COLLECTIVE_KINDS) + r")(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective traffic of one compiled module (per device)."""

    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    instructions: List[str]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> Dict[str, object]:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "by_kind": {
                k: {"bytes": self.bytes_by_kind[k], "count": self.count_by_kind[k]}
                for k in sorted(self.bytes_by_kind)
            },
        }


def parse_hlo_collectives(hlo_text: str) -> CollectiveStats:
    """Scan HLO text and accumulate collective bytes per op kind.

    ``-start``/``-done`` async pairs are counted once (on the ``-start``; a
    bare ``-done`` with no matching start, as appears for decomposed ops, is
    counted on the done).
    """
    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    instructions: List[str] = []
    for line in hlo_text.splitlines():
        if "-done(" in line:  # completion of an async op counted at its start
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        lhs, _, rhs = line.partition("=")
        out_bytes = _shape_bytes(rhs.split(kind, 1)[1].split("),", 1)[0]) or 0
        # Result shape sits between '=' and the op name.
        res_bytes = _shape_bytes(rhs.split(kind, 1)[0])
        wire = max(out_bytes, res_bytes)
        bytes_by_kind[kind] += wire
        count_by_kind[kind] += 1
        instructions.append(line.strip()[:200])
    return CollectiveStats(bytes_by_kind, count_by_kind, instructions)


def collective_bytes(hlo_text: str) -> int:
    return parse_hlo_collectives(hlo_text).total_bytes
