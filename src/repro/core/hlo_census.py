"""Loop-aware census of a compiled HLO module: FLOPs, HBM bytes, collectives.

``compiled.cost_analysis()`` counts each while-loop body ONCE — useless for
scan-over-layers models (a 64-layer model reports 1/64 of its FLOPs). This
module re-derives the three roofline inputs directly from
``compiled.as_text()`` (post-SPMD, post-fusion, scheduled), multiplying every
computation by its loop trip count, which XLA conveniently embeds as
``backend_config={"known_trip_count":{"n":"N"}}`` on each ``while`` op.

Accounting rules (documented for §Roofline):

* **FLOPs** — every ``dot`` contributes ``2 * prod(result_dims) *
  prod(lhs_contracting_dim_sizes)``; dots inside fusions are found by
  recursing through ``calls=``. Elementwise ops are ignored (noise next to
  the GEMMs; the validation test below bounds the error).
* **HBM bytes** — per instruction: result bytes + operand bytes, where the
  instruction set is post-fusion, so fusion operands/results approximate
  buffer-level traffic. Pure plumbing (parameter / tuple / get-tuple-element
  / bitcast / constant) is skipped. This is an upper-ish bound: XLA may keep
  some buffers in registers/cache across instructions.
* **Collectives** — operand/result bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute ops (single-shot
  ``max(in, out)`` convention; ring algorithms move up to 2x).
* **Loops** — ``census(entry) = sum(instr) + trip_count * census(body)`` per
  ``while``; nested loops multiply.

Everything is per device (the module is the post-partitioning per-device
program). Validated in tests against analytic FLOP counts of known GEMM
stacks (exact) and against ``cost_analysis`` on loop-free modules.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCensus", "census_hlo", "elementwise_passes", "EXEMPT_SCOPES"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")\[([0-9,]*)\]"
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPNAME = re.compile(r"^(?:\(|\w|\[|\]|,|\{|\}|/|\.|:|\s)*?\s*([a-z][\w\-]*)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id", "opt-barrier",
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _dims(dim_str: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in dim_str.split(",") if d) if dim_str else ()


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_text: str
    rest: str
    line: str


@dataclasses.dataclass
class HloCensus:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    collective_count: int
    n_while: int
    max_trip: int

    def summary(self) -> Dict[str, object]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_count": self.collective_count,
            "n_while": self.n_while,
            "max_trip": self.max_trip,
        }


def _parse_computations(text: str) -> Tuple[Dict[str, List[_Instr]], Optional[str]]:
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if line.strip().startswith("ENTRY"):
                        entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parsed = _split_rhs(rhs)
        if parsed is None:
            continue
        result_text, op, args = parsed
        comps[cur].append(_Instr(name, op, result_text, args, line))
    return comps, entry


def _split_rhs(rhs: str):
    """Split '<result-type> <op>(<args>)...' handling tuple result types."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        result_text = rhs[: end + 1]
        rest = rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        result_text = rhs[:sp]
        rest = rhs[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)", rest)
    if not m:
        return None
    op = m.group(1)
    return result_text, op, rest[len(op):]


def _op_args_span(args: str) -> str:
    """The operand list of the op: text inside its first balanced parens."""
    start = args.find("(")
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(args)):
        if args[i] == "(":
            depth += 1
        elif args[i] == ")":
            depth -= 1
            if depth == 0:
                return args[start + 1 : i]
    return args[start + 1 :]


def _dot_flops(inst: _Instr, shape_of: Dict[str, str]) -> float:
    res_bytes_dims = _SHAPE_RE.findall(inst.result_text)
    if not res_bytes_dims:
        return 0.0
    result_elems = 1
    for d in _dims(res_bytes_dims[0][1]):
        result_elems *= d
    m = _CONTRACT.search(inst.line)
    contract_idx = _dims(m.group(1)) if m else ()
    ops = _OPERANDS.findall(_op_args_span(inst.rest))
    lhs_shape_txt = shape_of.get(ops[0], "") if ops else ""
    lhs = _SHAPE_RE.findall(lhs_shape_txt)
    csize = 1
    if lhs:
        ldims = _dims(lhs[0][1])
        for i in contract_idx:
            if i < len(ldims):
                csize *= ldims[i]
    return 2.0 * result_elems * csize


def census_hlo(text: str) -> HloCensus:
    comps, entry = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    # Per-computation symbol tables: instruction name -> result shape text.
    shape_of: Dict[str, Dict[str, str]] = {}
    for cname, instrs in comps.items():
        tab: Dict[str, str] = {}
        for inst in instrs:
            tab[inst.name] = inst.result_text or inst.line.split("=", 1)[-1]
            if inst.op == "parameter":
                tab[inst.name] = inst.result_text
        shape_of[cname] = tab

    # Trip counts: from the while instruction's backend_config.
    memo: Dict[str, Tuple[float, float, float, Dict[str, float], int, int, int]] = {}

    def walk(cname: str):
        if cname in memo:
            return memo[cname]
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        coll_kind: Dict[str, float] = {}
        coll_n = 0
        n_while = 0
        max_trip = 1
        tab = shape_of.get(cname, {})
        for inst in comps.get(cname, []):
            op = inst.op
            if op == "while":
                m = _TRIP.search(inst.line)
                trip = int(m.group(1)) if m else 1
                bm = _BODY.search(inst.line)
                if bm:
                    bf, bh, bc, bk, bn, bw, bt = walk(bm.group(1))
                    flops += trip * bf
                    hbm += trip * bh
                    coll += trip * bc
                    for k, v in bk.items():
                        coll_kind[k] = coll_kind.get(k, 0.0) + trip * v
                    coll_n += trip * bn
                    n_while += 1 + bw
                    max_trip = max(max_trip, trip, bt)
                continue
            base_op = op.replace("-start", "").replace("-done", "")
            if base_op in _COLLECTIVES and not op.endswith("-done"):
                in_bytes = sum(
                    _shapes_bytes(tab.get(o, ""))
                    for o in _OPERANDS.findall(_op_args_span(inst.rest))
                )
                out_bytes = _shapes_bytes(inst.result_text)
                wire = max(in_bytes, out_bytes)
                coll += wire
                coll_kind[base_op] = coll_kind.get(base_op, 0.0) + wire
                coll_n += 1
                hbm += wire  # collectives also read/write HBM
                continue
            if op == "dot":
                flops += _dot_flops(inst, tab)
            cm = _CALLS.search(inst.line)
            if cm and cm.group(1) in comps:
                cf, ch, cc, ck, cn, cw, ct = walk(cm.group(1))
                flops += cf  # dots inside fusions
                coll += cc
                for k, v in ck.items():
                    coll_kind[k] = coll_kind.get(k, 0.0) + v
                coll_n += cn
                n_while += cw
                max_trip = max(max_trip, ct)
                # bytes: use the fusion instruction's own operands/result.
            if op not in _SKIP_BYTES:
                in_bytes = sum(
                    _shapes_bytes(tab.get(o, ""))
                    for o in _OPERANDS.findall(_op_args_span(inst.rest))
                )
                hbm += in_bytes + _shapes_bytes(inst.result_text)
        memo[cname] = (flops, hbm, coll, coll_kind, coll_n, n_while, max_trip)
        return memo[cname]

    f, h, c, ck, cn, nw, mt = walk(entry)
    return HloCensus(
        flops=f,
        hbm_bytes=h,
        collective_bytes=c,
        collective_by_kind=ck,
        collective_count=cn,
        n_while=nw,
        max_trip=mt,
    )


# ---------------------------------------------------------------------------
# elementwise-pass census (the fused-epilogue acceptance metric)
# ---------------------------------------------------------------------------

# HLO opcodes that are elementwise *compute* — the ops a standalone
# activation / residual / scale pass over a GEMM output would lower to.
# Data-movement and dtype ops (convert, copy, broadcast, reshape, slice, ...)
# are deliberately absent: the epilogue contract allows exactly one final
# cast, and layout ops don't re-read the tensor for math.
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "remainder", "power",
    "maximum", "minimum", "clamp", "select",
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "tanh", "sqrt", "rsqrt", "cbrt",
    "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even",
    "and", "or", "xor", "not", "compare",
    "erf", "atan2", "sine", "cosine", "tan",
}

_OP_NAME_META = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')

# The named scopes whose elementwise math is *legitimately* standalone —
# reduction-coupled (softmax/norm stats need the whole row) or
# position-dependent (rope), plus the epilogue lane itself (its ops sit at
# the GEMM writeback, or — post-hoc lane — form the single fused pass the
# registry guarantees). Everything else touching a GEMM-sized tensor is a
# missed fusion.
EXEMPT_SCOPES = ("opope_epilogue", "norm", "rope", "attn_core")


def elementwise_passes(
    text: str,
    *,
    min_elems: int = 1024,
    exempt_scopes: Tuple[str, ...] = EXEMPT_SCOPES,
) -> List[Dict[str, object]]:
    """Standalone elementwise-compute instructions over big tensors.

    Scans the post-fusion module (entry + while bodies + non-GEMM fusions)
    and reports every elementwise-compute instruction whose result has at
    least ``min_elems`` elements and whose ``op_name`` metadata path does not
    pass through one of ``exempt_scopes``. Fusion computations containing a
    ``dot`` are skipped wholesale — elementwise ops XLA already fused into a
    GEMM are not standalone passes. The hot-path acceptance criterion for the
    fused-epilogue refactor is ``len(...) == 0`` on a decode step
    (tests/test_epilogue.py keeps it that way).

    Each finding is a dict with ``computation`` / ``instruction`` / ``op`` /
    ``elems`` / ``op_name`` keys — enough to locate the missed fusion in the
    module text.
    """
    comps, entry = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    def _result_elems(inst: _Instr) -> int:
        found = _SHAPE_RE.findall(inst.result_text)
        if not found:
            return 0
        n = 1
        for d in _dims(found[0][1]):
            n *= d
        return n

    def _exempt(inst: _Instr) -> bool:
        m = _OP_NAME_META.search(inst.line)
        if not m:
            return False
        parts = m.group(1).split("/")
        return any(s in parts for s in exempt_scopes)

    findings: List[Dict[str, object]] = []
    seen: set = set()

    def walk(cname: str, fused: bool = False) -> None:
        if cname in seen:
            return
        seen.add(cname)
        instrs = comps.get(cname, [])
        if fused and any(i.op == "dot" for i in instrs):
            return  # GEMM fusion: its elementwise ops are already fused
        for inst in instrs:
            if inst.op == "while":
                bm = _BODY.search(inst.line)
                if bm:
                    walk(bm.group(1))
                continue
            cm = _CALLS.search(inst.line)
            if cm and cm.group(1) in comps:
                walk(cm.group(1), fused=True)
                continue
            if inst.op not in _ELEMENTWISE_OPS:
                continue
            elems = _result_elems(inst)
            if elems < min_elems or _exempt(inst):
                continue
            m = _OP_NAME_META.search(inst.line)
            findings.append(
                {
                    "computation": cname,
                    "instruction": inst.name,
                    "op": inst.op,
                    "elems": elems,
                    "op_name": m.group(1) if m else "",
                }
            )

    walk(entry)
    return findings
