"""Three-term roofline model for the dry-run artifacts (TPU v5e target).

Per the assignment brief, for each (architecture x shape x mesh) cell we
derive from the compiled module (all inputs per device, post-SPMD):

* compute term    = HLO_FLOPs / peak_FLOPs_per_chip
* memory term     = HLO_bytes / HBM_bandwidth_per_chip
* collective term = collective_bytes / ICI_link_bandwidth

(The brief's formulas divide totals by ``chips x per-chip-rate``; XLA's
``cost_analysis`` is already per device, so the division by chip count has
already happened.)

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "RooflineTerms",
    "roofline_terms",
    "model_flops",
    "dtype_width",
    "tensor_bytes",
    "gemm_bytes",
    "gemm_intensity",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # FLOP/s per chip (bf16)
    hbm_bw: float  # bytes/s per chip
    ici_link_bw: float  # bytes/s per link


TPU_V5E = HardwareSpec(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_link_bw=50e9
)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Roofline seconds per term for one compiled step (per device)."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    hw: HardwareSpec
    model_flops_per_device: Optional[float] = None  # 6*N*D / chips

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline-model step time: the max of the three terms (full overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline step time.

        ``model_flops / peak`` over the bound: 1.0 means every roofline-limited
        second does useful model math at peak. This is the reported perf score.
        """
        if not self.model_flops_per_device:
            return self.compute_s / self.bound_s if self.bound_s else 0.0
        return (self.model_flops_per_device / self.hw.peak_flops) / self.bound_s

    @property
    def useful_compute_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/dispatch/padding waste."""
        if not self.model_flops_per_device or not self.flops_per_device:
            return float("nan")
        return self.model_flops_per_device / self.flops_per_device

    def summary(self) -> Dict[str, object]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "roofline_fraction": self.roofline_fraction,
            "useful_compute_ratio": self.useful_compute_ratio,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
        }


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    hw: HardwareSpec = TPU_V5E,
    model_flops_total: Optional[float] = None,
    n_chips: Optional[int] = None,
) -> RooflineTerms:
    model_per_dev = None
    if model_flops_total is not None and n_chips:
        model_per_dev = model_flops_total / n_chips
    return RooflineTerms(
        compute_s=flops_per_device / hw.peak_flops,
        memory_s=bytes_per_device / hw.hbm_bw,
        collective_s=collective_bytes_per_device / hw.ici_link_bw,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes_per_device=collective_bytes_per_device,
        hw=hw,
        model_flops_per_device=model_per_dev,
    )


# ---------------------------------------------------------------------------
# Dtype-aware byte accounting
# ---------------------------------------------------------------------------
#
# Every byte term derives its operand width from the ACTUAL dtype — never an
# assumed 4-byte word. With the mixed-precision subsystem a GEMM can stream
# int8 A/B panels against an fp32 C and a bf16 output in one call; assuming
# one width would overstate quantized traffic ~4x and make the reported
# arithmetic intensity (and therefore the memory roofline term) meaningless.

# Widths for string dtype names that numpy may not know without ml_dtypes.
_NAMED_WIDTHS = {
    "int8": 1, "uint8": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "float8_e4m3": 1,
    "float8_e4m3fnuz": 1, "float8_e5m2fnuz": 1,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "float32": 4, "int32": 4, "uint32": 4,
    "float64": 8, "int64": 8, "uint64": 8,
}


def dtype_width(dtype) -> int:
    """Bytes per element of ``dtype`` (a dtype object, array dtype, or name)."""
    itemsize = getattr(dtype, "itemsize", None)
    if itemsize:
        return int(itemsize)
    name = str(getattr(dtype, "name", dtype))
    if name in _NAMED_WIDTHS:
        return _NAMED_WIDTHS[name]
    import numpy as np

    return int(np.dtype(dtype).itemsize)


def tensor_bytes(*arrays) -> int:
    """Total bytes of arrays (or ShapeDtypeStructs) at their ACTUAL dtypes."""
    total = 0
    for a in arrays:
        if a is None:
            continue
        size = getattr(a, "size", None)
        if size is None:
            size = 1
            for d in a.shape:
                size *= d
        total += int(size) * dtype_width(a.dtype)
    return total


def gemm_bytes(
    m: int,
    k: int,
    n: int,
    *,
    a_dtype,
    b_dtype=None,
    out_dtype=None,
    c_dtype=None,
    scale_elems: int = 0,
) -> int:
    """Minimal HBM traffic of one ``[M,K] @ [K,N] (+C) -> [M,N]`` GEMM:
    each operand read once, the output written once, each at its own width.

    ``scale_elems`` adds fp32 side-band elements (quantization scales —
    ``M + N`` for the per-row/per-channel q8 backends).
    """
    a_w = dtype_width(a_dtype)
    b_w = dtype_width(b_dtype if b_dtype is not None else a_dtype)
    o_w = dtype_width(out_dtype if out_dtype is not None else a_dtype)
    total = m * k * a_w + k * n * b_w + m * n * o_w
    if c_dtype is not None:
        total += m * n * dtype_width(c_dtype)
    return total + 4 * scale_elems


def gemm_intensity(m: int, k: int, n: int, **dtype_kw) -> float:
    """Arithmetic intensity (FLOPs/byte) of the GEMM at honest widths."""
    return (2.0 * m * k * n) / gemm_bytes(m, k, n, **dtype_kw)


def model_flops(
    n_params: int,
    tokens: int,
    *,
    kind: str = "train",
    n_params_active: Optional[int] = None,
) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference fwd), N = active params.

    For MoE models pass ``n_params_active`` (shared + routed*top_k experts plus
    dense layers); for decode shapes ``tokens`` is the global batch (one token
    per sequence per step).
    """
    n = n_params_active if n_params_active is not None else n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
