"""Published PPA constants and the Table II / Fig. 5 analytical models.

Area, frequency and power are silicon properties that cannot be measured in
this container; the paper's published numbers (Table II, §III-B) are encoded
here as constants, and an analytical area/power model — calibrated to them —
reproduces the scaling claims of Fig. 5:

* linear area scaling across 4x4 → 32x32 meshes with geomean area ratio
  between quadrupled-MAC configs in [3.27x, 3.79x] (buffers scale with sqrt of
  MACs, so the ratio is < 4x),
* input-buffer area share dropping below 2% at 32x32,
* Table II: O-POPE 512 GFLOPS / 2336 GFLOPS/mm2 / 3.18 TFLOPS/W, vs RedMulE
  384 / 2134 / 2.74, Sauria 333 / 1036 / 2.95, Gemmini 280 / 749 / (n.r.).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .engine import EngineConfig

__all__ = [
    "PUBLISHED_TABLE2",
    "MAC_AREA_UM2",
    "area_model_mm2",
    "buffer_share",
    "power_model_w",
    "table2_model",
]

# --- Published Table II (16x16 FP16->FP16 MAC configs, GF 12LP+) -----------
# name -> (GFLOPS, GFLOPS/mm2, TFLOPS/W or None)
PUBLISHED_TABLE2: Dict[str, tuple] = {
    "gemmini": (280.0, 749.0, None),
    "redmule": (384.0, 2134.0, 2.74),
    "sauria": (333.0, 1036.0, 2.95),  # technology-scaled per DeepScaleTool
    "o-pope": (512.0, 2336.0, 3.18),
}

# --- Analytical area model ---------------------------------------------------
# Calibrated so that a 16x16 FP16 O-POPE lands on 512/2336 = 0.2192 mm2.
# Per-PE area includes the FPnew MAC, its L pipeline registers, and the L
# q-bit accumulator registers (§II-A). Relative MAC-kind factors follow the
# FPnew area ratios reported across its instantiations.
MAC_AREA_UM2: Dict[str, float] = {
    "fp8_to_fp16": 620.0,  # 2x-widening small MAC
    "fp16": 800.0,  # same-precision FP16 (Table II configuration)
    "fp16_to_fp32": 1520.0,  # widening accumulation
    "fp32": 2880.0,
    "fp8_to_fp16+fp16": 1210.0,  # combined-support units (Fig. 5a)
    "fp16_to_fp32+fp32": 3740.0,
}
_FLOP_AREA_UM2_PER_BIT = 2.9  # 12 nm register area (buffers + accumulators)
_CTRL_BASE_MM2 = 0.004  # controller + streamer FSM
_CTRL_PER_P_MM2 = 0.0003  # address generators grow with vector width


def area_model_mm2(cfg: EngineConfig, mac_kind: str = "fp16") -> Dict[str, float]:
    """Post-synthesis area estimate (mm^2) broken down per Fig. 5b."""
    pe = cfg.n_macs * MAC_AREA_UM2[mac_kind] * 1e-6
    buffers = cfg.input_buffer_bits * _FLOP_AREA_UM2_PER_BIT * 1e-6
    ctrl = _CTRL_BASE_MM2 + _CTRL_PER_P_MM2 * cfg.p
    return {
        "pe_array": pe,
        "input_buffers": buffers,
        "control": ctrl,
        "total": pe + buffers + ctrl,
    }


def buffer_share(cfg: EngineConfig, mac_kind: str = "fp16") -> float:
    a = area_model_mm2(cfg, mac_kind)
    return a["input_buffers"] / a["total"]


# --- Analytical power model --------------------------------------------------
# Calibrated to Table II: 512 GFLOPS / 3.18 TFLOPS/W -> 161 mW at TT 0.8 V.
_E_MAC_PJ = 0.55  # energy per FP16 MAC incl. local movement
_P_LEAK_PER_MAC_MW = 0.079  # static + clock tree share


def power_model_w(cfg: EngineConfig, utilization: float = 1.0) -> float:
    dyn = cfg.n_macs * cfg.freq_ghz * 1e9 * _E_MAC_PJ * 1e-12 * utilization
    leak = cfg.n_macs * _P_LEAK_PER_MAC_MW * 1e-3
    return dyn + leak


def table2_model() -> Dict[str, Dict[str, float]]:
    """Our reproduction of Table II from the cycle + area + power models.

    GFLOPS is each accelerator's peak (2 * MACs * f_max) as in the paper;
    area/power for the baselines are back-derived from their published
    efficiency figures (silicon ground truth), while O-POPE's come from the
    analytical models above — so the table cross-checks that the analytical
    models land on the published O-POPE numbers.
    """
    from .dataflows import ACCELERATORS  # local import to avoid cycles

    out: Dict[str, Dict[str, float]] = {}
    for name, acc in ACCELERATORS.items():
        gflops = acc.peak_gflops
        if name == "o-pope":
            area = area_model_mm2(EngineConfig(p=16, freq_ghz=1.0))["total"]
            power = power_model_w(EngineConfig(p=16, freq_ghz=1.0))
        else:
            pub_gflops, pub_dens, pub_eff = PUBLISHED_TABLE2[name]
            area = pub_gflops / pub_dens
            power = (pub_gflops / 1e3) / pub_eff if pub_eff else float("nan")
        out[name] = {
            "gflops": gflops,
            "gflops_per_mm2": gflops / area,
            "tflops_per_w": (gflops / 1e3) / power if power == power else float("nan"),
            "area_mm2": area,
            "power_w": power,
        }
    return out


def fig5_area_sweep() -> Dict[str, Dict[str, float]]:
    """Fig. 5a/5b reproduction: area and peak GFLOPS across mesh x MAC kind."""
    out: Dict[str, Dict[str, float]] = {}
    for mac_kind in MAC_AREA_UM2:
        for p in (4, 8, 16, 32):
            cfg = EngineConfig(p=p, freq_ghz=1.0)
            a = area_model_mm2(cfg, mac_kind)
            out[f"{mac_kind}/{p}x{p}"] = {
                "area_mm2": a["total"],
                "buffer_share": a["input_buffers"] / a["total"],
                "peak_gflops": cfg.peak_gflops,
            }
    return out


def fig5_geomean_scaling(mac_kind: str = "fp16") -> float:
    """Geometric mean of the area ratio between quadrupled-MAC configs."""
    ratios = []
    for p in (4, 8, 16):
        a1 = area_model_mm2(EngineConfig(p=p), mac_kind)["total"]
        a2 = area_model_mm2(EngineConfig(p=2 * p), mac_kind)["total"]
        ratios.append(a2 / a1)
    return math.prod(ratios) ** (1.0 / len(ratios))
