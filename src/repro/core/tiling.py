"""L1 tiling and DMA double-buffering model (paper §II-C, §III-D).

When GEMM operands exceed the 128 kB TCDM, the PULP cluster splits the
scratchpad in half: 64 kB holds the tiles the engine is computing on while the
DMA fills the other 64 kB with the next tiles (Fig. 7 setup). Core 0 reprograms
the DMA and the accelerator for every tile. The paper's exemplary tiling is
``64 x 128 x 128`` (FP16: 16 kB A + 32 kB B + 16 kB C = 64 kB).

This module provides

* :func:`choose_tile` — pick an (tm, tk, tn) tile satisfying the paper's
  utilization constraints (tm, tn multiples of the engine's C-tile side,
  tk >= 2p) under an L1 byte budget, and
* :func:`tiled_gemm_cycles` — the cluster-level runtime model: engine cycles
  per tile (via the cycle-accurate engine model, with C preload/writeback at
  k-tile boundaries) overlapped with DMA transfers, plus per-tile reprogramming.

The same tile-selection logic drives the TPU kernel's block-shape defaults
(`repro.kernels.opope_gemm`) with VMEM standing in for the TCDM — see
DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, List, Tuple

from .engine import EngineConfig, simulate_gemm

__all__ = [
    "TilingPlan",
    "choose_tile",
    "tiled_gemm_cycles",
    "ClusterConfig",
    "rank_plans",
]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """PULP cluster parameters around the engine (paper §II-C)."""

    tcdm_bytes: int = 128 * 1024
    double_buffer: bool = True  # half TCDM for DMA, half for compute
    dma_bytes_per_cycle: float = 16.0  # 128-bit AXI to L2
    reprogram_cycles: int = 50  # core 0 re-programs DMA + accelerator per tile

    @property
    def compute_bytes(self) -> int:
        return self.tcdm_bytes // 2 if self.double_buffer else self.tcdm_bytes


@dataclasses.dataclass(frozen=True)
class TilingPlan:
    tm: int
    tk: int
    tn: int
    elem_bytes: int

    @property
    def a_bytes(self) -> int:
        return self.tm * self.tk * self.elem_bytes

    @property
    def b_bytes(self) -> int:
        return self.tk * self.tn * self.elem_bytes

    @property
    def c_bytes(self) -> int:
        return self.tm * self.tn * self.elem_bytes

    @property
    def total_bytes(self) -> int:
        return self.a_bytes + self.b_bytes + self.c_bytes


def choose_tile(
    engine: EngineConfig,
    m: int,
    k: int,
    n: int,
    *,
    l1_budget_bytes: int = 64 * 1024,
    elem_bytes: int = 2,
) -> TilingPlan:
    """Pick an L1 tile per the paper's constraints.

    Preference order mirrors §III-C: (1) tm, tn multiples of the engine's
    output tile side (2p) to avoid pipeline quantization, (2) tk as large as
    possible and at least 2p so the C-tile swap hides under compute, (3) fit
    A+B+C in the budget. Falls back to the full dimension when it already fits.
    """
    side = engine.tile_m  # 2p
    tm = min(m, 2 * side)  # 64 for p=16 — the paper's exemplary tile height
    tm = max(side, (tm // side) * side) if m >= side else m

    def fits(tm: int, tk: int, tn: int) -> bool:
        return TilingPlan(tm, tk, tn, elem_bytes).total_bytes <= l1_budget_bytes

    # Grow tn in units of the tile side, then give the rest of the budget to tk.
    best: TilingPlan | None = None
    tn_cap = min(n, 16 * side)
    tn = side if n >= side else n
    while True:
        # Largest tk fitting the budget for this (tm, tn).
        tk_budget = (l1_budget_bytes - tm * tn * elem_bytes) // (
            (tm + tn) * elem_bytes
        )
        tk = min(k, tk_budget)
        if tk >= min(k, 2 * engine.p) and fits(tm, tk, tn):
            best = TilingPlan(tm, tk, tn, elem_bytes)
        next_tn = tn + side
        if next_tn > tn_cap or not fits(tm, min(k, 2 * engine.p), next_tn):
            break
        tn = next_tn
    if best is None:  # degenerate small-budget fallback
        best = TilingPlan(min(m, side), min(k, 2 * engine.p), min(n, side), elem_bytes)
    return best


def tiled_gemm_cycles(
    engine: EngineConfig,
    m: int,
    k: int,
    n: int,
    *,
    cluster: ClusterConfig = ClusterConfig(),
    plan: TilingPlan | None = None,
    elem_bytes: int = 2,
) -> dict:
    """Cluster-level runtime of a large GEMM with L1 double buffering.

    Per (m, n) macro-tile the K dimension is consumed in tk-chunks; the engine
    preloads the partial C tile as accumulator initial values (the paper's
    C-preload path) and writes it back per chunk. The DMA moves the next
    chunk's A/B (and C at macro-tile boundaries) concurrently; with double
    buffering each tile step costs ``max(engine, dma)`` cycles plus the
    reprogramming overhead.

    Returns a dict with total cycles, utilization, and the bound ("compute" or
    "dma") for reporting in `benchmarks/fig7_runtime.py`.
    """
    if plan is None:
        plan = choose_tile(
            engine, m, k, n,
            l1_budget_bytes=cluster.compute_bytes, elem_bytes=elem_bytes,
        )
    mt = math.ceil(m / plan.tm)
    nt = math.ceil(n / plan.tn)
    kt = math.ceil(k / plan.tk)

    total = 0
    compute_bound_steps = 0
    dma_bound_steps = 0
    # Prologue: DMA in the first tile set (not overlapped).
    first_bytes = plan.total_bytes
    total += math.ceil(first_bytes / cluster.dma_bytes_per_cycle)
    for i in range(mt):
        tm = min(plan.tm, m - i * plan.tm)
        for j in range(nt):
            tn = min(plan.tn, n - j * plan.tn)
            for kk in range(kt):
                tk = min(plan.tk, k - kk * plan.tk)
                eng = _engine_cycles(engine, tm, tk, tn)
                dma_bytes = (tm * tk + tk * tn) * elem_bytes
                if kk == kt - 1:  # C tile in/out at macro-tile boundary
                    dma_bytes += 2 * tm * tn * elem_bytes
                dma = math.ceil(dma_bytes / cluster.dma_bytes_per_cycle)
                step = max(eng, dma) if cluster.double_buffer else eng + dma
                total += step + cluster.reprogram_cycles
                if eng >= dma:
                    compute_bound_steps += 1
                else:
                    dma_bound_steps += 1
    useful = m * k * n
    return {
        "plan": plan,
        "total_cycles": total,
        "utilization": useful / (engine.n_macs * total),
        "runtime_us": total / (engine.freq_ghz * 1e3),
        "compute_bound_steps": compute_bound_steps,
        "dma_bound_steps": dma_bound_steps,
        "bound": "compute" if compute_bound_steps >= dma_bound_steps else "dma",
    }


@functools.lru_cache(maxsize=4096)
def _engine_cycles(engine: EngineConfig, tm: int, tk: int, tn: int) -> int:
    """Memoized per-tile engine cycles. A tiled GEMM sweep sees at most four
    distinct tile shapes (interior plus the three ragged edges), and the
    autotuner's candidate ranking replays the sweep for tens of candidate
    plans — without the memo the closed-form model dominates search time."""
    return simulate_gemm(engine, tm, tk, tn).total_cycles


def rank_plans(
    engine: EngineConfig,
    m: int,
    k: int,
    n: int,
    candidates: Iterable[Tuple[int, int, int]],
    *,
    elem_bytes: int = 2,
    top_k: int = 4,
    cluster: ClusterConfig = ClusterConfig(),
) -> List[Tuple[Tuple[int, int, int], int]]:
    """Rank candidate ``(tm, tk, tn)`` tiles by the analytic cluster model.

    This is the autotuner's **pruner** (`repro.tune.search`): instead of
    timing an exhaustive sweep on-device, every candidate is scored with
    :func:`tiled_gemm_cycles` — the same double-buffered compute/DMA-overlap
    model behind :func:`choose_tile` — and only the ``top_k`` cheapest (by
    modeled total cycles) go on to empirical measurement. Returns
    ``[(candidate, modeled_cycles), ...]`` cheapest first; duplicates are
    collapsed, order among equals is first-seen (deterministic).
    """
    scored: List[Tuple[Tuple[int, int, int], int]] = []
    seen = set()
    for tm, tk, tn in candidates:
        cand = (int(tm), int(tk), int(tn))
        if cand in seen:
            continue
        seen.add(cand)
        plan = TilingPlan(cand[0], cand[1], cand[2], elem_bytes)
        cycles = tiled_gemm_cycles(
            engine, m, k, n, cluster=cluster, plan=plan, elem_bytes=elem_bytes
        )["total_cycles"]
        scored.append((cand, cycles))
    scored.sort(key=lambda sc: sc[1])
    return scored[: max(1, top_k)]
