"""Data substrate: deterministic synthetic pipeline + prefetch."""
from .pipeline import MarkovLMDataset, Prefetcher, make_batch_fn
__all__ = ["MarkovLMDataset", "Prefetcher", "make_batch_fn"]
