"""Synthetic deterministic data pipeline.

Offline container: no corpus on disk, so the pipeline synthesizes token
streams from a fixed-seed Markov chain over the vocabulary. The chain gives
the stream real learnable structure (each token's successor distribution has
low entropy), so the end-to-end training examples show loss dropping well
below ln(V) — which is how tests assert the training loop actually learns.

Production shape: batches are generated host-side per step index
(deterministic, resumable — step N always yields the same batch, so a
restart from a checkpoint replays identically), then ``jax.device_put`` with
the step's batch sharding. A background prefetch thread keeps ``depth``
batches ahead of the training loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MarkovLMDataset", "Prefetcher", "make_batch_fn"]


@dataclasses.dataclass
class MarkovLMDataset:
    """Order-1 Markov token stream with ``branch`` successors per token."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branch: int = 4  # successors per state -> target CE ~ ln(branch)

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        self._succ = rng.integers(
            0, self.vocab, size=(self.vocab, self.branch), dtype=np.int64
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        rng = np.random.default_rng((self.seed, step))
        b, s = self.batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        choices = rng.integers(0, self.branch, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_fn(dataset: MarkovLMDataset, shardings=None):
    """step -> device-resident batch, placed with the given shardings."""

    def fn(step: int):
        host = dataset.batch_at(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {
            k: jax.device_put(v, shardings[k]) for k, v in host.items()
        }

    return fn


class Prefetcher:
    """Background thread that keeps ``depth`` batches ready."""

    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2):
        self._fn = batch_fn
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self) -> None:
        step = self._next
        while not self._stop.is_set():
            try:
                self._q.put((step, self._fn(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self):
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
        self._thread.join(timeout=2)
