"""Distribution layer: sharding rules for the FSDP x TP (x pod) mesh."""

from .sharding import (
    batch_pspecs,
    batch_shardings,
    cache_shardings,
    data_axes,
    guard_spec,
    param_pspec,
    param_shardings,
)

__all__ = [
    "batch_pspecs",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "guard_spec",
    "param_pspec",
    "param_shardings",
]
