"""Ambient-mesh sharding hints usable inside model code.

``constrain(x, spec...)`` applies ``with_sharding_constraint`` against the
ambient mesh (``repro.compat.set_mesh``), silently dropping axis names the
mesh doesn't have and becoming a no-op when there is no mesh (CPU smoke
tests). This lets model internals pin the few layouts GSPMD gets wrong
(split-K decode attention) without threading mesh objects through every
call. On JAX without abstract meshes the ambient mesh is the physical one,
and the constraint is issued as a NamedSharding (which needs no resource
env); on newer JAX the bare PartitionSpec binds to the abstract mesh.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["constrain"]

AxisEntry = Union[None, str, Tuple[str, ...]]


def constrain(x: jax.Array, *entries: AxisEntry) -> jax.Array:
    mesh = compat.current_abstract_mesh()
    names = set(getattr(mesh, "axis_names", ()) or ())
    if not names:
        return x

    def keep(e: AxisEntry) -> AxisEntry:
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    spec = [keep(e) for e in entries]
    # Drop axes whose mesh size does not divide the dim (jit-arg rule is
    # stricter than constraints, but keep it uniform and predictable).
    sizes = compat.mesh_axis_sizes(mesh)
    for i, (e, d) in enumerate(zip(spec, x.shape)):
        if e is None:
            continue
        n = 1
        for a in e if isinstance(e, tuple) else (e,):
            n *= sizes[a]
        if d % n:
            spec[i] = None
    pspec = P(*spec)
    if isinstance(mesh, jax.sharding.Mesh):  # physical-mesh fallback (0.4.x)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, pspec)
        )
    return jax.lax.with_sharding_constraint(x, pspec)
