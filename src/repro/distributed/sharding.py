"""Sharding rules: FSDP x TP 2-D parameter sharding + batch/cache specs.

Mesh axes (launch.mesh): ``data`` (+ ``pod`` at multi-pod scale) carry the
batch; ``model`` carries tensor parallelism. Parameters shard 2-D — the TP
dimension (d_ff / fused head dim / vocab / experts) over ``model`` and the
d_model dimension over ``data`` (FSDP) — which is required to fit grok-1's
314 B params + moments in a 4 TB pod (DESIGN.md §4).

Every rule is divisibility-guarded: a dim that does not divide its mesh axis
is replicated on that axis instead (JAX rejects unevenly-sharded jit
arguments — verified empirically). This is what keeps qwen's 40 heads,
grok's 8 experts and whisper's 51865-row vocab lowering cleanly.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = [
    "param_pspec",
    "param_shardings",
    "batch_pspecs",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "guard_spec",
]

AxisName = Union[str, Tuple[str, ...]]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch axes: ('pod', 'data') on a multi-pod mesh, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis: Optional[AxisName]) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def guard_spec(mesh: Mesh, shape: Sequence[int], spec: P) -> P:
    """Drop any spec axis whose mesh size does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        out.append(axis if dim % _axis_size(mesh, axis) == 0 else None)
    return P(*out)


# --- parameter rules ---------------------------------------------------------
# Matched in order against '/'-joined tree paths. First hit wins. ``S`` below
# marks the stacked leading period/layer axis on block params (always None).
_FSDP = "data"  # d_model / reduction dims
_TP = "model"  # d_ff / fused-heads / vocab / expert dims

_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / unembedding: [V, D]
    (r"embed/table$", (_TP, _FSDP)),
    (r"lm_head$", (_TP, _FSDP)),
    (r"enc_pos$", (None, _FSDP)),
    # attention (stacked): wq/wk/wv [S, D, H*Dh]; wo [S, H*Dh, D]
    (r"(attn|self_attn|cross_attn)/w[qkv]/w$", ("S", _FSDP, _TP)),
    (r"(attn|self_attn|cross_attn)/w[qkv]/b$", ("S", _TP)),
    (r"(attn|self_attn|cross_attn)/wo/w$", ("S", _TP, _FSDP)),
    # dense MLP: w_up/w_gate [S, D, F]; w_down [S, F, D]
    (r"mlp/w_(up|gate)$", ("S", _FSDP, _TP)),
    (r"mlp/w_down$", ("S", _TP, _FSDP)),
    # MoE: router [S, D, E]; experts [S, E, D, F] / [S, E, F, D].
    # EP over the expert dim when it divides the model axis (deepseek 64,
    # jamba 16); otherwise TP inside the expert on d_ff (grok E=8 — without
    # this fallback the 3.2 TB of grok expert weights would replicate 16x).
    (r"moe/router$", ("S", _FSDP, None)),
    (r"moe/w_(up|gate)$", ("S", "_EP_E", _FSDP, "_EP_F")),
    (r"moe/w_down$", ("S", "_EP_E", "_EP_F", _FSDP)),
    (r"moe/shared/w_(up|gate)$", ("S", _FSDP, _TP)),
    (r"moe/shared/w_down$", ("S", _TP, _FSDP)),
    # mamba
    (r"mamba/in_proj$", ("S", _FSDP, _TP)),
    (r"mamba/conv_w$", ("S", None, _TP)),
    (r"mamba/conv_b$", ("S", _TP)),
    (r"mamba/x_proj$", ("S", _TP, None)),
    (r"mamba/dt_proj$", ("S", None, _TP)),
    (r"mamba/dt_bias$", ("S", _TP)),
    (r"mamba/A_log$", ("S", _TP, None)),
    (r"mamba/D$", ("S", _TP)),
    (r"mamba/out_proj$", ("S", _TP, _FSDP)),
    # xLSTM
    (r"mlstm/w[qkv]$", ("S", _FSDP, _TP)),
    (r"mlstm/w_if$", ("S", _FSDP, None)),
    (r"mlstm/(w_o|ogate)$", ("S", _TP, _FSDP)),
    (r"slstm/w_x$", ("S", _FSDP, _TP)),
    (r"slstm/r$", ("S", None, None, None)),
    (r"slstm/w_o$", ("S", _TP, _FSDP)),
    # VLM projector
    (r"mm_projector/w1$", (_FSDP, _TP)),
    (r"mm_projector/w2$", (_TP, _FSDP)),
    # norms, biases, scalars: replicated
    (r".*", ()),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(
    mesh: Mesh, path_str: str, shape: Sequence[int], *, stacked_depth: bool = True
) -> P:
    """Spec for one parameter leaf; 'S' entries map to the stacked layer dim."""
    for pattern, rule in _PARAM_RULES:
        if re.search(pattern, path_str):
            entries = []
            rule_list = list(rule)
            # 'S' is positional: align rule entries to trailing dims if the
            # leaf lacks the stacked axis (e.g. unstacked whisper usage).
            if rule_list and rule_list[0] == "S":
                if len(shape) == len(rule_list):
                    entries.append(None)  # stacked axis replicated
                    rule_list = rule_list[1:]
                else:
                    rule_list = rule_list[1:]
            # Expert-dim fallback: _EP_E takes the model axis if the expert
            # count divides it, else _EP_F (the d_ff entry) takes it.
            if "_EP_E" in rule_list:
                e_pos = rule_list.index("_EP_E")
                dim_offset = len(entries)
                e_dim = shape[dim_offset + e_pos]
                ep_ok = e_dim % mesh.shape[_TP] == 0
                rule_list = [
                    (_TP if ep_ok else None)
                    if a == "_EP_E"
                    else ((None if ep_ok else _TP) if a == "_EP_F" else a)
                    for a in rule_list
                ]
            entries.extend(rule_list)
            spec = P(*entries) if entries else P()
            return guard_spec(mesh, shape, spec)
    return P()


def param_shardings(mesh: Mesh, params_shapes: Any) -> Any:
    """Tree of NamedShardings matching an eval_shape'd parameter pytree."""

    def one(path, leaf):
        spec = param_pspec(mesh, _path_str(path), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


# --- batch / cache specs -----------------------------------------------------


def batch_pspecs(mesh: Mesh, cfg: ArchConfig, batch_shapes: Any) -> Any:
    """Input batch: leading batch dim over the data axes (guarded)."""
    dp = data_axes(mesh)
    dp_axis = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        spec = guard_spec(mesh, leaf.shape, P(dp_axis))
        return spec

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def batch_shardings(mesh: Mesh, cfg: ArchConfig, batch_shapes: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), batch_pspecs(mesh, cfg, batch_shapes)
    )


def cache_shardings(
    mesh: Mesh, cfg: ArchConfig, cache_shapes: Any, *, layout: str = "decode"
) -> Any:
    """Decode-state sharding.

    KV caches are stored fused, [layers, B, S, H_kv*D] (the fused head dim
    always divides the 16-way model axis; individual head counts often
    don't — see KVCache).

    * ``layout="decode"`` — batch over the data axes, SEQUENCE over
      ``model``: split-K flash-decoding; the per-token cache read is the
      roofline memory term and shards 256-way. B=1 (long_500k) puts the
      sequence over data axes too (SP).
    * ``layout="prefill"`` — batch over data, fused HEAD dim over ``model``:
      exactly the K/V projection output layout, so the prefill installs the
      cache with zero resharding. (Serving reshards prefill->decode once,
      amortized over thousands of decode steps.)

    SSM / xLSTM states [layers, B, inner, ...]: batch over data axes, inner
    dim over ``model``. Whisper cross K/V [L, B, T_enc, H, D]: batch only.
    """
    dp = data_axes(mesh)
    dp_axis = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        shape = leaf.shape
        name = _path_str(path)
        last = name.split("/")[-1]
        if leaf.ndim == 4 and last in ("k", "v"):
            # fused KV cache [L, B, S, H*D]
            b_ok = shape[1] % _axis_size(mesh, dp_axis) == 0
            if layout == "prefill":
                spec = P(None, dp_axis, None, _TP)
            elif b_ok:
                spec = P(None, dp_axis, _TP, None)
            else:  # long-context decode, B=1: SP + split-K on the sequence
                spec = P(None, None, (*_as_tuple(dp_axis), _TP), None)
            return NamedSharding(mesh, guard_spec(mesh, shape, spec))
        if leaf.ndim == 5 and "cross" in name:
            return NamedSharding(
                mesh, guard_spec(mesh, shape, P(None, dp_axis, None, None, None))
            )
        if leaf.ndim >= 3:
            # ssm/conv/mlstm/slstm states: [L, B, inner, ...]
            if "conv" in name:  # [L, B, d_conv-1, Di]
                spec = P(None, dp_axis, None, _TP)
            else:
                spec = P(*([None, dp_axis, _TP] + [None] * (leaf.ndim - 3)))
            return NamedSharding(mesh, guard_spec(mesh, shape, spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def _as_tuple(axis: Optional[AxisName]) -> Tuple[str, ...]:
    if axis is None:
        return ()
    return axis if isinstance(axis, tuple) else (axis,)
