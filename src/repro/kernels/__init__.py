"""O-POPE kernels: output-stationary Pallas TPU kernels + jnp oracles.

* opope_gemm      — the paper's GEMM dataflow (VMEM-resident accumulator,
                    K-innermost panel streaming, C-preload epilogue).
* opope_grouped   — the grouped/batched member of the same dataflow: one
                    launch for G same-shape GEMMs (MoE expert FFNs).
* opope_attention — flash attention with the same accumulator-resident
                    structure (beyond-paper, §Perf).
* opope_scan      — state-resident chunked linear scan (mamba/xLSTM).
* ref             — pure-jnp oracles for all of the above.
* ops             — the backend-routed matmul / grouped_matmul every model
                    layer calls.
"""

from . import ops, ref
from .opope_gemm import opope_gemm
from .opope_grouped import opope_gemm_grouped
from .opope_attention import opope_attention, opope_attention_bhsd
from .opope_scan import opope_chunked_scan

__all__ = [
    "ops",
    "ref",
    "opope_gemm",
    "opope_gemm_grouped",
    "opope_attention",
    "opope_attention_bhsd",
    "opope_chunked_scan",
]
