"""Fused GEMM epilogues: the post-ops that ride the accumulator writeback.

O-POPE's output-stationary dataflow touches the [M, N] result exactly once —
at writeback, when the resident accumulator leaves VMEM. Every activation,
residual add or re-quantize applied *after* the GEMM as a separate XLA op
re-reads that result from HBM and throws the data-movement win away. This
module is the registry of post-ops that may instead be applied **to the
fp32 accumulator, before the single final cast**, wherever the writeback
happens:

* inside the Pallas kernels (``opope_gemm``/``opope_gemm_grouped`` and the
  q8 variants), on the resident tile, with operands streamed per-block;
* post-hoc in :mod:`repro.kernels.ops` for backends without a fused writeback
  (the XLA references): the backend produces the fp32 accumulator, the same
  op chain runs on it, then the one cast — numerically identical by
  construction, so the conformance contract (backend == reference, single
  cast) extends to epilogues unchanged.

An epilogue **spec** is a pipeline of named ops, each either parameterless
(``"silu"``) or carrying one operand (``("residual", x)``). Operand *kinds*
decide how the kernels stream them:

========  ===========================  ==============================
kind      operand shape (dense)        streamed per (bm, bn) tile as
========  ===========================  ==============================
none      —                            —
scalar    scalar / ()-shaped           (1, 1), broadcast
row       ``[N]``                      (1, bn) row, broadcast down M
full      ``[..., N]`` matching out    (bm, bn) tile
========  ===========================  ==============================

Each op declares ``apply(acc_f32, operand) -> f32`` — pure jnp, traceable
both inside a Pallas kernel body and at the XLA level — and optionally its
own ``vjp``; :func:`epilogue_vjp` composes the chain's backward pass for the
``custom_vjp`` rules in ``ops`` (ops without an explicit vjp differentiate
through ``jax.vjp`` of their ``apply``).

The built-in set covers the model stack: the ACT2FN-style activation table
(``gelu``/``silu``/``swish``/``relu`` — :data:`ACTIVATIONS`, the single
naming authority ``models.layers.ACT2FN`` re-exports), ``bias`` (+[N] row),
``residual`` (+[M, N]), ``mul`` (x[M, N] — the SwiGLU gate lane),
``scale`` (x[N] — an RMSNorm gamma), and ``requant_int8`` (re-quantize the
accumulator onto the int8 grid with a calibrated scalar scale, so layer N's
output feeds layer N+1's quantized GEMM without a dequant round trip;
gradients pass straight-through).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = [
    "EpilogueOp",
    "ACTIVATIONS",
    "register_epilogue_op",
    "epilogue_ops",
    "op_def",
    "op_kind",
    "normalize_epilogue",
    "canonicalize_operands",
    "apply_epilogue",
    "epilogue_vjp",
    "SCOPE_NAME",
]

# The jax.named_scope every epilogue application runs under — fused in-kernel
# or post-hoc. HLO instruction metadata keeps the scope name, which is how
# the decode-step census (core.hlo_census.elementwise_passes) tells the one
# sanctioned writeback pass from a stray hand-applied activation.
SCOPE_NAME = "opope_epilogue"

ApplyFn = Callable[[jax.Array, Optional[jax.Array]], jax.Array]
# vjp(acc_in, operand, g) -> (d_acc, d_operand_or_None): cotangents of one
# op given its *input* accumulator (the recomputed forward chain supplies it).
VjpFn = Callable[
    [jax.Array, Optional[jax.Array], jax.Array],
    Tuple[jax.Array, Optional[jax.Array]],
]

_KINDS = ("none", "scalar", "row", "full")


@dataclasses.dataclass(frozen=True)
class EpilogueOp:
    """One registered post-op: name, operand kind, fp32 apply, optional vjp."""

    name: str
    kind: str  # "none" | "scalar" | "row" | "full"
    apply: ApplyFn
    vjp: Optional[VjpFn] = None


_REGISTRY: Dict[str, EpilogueOp] = {}


def register_epilogue_op(
    name: str,
    kind: str,
    apply: ApplyFn,
    *,
    vjp: Optional[VjpFn] = None,
) -> None:
    """Register (or replace) an epilogue op.

    ``apply(acc_f32, operand)`` must be pure jnp (it traces inside Pallas
    kernel bodies *and* at the XLA level) and must keep fp32: the single
    final cast belongs to the GEMM, never to an epilogue op. Operands arrive
    broadcast-ready against the accumulator (see module docstring), so most
    binary ops are one jnp broadcast expression. ``vjp`` overrides the
    default backward (``jax.vjp`` of ``apply``).
    """
    if kind not in _KINDS:
        raise ValueError(f"bad epilogue operand kind {kind!r}; one of {_KINDS}")
    if not callable(apply):
        raise TypeError(f"epilogue apply for {name!r} is not callable")
    _REGISTRY[name] = EpilogueOp(name, kind, apply, vjp=vjp)


def epilogue_ops() -> List[str]:
    """Names of every registered epilogue op."""
    return list(_REGISTRY)


def op_def(name: str) -> EpilogueOp:
    op = _REGISTRY.get(name)
    if op is None:
        raise ValueError(
            f"unknown epilogue op {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return op


def op_kind(name: str) -> str:
    return op_def(name).kind


# --------------------------------------------------------------------------
# Built-in ops
# --------------------------------------------------------------------------

# The activation table — the one place activation *names* resolve (the
# ACT2FN-style table of the model stack; models.layers.ACT2FN is a view of
# this). All tanh-approximate gelu, matching jax.nn defaults.
ACTIVATIONS: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "swish": jax.nn.silu,  # alias: same op, HF-style naming
    "relu": lambda x: jnp.maximum(x, 0.0),
}

for _name, _fn in ACTIVATIONS.items():
    register_epilogue_op(_name, "none", (lambda acc, _o, _f=_fn: _f(acc)))

register_epilogue_op("bias", "row", lambda acc, o: acc + o)
register_epilogue_op("residual", "full", lambda acc, o: acc + o)
register_epilogue_op("mul", "full", lambda acc, o: acc * o)
register_epilogue_op("scale", "row", lambda acc, o: acc * o)


def _requant_int8(acc: jax.Array, s: jax.Array) -> jax.Array:
    # Snap the accumulator onto the int8 grid of a calibrated scalar scale:
    # the output values are *exact* integers in [-127.0, 127.0] (stored via
    # the single final cast, typically to int8 — exact integral floats make
    # the truncating float->int cast safe) that layer N+1's quantized GEMM
    # consumes directly — no dequantized copy, no second amax pass.
    return jnp.clip(jnp.round(acc / s), -127.0, 127.0)


def _requant_int8_vjp(
    acc: jax.Array, s: jax.Array, g: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    # Straight-through estimator: the quantization grid is invisible to the
    # gradient (QAT fake-quant) — out ~ acc/s where unclipped, 0 where
    # clipped. d/dacc = 1/s, d/ds = -acc/s^2, masked to the pass-through
    # region.
    x = acc / s
    gm = g * (jnp.abs(x) <= 127.0)
    d_acc = gm / s
    d_s = jnp.sum(gm * (-x / s)).reshape(s.shape)
    return d_acc, d_s


register_epilogue_op("requant_int8", "scalar", _requant_int8, vjp=_requant_int8_vjp)


# --------------------------------------------------------------------------
# Spec normalization
# --------------------------------------------------------------------------

# A user-facing epilogue spec: one step or a sequence of steps, each a bare
# name ("silu") or a (name, operand) pair (("residual", x)).
Step = Union[str, Tuple[str, Any]]
EpilogueSpec = Union[Step, Sequence[Step]]


def normalize_epilogue(
    spec: Optional[EpilogueSpec],
) -> Tuple[Tuple[str, ...], Tuple[Any, ...]]:
    """Normalize a spec to ``(step_names, raw_operands)``.

    ``step_names`` is hashable (it rides static/nondiff argument lanes);
    ``raw_operands`` holds one entry per step whose kind takes an operand,
    in pipeline order, shapes not yet canonicalized (see
    :func:`canonicalize_operands`). Unknown op names and arity mismatches
    raise — a typo'd activation must never silently become identity.
    """
    if spec is None:
        return (), ()
    if isinstance(spec, str):
        steps: Sequence[Step] = [spec]
    elif (
        isinstance(spec, tuple)
        and len(spec) == 2
        and isinstance(spec[0], str)
        # the second element is an operand (array/scalar), not another step:
        # ("silu", ("mul", x)) is a two-step sequence, ("residual", x) is one
        and not isinstance(spec[1], (str, tuple, list))
    ):
        steps = [spec]
    else:
        steps = list(spec)
    names: List[str] = []
    operands: List[Any] = []
    for step in steps:
        if isinstance(step, str):
            name, operand = step, None
        elif isinstance(step, tuple) and len(step) == 2:
            name, operand = step
        else:
            raise ValueError(
                f"bad epilogue step {step!r}: want 'name' or ('name', operand)"
            )
        op = op_def(name)
        if op.kind == "none":
            if operand is not None:
                raise ValueError(f"epilogue op {name!r} takes no operand")
        else:
            if operand is None:
                raise ValueError(
                    f"epilogue op {name!r} ({op.kind}) needs an operand: "
                    f"pass ({name!r}, operand)"
                )
            operands.append(operand)
        names.append(name)
    return tuple(names), tuple(operands)


def canonicalize_operands(
    steps: Tuple[str, ...],
    operands: Tuple[Any, ...],
    *,
    n: int,
    m: int,
    groups: int = 0,
    batch_shape: Tuple[int, ...] = (),
) -> Tuple[jax.Array, ...]:
    """Reshape raw operands broadcast-ready against the [M, N] (or
    [G, M, N]) accumulator, validating shapes.

    dense:   scalar -> (1, 1);  row [N] -> (1, N);  full batch x N -> (M, N)
    grouped: scalar -> (1, 1, 1); row [G, N] -> (G, 1, N); full -> (G, M, N)
    """
    out: List[jax.Array] = []
    it = iter(operands)
    for name in steps:
        kind = op_kind(name)
        if kind == "none":
            continue
        raw = next(it)
        x = jnp.asarray(raw)
        if kind == "scalar":
            if x.size != 1:
                raise ValueError(
                    f"epilogue op {name!r} wants a scalar operand; got "
                    f"shape {x.shape}"
                )
            shape = (1, 1, 1) if groups else (1, 1)
            out.append(x.astype(jnp.float32).reshape(shape))
        elif kind == "row":
            if groups:
                if x.shape == (n,):
                    x = jnp.broadcast_to(x, (groups, n))
                if x.shape != (groups, n):
                    raise ValueError(
                        f"epilogue op {name!r} row operand shape {x.shape} != "
                        f"{(groups, n)} (or broadcastable {(n,)})"
                    )
                out.append(x.reshape(groups, 1, n))
            else:
                if x.shape != (n,):
                    raise ValueError(
                        f"epilogue op {name!r} row operand shape {x.shape} != {(n,)}"
                    )
                out.append(x.reshape(1, n))
        else:  # full
            want = (groups, m, n) if groups else (m, n)
            if x.size != (groups or 1) * m * n:
                raise ValueError(
                    f"epilogue op {name!r} full operand shape {x.shape} "
                    f"incompatible with output {batch_shape + (n,)}"
                )
            out.append(x.reshape(want))
    return tuple(out)


# --------------------------------------------------------------------------
# Application + vjp (shared by kernels, the post-hoc lane, and conformance)
# --------------------------------------------------------------------------


def apply_epilogue(
    acc: jax.Array,
    steps: Tuple[str, ...],
    operands: Tuple[jax.Array, ...],
) -> jax.Array:
    """Run the op pipeline on the fp32 accumulator (no final cast here).

    Shape-agnostic: ``acc`` is a full [M, N] / [G, M, N] accumulator on the
    post-hoc lane or one (bm, bn) resident tile inside a kernel body —
    operands arrive broadcast-ready either way. Everything computes in fp32
    (operands are widened), preserving the widening-accumulation contract.
    """
    with jax.named_scope(SCOPE_NAME):
        x = acc.astype(jnp.float32)
        it = iter(operands)
        for name in steps:
            op = op_def(name)
            operand = None if op.kind == "none" else next(it).astype(jnp.float32)
            x = op.apply(x, operand)
    return x


def epilogue_vjp(
    steps: Tuple[str, ...],
    operands: Tuple[jax.Array, ...],
    acc: jax.Array,
    g: jax.Array,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Backward through the op pipeline at input accumulator ``acc``.

    Returns ``(d_acc, d_operands)`` with ``d_operands`` aligned to
    ``operands`` (reduced over broadcast dimensions). The forward chain is
    recomputed op-by-op (the fused writeback never materializes the
    intermediates); each op uses its registered ``vjp`` or differentiates
    through ``jax.vjp`` of its ``apply``.
    """
    accf = acc.astype(jnp.float32)
    ops_f = tuple(o.astype(jnp.float32) for o in operands)
    # Forward replay, saving each op's input accumulator.
    inputs: List[jax.Array] = []
    per_step: List[Tuple[EpilogueOp, Optional[jax.Array]]] = []
    it = iter(ops_f)
    x = accf
    for name in steps:
        op = op_def(name)
        operand = None if op.kind == "none" else next(it)
        inputs.append(x)
        per_step.append((op, operand))
        x = op.apply(x, operand)
    # Reverse sweep.
    d_ops: List[Optional[jax.Array]] = [None] * len(per_step)
    gx = g.astype(jnp.float32)
    for i in range(len(per_step) - 1, -1, -1):
        op, operand = per_step[i]
        if op.vjp is not None:
            gx, d_op = op.vjp(inputs[i], operand, gx)
        elif op.kind == "none":
            _, pull = jax.vjp(lambda a, _op=op: _op.apply(a, None), inputs[i])
            (gx,) = pull(gx)
            d_op = None
        else:
            _, pull = jax.vjp(
                lambda a, o, _op=op: _op.apply(a, o), inputs[i], operand
            )
            gx, d_op = pull(gx)
        d_ops[i] = d_op
    grads = tuple(d for d in d_ops if d is not None)
    return gx, grads
