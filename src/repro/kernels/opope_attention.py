"""Flash attention with the O-POPE accumulator-resident dataflow (Pallas).

Beyond-paper kernel (§Perf): the paper keeps the GEMM's output tile resident
in the PE accumulators while input panels stream. Attention has the same
structure once softmax is computed online — the per-query-block state
``(m, l, acc)`` is the output-stationary accumulator, KV panels are the
streamed rank-k updates:

* grid = (q_blocks, kv_steps), kv innermost (``arbitrary``), exactly the
  (m, n, k) structure of ``opope_gemm`` with k -> KV panels;
* ``m/l/acc`` live in VMEM scratch across the KV loop (the paper's
  accumulator registers), written to the output window once at the end;
* Mosaic double-buffers the K/V panel DMAs behind the MXU — the "pipeline
  registers as buffers" insight, one level up.

Single-head layout (q: [S, D], k/v: [T, D]); batch/heads via ``jax.vmap``.
Causal masking per block pair; fully-masked panels are skipped via
``pl.when`` (no MXU work issued).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["opope_attention", "opope_attention_bhsd"]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, kv_steps: int, block_q: int, block_k: int, causal: bool, scale: float,
    t_actual: int, q_offset: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal panel pruning: panel j is live iff its first kv position is
    # <= the block's last query position (+ q_offset aligns q to the END of
    # the key range when T != S, matching cache-continuation semantics).
    live = (j * block_k <= (i + 1) * block_q - 1 + q_offset) if causal else True

    @pl.when(live)
    def _panel():
        s = jax.lax.dot_general(
            q_ref[...], k_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [block_q, block_k]
        qpos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        valid = kpos < t_actual  # padded keys never win softmax weight
        if causal:
            valid &= kpos <= qpos + q_offset
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _writeback():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def opope_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Single-head attention. q: [S, D]; k/v: [T, D] -> [S, D]."""
    s, d = q.shape
    t = k.shape[0]
    scale = d**-0.5
    bq = min(block_q, s)
    bk = min(block_k, t)
    sp, tp = _rup(s, bq), _rup(t, bk)
    q_p = jnp.pad(q, ((0, sp - s), (0, 0)))
    k_p = jnp.pad(k, ((0, tp - t), (0, 0)))
    v_p = jnp.pad(v, ((0, tp - t), (0, 0)))

    kv_steps = tp // bk
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            kv_steps=kv_steps,
            block_q=bq,
            block_k=bk,
            causal=causal,
            scale=scale,
            t_actual=t,
            q_offset=t - s,
        ),
        grid=(sp // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_p, k_p, v_p)
    return out[:s]


def opope_attention_bhsd(
    q: jax.Array, k: jax.Array, v: jax.Array, **kw
) -> jax.Array:
    """Batched/multi-head wrapper. q: [B,H,S,D]; k/v: [B,H,T,D]."""
    fn = functools.partial(opope_attention, **kw)
    return jax.vmap(jax.vmap(fn))(q, k, v)


def _rup(x: int, m: int) -> int:
    return m * math.ceil(x / m)
