"""O-POPE GEMM as a Pallas TPU kernel.

This is the TPU-native embodiment of the paper's dataflow (DESIGN.md §3):

* **Output-stationary**: the fp32 accumulator tile lives in VMEM scratch for
  the whole K loop — the analogue of the paper's accumulator registers. It is
  written to the (HBM-backed) output window exactly once, on the last K step.
* **Outer-product K streaming**: the grid is ``(m, n, k)`` with ``k`` the
  innermost, ``arbitrary`` (sequential) dimension; each step performs a
  rank-``block_k`` panel update — the MXU generalization of the paper's
  rank-1 updates (a rank-1 grid step would starve the 128x128 MXU; the
  *dataflow* is identical, the panel width is sized to the unit).
* **Pipeline registers as buffers**: Mosaic's automatic multiple-buffering of
  the ``BlockSpec`` input streams plays the role of the FPU pipeline
  registers: A/B panels for step ``k+1`` are DMA'd while step ``k`` computes,
  with no explicitly managed buffers — the same "the pipeline is the buffer"
  insight, one level up the memory hierarchy.
* **Accumulator preload (C operand)**: like the paper's engine, the kernel can
  preload an initial C tile into the accumulator (``c=``). This fuses
  ``A @ B + C`` (residual adds, bias grids, K-split partial accumulation)
  into the GEMM epilogue with zero extra HBM round-trip.
* **Mixed precision**: inputs fp8/bf16/f32, accumulation always fp32
  (``preferred_element_type``), output cast configurable — mirroring the
  paper's FP8→FP16 / FP16→FP32 widening MAC configurations.

Block shapes are multiples of the TPU tile (8x128 lanes; 128-aligned MXU
dims). Shape padding is applied outside the ``pallas_call`` and reported via
:func:`padding_waste` — the software analogue of the paper's tile-quantization
utilization loss (§III-C).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import epilogue as _ep

__all__ = [
    "opope_gemm",
    "default_block_shape",
    "validate_block_shape",
    "padding_waste",
    "VMEM_BUDGET_BYTES",
]

# VMEM working-set budget for one grid step: the resident fp32/int32
# accumulator tile plus double-buffered A/B panels must fit in roughly half
# of a core's 16 MiB VMEM (the other half is Mosaic's pipelining headroom) —
# the TPU analogue of the paper's 64 kB compute half of the TCDM. Shared by
# the heuristic below and the autotuner's candidate validation.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """One (m, n, k) grid step: rank-block_k update of the resident tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gemm_preload_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, k_steps: int):
    """As :func:`_gemm_kernel` but the accumulator is preloaded from C —
    the paper's accumulator-preload path (Fig. 2/3). The C tile is either a
    full (bm, bn) block or a (1, bn) bias row broadcast down the M dimension
    at preload time (no [M, N] operand ever materializes in HBM)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            c_ref[...].astype(jnp.float32), acc_ref.shape
        )

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gemm_epilogue_kernel(*refs, k_steps: int, steps, has_c: bool):
    """Epilogue-fused grid step: the op pipeline runs on the resident fp32
    tile at writeback, before the single cast — the result never round-trips
    HBM between the GEMM and its post-ops.

    ``refs`` in pallas_call order: a, b, (c if ``has_c``), one ref per
    operand-taking epilogue step, o, acc scratch. Epilogue operand blocks are
    streamed by kind — (1, 1) scalar, (1, bn) row, (bm, bn) full — and
    broadcast against the tile inside :func:`repro.kernels.epilogue.apply_epilogue`.
    """
    a_ref, b_ref = refs[0], refs[1]
    idx = 3 if has_c else 2
    c_ref = refs[2] if has_c else None
    ep_refs = refs[idx:-2]
    o_ref, acc_ref = refs[-2], refs[-1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if c_ref is None:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        else:
            acc_ref[...] = jnp.broadcast_to(
                c_ref[...].astype(jnp.float32), acc_ref.shape
            )

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        acc = _ep.apply_epilogue(
            acc_ref[...], steps, tuple(r[...] for r in ep_refs)
        )
        o_ref[...] = acc.astype(o_ref.dtype)


def default_block_shape(
    m: int, k: int, n: int, elem_bytes: int = 2
) -> Tuple[int, int, int]:
    """Pick (block_m, block_n, block_k) the way `core.tiling.choose_tile` does
    for the TCDM, with VMEM (16 MiB/core, ~half usable with double buffering)
    as the budget: C tile fp32 + double-buffered A/B panels must fit, MXU dims
    128-aligned, and block_k at least 2x the MXU side so the output tile swap
    hides under compute (the paper's K >= 2p condition, one level up)."""
    bm = min(256, max(128, 8 * math.ceil(m / 8) if m < 128 else 128))
    bn = min(256, 128 * max(1, math.ceil(min(n, 256) / 128)))
    bk = min(512, 128 * max(2, math.ceil(min(k, 512) / 128)))
    while (
        bm * bn * 4 + 2 * (bm * bk + bk * bn) * elem_bytes > VMEM_BUDGET_BYTES
        and bk > 128
    ):
        bk //= 2
    return bm, bn, bk


def validate_block_shape(
    bm: int,
    bn: int,
    bk: int,
    *,
    elem_bytes: int = 2,
    m_align: int = 8,
    budget_bytes: int = VMEM_BUDGET_BYTES,
) -> bool:
    """Whether ``(bm, bn, bk)`` is a legal O-POPE block shape on this kernel.

    The kernel's hard constraints, checked before any tuned tile (a table
    entry is untrusted input — hand-edited files, tables tuned for another
    kernel revision) is allowed near a ``pallas_call``:

    * ``bm`` positive and ``m_align``-aligned (8 = fp sublane tile; the int8
      kernels need 32),
    * ``bn``, ``bk`` positive multiples of 128 (MXU lane dimension),
    * accumulator tile + double-buffered A/B panels fit the VMEM budget.
    """
    if bm <= 0 or bn <= 0 or bk <= 0:
        return False
    if bm % m_align or bn % 128 or bk % 128:
        return False
    return bm * bn * 4 + 2 * (bm * bk + bk * bn) * elem_bytes <= budget_bytes


def padding_waste(m: int, k: int, n: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MACs wasted on pad — the paper's quantization loss."""
    mp = math.ceil(m / bm) * bm
    kp = math.ceil(k / bk) * bk
    np_ = math.ceil(n / bn) * bn
    return 1.0 - (m * k * n) / (mp * kp * np_)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "out_dtype",
        "interpret",
        "epilogue",
    ),
)
def opope_gemm(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    epilogue: Tuple[str, ...] = (),
    epilogue_operands: Tuple[jax.Array, ...] = (),
) -> jax.Array:
    """``O = A @ B (+ C)`` with the O-POPE dataflow. a: [M,K], b: [K,N].

    ``epilogue`` names a pipeline of registered post-ops (static; see
    :mod:`repro.kernels.epilogue`) applied to the resident fp32 accumulator
    at writeback, before the single final cast; ``epilogue_operands`` carries
    one canonical-dense-shape array per operand-taking step — scalar ``(1,1)``,
    row ``(1,N)``, full ``(M,N)`` — streamed per-tile by kind.

    ``interpret=True`` runs the kernel body in the Pallas interpreter (CPU) —
    used for all correctness tests in this container; on a real TPU the same
    call lowers through Mosaic.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    out_dtype = jnp.dtype(out_dtype or a.dtype)

    bm, bn, bk = min(block_m, _rup(m, 8)), min(block_n, _rup(n, 128)), min(
        block_k, _rup(k, 128)
    )
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    a_p = _pad2(a, mp, kp)
    b_p = _pad2(b, kp, np_)
    k_steps = kp // bk

    grid = (mp // bm, np_ // bn, k_steps)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [a_p, b_p]
    if c is not None:
        if c.ndim == 1:
            # [N] bias: streamed as a single (1, bn) row per N tile and
            # broadcast into the accumulator at preload — O(N) HBM traffic
            # instead of an O(M*N) materialized C operand.
            if c.shape != (n,):
                raise ValueError(f"C preload shape {c.shape} != {(n,)} or {(m, n)}")
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
            operands.append(_pad2(c[None, :], 1, np_))
        else:
            if c.shape != (m, n):
                raise ValueError(f"C preload shape {c.shape} != {(m, n)}")
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
            operands.append(_pad2(c, mp, np_))
        kernel = functools.partial(_gemm_preload_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(_gemm_kernel, k_steps=k_steps)

    if epilogue:
        # One streamed operand per operand-taking step, blocked by kind.
        # Zero-pad is safe throughout: every built-in op maps 0 -> 0 on the
        # pad region or the pad is sliced off below before anyone reads it.
        it = iter(epilogue_operands)
        for name in epilogue:
            kind = _ep.op_kind(name)
            if kind == "none":
                continue
            x = next(it)
            if kind == "scalar":
                in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
                operands.append(x.reshape(1, 1))
            elif kind == "row":
                in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
                operands.append(_pad2(x.reshape(1, n), 1, np_))
            else:  # full
                in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
                operands.append(_pad2(x.reshape(m, n), mp, np_))
        kernel = functools.partial(
            _gemm_epilogue_kernel,
            k_steps=k_steps,
            steps=epilogue,
            has_c=c is not None,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def _rup(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def _pad2(x: jax.Array, d0: int, d1: int) -> jax.Array:
    if x.shape == (d0, d1):
        return x
    return jnp.pad(x, ((0, d0 - x.shape[0]), (0, d1 - x.shape[1])))
