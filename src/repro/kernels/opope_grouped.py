"""Grouped O-POPE GEMM: one kernel launch for a whole family of same-shape
GEMMs (MoE expert FFNs, multi-head projections folded per-head, LoRA branch
stacks).

``O[g] = A[g] @ B[g] (+ C[g])`` for ``g`` in ``0..G-1`` — the batched-GEMM
shape family OpenGeMM (arXiv:2411.09543) identifies as the one that collapses
utilization when it bypasses the tuned engine. The dataflow per group is
exactly :func:`repro.kernels.opope_gemm.opope_gemm`:

* the grid is ``(G, m, n, k)`` with ``k`` innermost/sequential — the group
  axis is one more ``parallel`` grid dimension, so groups pipeline through
  the same MXU schedule instead of launching G kernels;
* one fp32 accumulator tile stays resident in VMEM scratch across the K loop
  of each group; it is written back exactly once per ``(g, m, n)`` tile;
* A/B panels stream under Mosaic's automatic multiple-buffering — while
  group ``g`` finishes its last K step, the first panels of group ``g+1``
  are already in flight (the paper's "pipeline is the buffer", now across
  group boundaries too);
* the optional C operand preloads the accumulator: a full ``[G, M, N]``
  operand or a ``[G, N]`` per-group bias row broadcast down M at preload
  (never materialized as ``[G, M, N]``).

Because every group shares (M, K, N), tile selection is the single-group
choice — resolved through the registry's shared path (``ops._tile_for``)
under the **grouped** family key with the group count: a tuning-table entry
measured for this grouped shape wins over the
:func:`repro.kernels.opope_gemm.default_block_shape` heuristic, and never
collides with a dense entry of the same per-group (M, K, N).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import epilogue as _ep

__all__ = ["opope_gemm_grouped"]


def _grouped_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """One (g, m, n, k) grid step: rank-block_k update of group g's tile."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _grouped_preload_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, k_steps: int):
    """As :func:`_grouped_kernel` with the accumulator preloaded from C.

    The C block is either a full (1, bm, bn) tile of group g or a (1, 1, bn)
    per-group bias row broadcast down M at preload time.
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            c_ref[0].astype(jnp.float32), acc_ref.shape
        )

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _grouped_epilogue_kernel(*refs, k_steps: int, steps, has_c: bool):
    """Epilogue-fused (g, m, n, k) grid step — the grouped analogue of
    ``opope_gemm._gemm_epilogue_kernel``: the op pipeline runs on group g's
    resident fp32 tile at writeback, before the single cast.

    ``refs`` order: a, b, (c if ``has_c``), one ref per operand-taking
    epilogue step, o, acc scratch. Epilogue operand blocks carry a leading
    group dim — (1, 1, 1) scalar, (1, 1, bn) row, (1, bm, bn) full — dropped
    with ``ref[0]`` before broadcasting against the 2-D tile.
    """
    a_ref, b_ref = refs[0], refs[1]
    idx = 3 if has_c else 2
    c_ref = refs[2] if has_c else None
    ep_refs = refs[idx:-2]
    o_ref, acc_ref = refs[-2], refs[-1]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        if c_ref is None:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        else:
            acc_ref[...] = jnp.broadcast_to(
                c_ref[0].astype(jnp.float32), acc_ref.shape
            )

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        acc = _ep.apply_epilogue(
            acc_ref[...], steps, tuple(r[0] for r in ep_refs)
        )
        o_ref[...] = acc.astype(o_ref.dtype)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "out_dtype", "interpret", "epilogue",
    ),
)
def opope_gemm_grouped(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    epilogue: Tuple[str, ...] = (),
    epilogue_operands: Tuple[jax.Array, ...] = (),
) -> jax.Array:
    """``O[g] = A[g] @ B[g] (+ C[g])``. a: [G, M, K], b: [G, K, N].

    ``c`` is ``None``, a full ``[G, M, N]`` preload, or a ``[G, N]`` per-group
    bias row. ``epilogue`` names a static pipeline of registered post-ops
    (see :mod:`repro.kernels.epilogue`) fused at the accumulator writeback;
    ``epilogue_operands`` carries one canonical-grouped-shape array per
    operand-taking step — scalar ``(1,1,1)``, row ``(G,1,N)``, full
    ``(G,M,N)``. ``interpret=True`` runs the body in the Pallas interpreter
    (CPU tests); on a real TPU the same call lowers through Mosaic.
    """
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0] or a.shape[2] != b.shape[1]:
        raise ValueError(f"bad grouped GEMM shapes {a.shape} @ {b.shape}")
    g, m, k = a.shape
    _, _, n = b.shape
    out_dtype = jnp.dtype(out_dtype or a.dtype)

    bm, bn, bk = min(block_m, _rup(m, 8)), min(block_n, _rup(n, 128)), min(
        block_k, _rup(k, 128)
    )
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    a_p = _pad3(a, g, mp, kp)
    b_p = _pad3(b, g, kp, np_)
    k_steps = kp // bk

    grid = (g, mp // bm, np_ // bn, k_steps)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
        pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
    ]
    operands = [a_p, b_p]
    if c is not None:
        if c.ndim == 2:
            # [G, N] per-group bias rows: streamed as (1, 1, bn) blocks and
            # broadcast into the accumulator at preload — O(G*N) HBM traffic
            # instead of an O(G*M*N) materialized C operand.
            if c.shape != (g, n):
                raise ValueError(
                    f"C preload shape {c.shape} != {(g, n)} or {(g, m, n)}"
                )
            in_specs.append(pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j)))
            operands.append(_pad3(c[:, None, :], g, 1, np_))
        else:
            if c.shape != (g, m, n):
                raise ValueError(
                    f"C preload shape {c.shape} != {(g, n)} or {(g, m, n)}"
                )
            in_specs.append(pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)))
            operands.append(_pad3(c, g, mp, np_))
        kernel = functools.partial(_grouped_preload_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(_grouped_kernel, k_steps=k_steps)

    if epilogue:
        # One streamed operand per operand-taking step, blocked by kind;
        # zero-pad is safe (pad regions are sliced off below).
        it = iter(epilogue_operands)
        for name in epilogue:
            kind = _ep.op_kind(name)
            if kind == "none":
                continue
            x = next(it)
            if kind == "scalar":
                in_specs.append(
                    pl.BlockSpec((1, 1, 1), lambda gg, i, j, kk: (0, 0, 0))
                )
                operands.append(x.reshape(1, 1, 1))
            elif kind == "row":
                in_specs.append(
                    pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j))
                )
                operands.append(_pad3(x.reshape(g, 1, n), g, 1, np_))
            else:  # full
                in_specs.append(
                    pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j))
                )
                operands.append(_pad3(x.reshape(g, m, n), g, mp, np_))
        kernel = functools.partial(
            _grouped_epilogue_kernel,
            k_steps=k_steps,
            steps=epilogue,
            has_c=c is not None,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :m, :n]


def _rup(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def _pad3(x: jax.Array, d0: int, d1: int, d2: int, value=0) -> jax.Array:
    """Zero-pad (or ``value``-pad: q8 scale operands pad with ones) a 3-D
    operand up to (d0, d1, d2). Shared with the grouped q8 kernel."""
    if x.shape == (d0, d1, d2):
        return x
    return jnp.pad(
        x,
        (
            (0, d0 - x.shape[0]),
            (0, d1 - x.shape[1]),
            (0, d2 - x.shape[2]),
        ),
        constant_values=value,
    )
