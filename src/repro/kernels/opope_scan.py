"""State-resident chunked linear scan (Pallas) — the mamba/xLSTM recurrence.

Beyond-paper kernel (§Perf, jamba hillclimb): the jnp chunked scan
materializes [chunk, D] discretized tensors in HBM at every associative-scan
stage — the dominant HBM-traffic term of jamba's train cell before the fix.
Here the recurrence state is the output-stationary accumulator held in VMEM
scratch across grid steps (the O-POPE discipline), and each grid step
consumes one chunk panel of (decay, update) inputs:

    h[t] = decay[t] * h[t-1] + update[t]

The kernel emits all states (needed by the SSM output projection). Chunks
are the grid's ``arbitrary`` dimension, so Mosaic pipelines panel DMAs
behind the VPU exactly as it pipelines GEMM panels behind the MXU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["opope_chunked_scan"]


def _scan_kernel(a_ref, b_ref, o_ref, h_ref, *, chunk: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)  # [chunk, D]
    b = b_ref[...].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        o_ref[t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def opope_chunked_scan(
    decay: jax.Array,
    update: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """All-states linear scan. decay/update: [S, D] -> states [S, D] (f32)."""
    s, d = decay.shape
    ck = min(chunk, s)
    sp = ck * math.ceil(s / ck)
    a_p = jnp.pad(decay, ((0, sp - s), (0, 0)))
    b_p = jnp.pad(update, ((0, sp - s), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=ck),
        grid=(sp // ck,),
        in_specs=[
            pl.BlockSpec((ck, d), lambda j: (j, 0)),
            pl.BlockSpec((ck, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ck, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:s]
