"""Public jit'd matmul entry point used by every layer in the framework.

``matmul`` routes through a **backend registry** with identical numerics
across backends (fp32 accumulation, single final cast — see `ref.py`):

* ``"pallas"``            — the O-POPE Pallas kernel, compiled (TPU).
* ``"pallas_interpret"``  — same kernel body, Pallas interpreter (CPU tests).
* ``"xla"``               — ``jax.lax.dot_general`` with
  ``preferred_element_type=f32``; used for the CPU dry-run, where Pallas
  cannot lower, and as the A/B comparison baseline in benchmarks.

New backends register with :func:`register_backend` (an availability probe
gates selection). The default ``"auto"`` resolver probes whether the
compiled Pallas path actually lowers on the current platform — once, lazily,
cached — so model code is backend-agnostic and a platform where Mosaic is
absent degrades to ``xla`` instead of raising at the first layer. An
explicitly requested backend that is unavailable likewise degrades along its
*fallback chain* (default ``pallas_interpret`` then ``xla``; a registered
backend may declare its own chain — the quantized backends fall back to
``xla_q8`` so degradation preserves quantized numerics) rather than raising.

Quantized backends (``xla_q8``, ``pallas_q8`` — see :mod:`repro.quant`)
register themselves on first use: an unknown backend name triggers one lazy
``import repro.quant`` before resolution fails, so callers never import the
quant package explicitly just to name its backends.

The pallas backends pick block shapes through one memoized resolution path,
``_tile_for``, keyed per ``(backend, shape-family, M, K, N, G, dtype)`` so a
grouped GEMM can never collide with a dense one of the same (M, K, N). The
resolution order is **tuned table first, heuristic second**: a persistent
tuning table written by :mod:`repro.tune` (the ``repro-tune`` CLI; location
overridable via ``REPRO_TUNE_TABLE``) is consulted for an empirically
measured winner on this device kind, and only on a miss does the backend's
registered ``tile_fn`` heuristic (``opope_gemm.default_block_shape`` — the
VMEM-budget analogue of the paper's tile quantization rule — or the q8
variant) decide. Tuned tiles are validated against the kernel's hard
constraints (alignment, VMEM budget) before use; :func:`tile_source` reports
which path won for a given shape. The memo is LRU-bounded
(``_TILE_CACHE_CAP``): a long-lived serving process that sees an unbounded
stream of request shapes must not grow it without limit.
:func:`clear_tile_cache` drops both the memo and the loaded table state.

A ``custom_vjp`` makes the backward pass run the same O-POPE dataflow (two
more GEMMs: dA = dO @ B^T, dB = A^T @ dO) instead of whatever XLA would pick
for the transposed dots. A backend registered with ``grad_backend=`` runs
its backward GEMMs on that backend instead — how the quantized paths encode
the paper's "accuracy-sensitive tasks such as training still require
higher-precision floating-point formats": forward may be q8, gradients are
always full-precision fp32-accumulated.

Each backend is a **family**: alongside the 2-D ``fn`` it may register a
``grouped`` member (``[G, M, K] @ [G, K, N]`` — :func:`grouped_matmul`), so
batched shape families (MoE expert FFNs) route through the same names,
resolver, fallback chains and grad-backend rule as single GEMMs. Backends
also declare a numerics ``family`` tag (``"fp"``/``"q8"``): a fallback chain
may change the execution engine but must land on a terminal of the same
family — degradation never silently changes quantization behaviour
(asserted registry-wide by ``tests/test_backend_conformance.py`` and the CI
introspection step).

**Fused epilogues** (:mod:`repro.kernels.epilogue`): ``matmul`` and
``grouped_matmul`` take an ``epilogue=`` pipeline of registered post-ops
(activations, bias, residual, RMSNorm scale, re-quantize) applied to the
fp32 accumulator before the single final cast. Backends registered with
``epilogue_fused=True`` run the pipeline *inside* their kernel at the
accumulator writeback (the O-POPE point: the result is touched once); every
other backend — including any fallback a request degrades onto — gets the
**post-hoc lane**: the backend produces the fp32 accumulator, the same op
pipeline runs on it under ``jax.named_scope("opope_epilogue")``, then the
one cast. The two lanes are numerically identical by construction, so the
conformance contract extends to epilogues unchanged, and degradation can
never drop or double-apply a requested epilogue. Whether a *capable*
backend actually fuses is a per-shape decision: tuning-table verdict first
(:mod:`repro.tune` measures fused vs post-hoc), fuse-by-default second —
:func:`fusion_source` reports which. The custom_vjp rules recompute the
pre-epilogue accumulator in the backward pass (one extra GEMM — the fused
forward never materializes it), backprop through the op pipeline, then run
the usual two transposed GEMMs on the grad backend.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import warnings
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro import obs as _obs

from . import epilogue as _epi
from . import opope_gemm as _kern
from . import opope_grouped as _gkern
from . import ref as _ref

__all__ = [
    "matmul",
    "grouped_matmul",
    "linear",
    "epilogue_capable",
    "fusion_source",
    "default_backend",
    "set_default_backend",
    "register_backend",
    "resolve_backend",
    "resolve_grouped_backend",
    "available_backends",
    "registered_backends",
    "grouped_backends",
    "grad_backend_of",
    "fallback_chain_of",
    "family_of",
    "tunable_backends",
    "tile_for",
    "tile_source",
    "heuristic_tile",
    "tile_cache_info",
    "tile_cache_stats",
    "reset_tile_cache_stats",
    "on_miss_streak",
    "on_util_gap",
    "clear_tile_cache",
    "capture_shapes",
]

_DEFAULT_BACKEND = "auto"

# --------------------------------------------------------------------------
# Backend registry
# --------------------------------------------------------------------------

# A backend is fn(a, b, c_or_None, out_dtype) -> [M, N] array with fp32
# accumulation and a single final cast (the repo-wide numerics contract).
BackendFn = Callable[[jax.Array, jax.Array, Optional[jax.Array], jnp.dtype], jax.Array]
# The grouped member of a backend family: fn(a [G,M,K], b [G,K,N], c_or_None,
# out_dtype) -> [G, M, N], same accumulation/cast contract per group. ``c``
# is None, a full [G, M, N] preload, or a [G, N] per-group bias row.
GroupedFn = Callable[[jax.Array, jax.Array, Optional[jax.Array], jnp.dtype], jax.Array]


@dataclasses.dataclass(frozen=True)
class _Backend:
    name: str
    fn: BackendFn
    available: Callable[[], bool]
    # Degradation order when this backend's probe fails (None = the default
    # chain). Quantized backends fall back to other *quantized* backends so
    # an unavailable accelerator path degrades without changing numerics.
    fallback: Optional[Tuple[str, ...]] = None
    # Backend for the custom_vjp backward GEMMs (None = same as forward).
    # Quantized backends set a full-precision grad backend — the paper's
    # "training still needs FP" rule, enforced at the registry.
    grad_backend: Optional[str] = None
    # Grouped/batched GEMM implementation (None = this backend has no grouped
    # member; grouped_matmul degrades along the fallback chain to one that
    # does).
    grouped: Optional[GroupedFn] = None
    # Separate availability probe for the grouped member (None = the grouped
    # member is available whenever the backend is). Per-member probing keeps
    # a grouped-only lowering failure from disabling the 2-D matmul path:
    # dense models keep their compiled kernels, only grouped_matmul degrades.
    grouped_available: Optional[Callable[[], bool]] = None
    # Numerics family ("fp" full-precision, "q8" int8-quantized, ...): the
    # invariant a fallback chain must preserve — degradation may change the
    # execution engine, never the numerics family.
    family: str = "fp"
    # Block-shape heuristic fn(m, k, n, elem_bytes=...) -> (bm, bn, bk) for
    # backends whose kernels take block_*= parameters. None = the backend has
    # no tile knob (the XLA paths) and is not tunable. Tuned backends resolve
    # tiles through ops._tile_for: tuning table first, this heuristic second.
    tile_fn: Optional[Callable[..., Tuple[int, int, int]]] = None
    # Whether fn/grouped accept the two extra epilogue arguments
    # (ep_steps, ep_ops) and fuse the op pipeline at the accumulator
    # writeback. Backends without it (the XLA references) get the post-hoc
    # lane in _matmul_impl/_grouped_impl — same numerics, same single cast.
    epilogue_fused: bool = False


_REGISTRY: Dict[str, _Backend] = {}
# Default degradation order when a requested backend's availability probe
# fails: prefer the semantics-preserving interpreter, then the XLA reference.
_FALLBACK_CHAIN = ("pallas_interpret", "xla")


def register_backend(
    name: str,
    fn: BackendFn,
    *,
    available: Union[bool, Callable[[], bool]] = True,
    fallback: Optional[Tuple[str, ...]] = None,
    grad_backend: Optional[str] = None,
    grouped: Optional[GroupedFn] = None,
    grouped_available: Optional[Union[bool, Callable[[], bool]]] = None,
    family: str = "fp",
    tile_fn: Optional[Callable[..., Tuple[int, int, int]]] = None,
    epilogue_fused: bool = False,
) -> None:
    """Register (or replace) a matmul backend.

    ``available`` is either a bool or a zero-arg probe evaluated lazily at
    resolution time (never at import — see :func:`_pallas_compiles`).
    ``fallback`` overrides the default degradation chain for this backend;
    ``grad_backend`` names the backend the custom_vjp backward GEMMs run on
    (quantized backends point it at a full-precision path). ``grouped`` is
    the backend family's grouped/batched GEMM member (``[G,M,K] @ [G,K,N]``)
    served by :func:`grouped_matmul`, with its own optional
    ``grouped_available`` probe (default: available whenever the backend
    is) so a grouped-only failure never disables the 2-D path; ``family``
    names the numerics family (``"fp"``/``"q8"``) a degradation chain must
    preserve. ``tile_fn`` is the block-shape heuristic
    ``fn(m, k, n, elem_bytes=...) -> (bm, bn, bk)`` for kernels with
    ``block_*=`` knobs — registering one makes the backend tunable: its
    tiles resolve through the tuning table (:mod:`repro.tune`) before this
    heuristic. ``epilogue_fused=True`` declares that ``fn``/``grouped``
    accept ``(a, b, c, out_dtype, ep_steps, ep_ops)`` and fuse the epilogue
    pipeline at the accumulator writeback; backends without it are served by
    the numerically-identical post-hoc lane.
    """
    if not callable(fn):
        raise TypeError(f"backend fn for {name!r} is not callable")
    probe = available if callable(available) else (lambda _a=bool(available): _a)
    gprobe = (
        grouped_available
        if grouped_available is None or callable(grouped_available)
        else (lambda _a=bool(grouped_available): _a)
    )
    _REGISTRY[name] = _Backend(
        name, fn, probe, fallback=tuple(fallback) if fallback else None,
        grad_backend=grad_backend, grouped=grouped, grouped_available=gprobe,
        family=family, tile_fn=tile_fn, epilogue_fused=epilogue_fused,
    )


def registered_backends() -> List[str]:
    return list(_REGISTRY)


def available_backends() -> List[str]:
    _load_plugin_backends()  # the quant backends count, even if not yet named
    return [n for n, b in _REGISTRY.items() if _probe_ok(b)]


def grouped_backends() -> List[str]:
    """Names of registered backends that declare a grouped GEMM member
    (regardless of the grouped probe's outcome on this platform)."""
    _load_plugin_backends()
    return [n for n, b in _REGISTRY.items() if b.grouped is not None]


def fallback_chain_of(name: str) -> Tuple[str, ...]:
    """The degradation chain a backend resolves along when unavailable."""
    _load_plugin_backends()
    b = _REGISTRY.get(name)
    if b is None:
        raise ValueError(
            f"unknown matmul backend {name!r}; registered: {registered_backends()}"
        )
    return b.fallback or _FALLBACK_CHAIN


def family_of(name: str) -> str:
    """Numerics family of a backend ("fp", "q8"): what degradation preserves."""
    _load_plugin_backends()
    b = _REGISTRY.get(name)
    if b is None:
        raise ValueError(
            f"unknown matmul backend {name!r}; registered: {registered_backends()}"
        )
    return b.family


def _probe_ok(backend: _Backend) -> bool:
    try:
        return bool(backend.available())
    except Exception:
        return False


def _grouped_ok(backend: _Backend) -> bool:
    """Whether the backend's grouped member is usable (declared + probed)."""
    if backend.grouped is None:
        return False
    if backend.grouped_available is None:
        return True
    try:
        return bool(backend.grouped_available())
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def _pallas_compiles() -> bool:
    """Probe once whether the *compiled* Pallas path lowers here.

    Lazy (first ``auto``/``pallas`` resolution, not import) because touching
    ``jax.devices()`` at import would lock the device count before the
    dry-run can set ``XLA_FLAGS``. A tiny one-tile GEMM is lowered and
    compiled; any failure (no TPU, no Mosaic support) means "unavailable".
    """
    try:
        if jax.devices()[0].platform != "tpu":
            return False
        a = jax.ShapeDtypeStruct((8, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        _kern.opope_gemm.lower(a, b, interpret=False).compile()
        return True
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def _pallas_grouped_compiles() -> bool:
    """Probe once whether the compiled grouped (G, m, n, k) grid lowers here.

    A separate probe from :func:`_pallas_compiles` on purpose: a platform
    where only the grouped grid fails keeps its compiled 2-D kernels for
    every dense matmul and degrades ``grouped_matmul`` alone (with the
    resolver's warning) instead of demoting the whole backend to ``xla``.
    """
    try:
        if not _pallas_compiles():
            return False
        ag = jax.ShapeDtypeStruct((2, 8, 128), jnp.float32)
        bg = jax.ShapeDtypeStruct((2, 128, 128), jnp.float32)
        _gkern.opope_gemm_grouped.lower(ag, bg, interpret=False).compile()
        return True
    except Exception:
        return False


# Cap on the per-(backend, family, M, N, K, G, dtype) tile-selection memo. A
# training run sees a handful of layer shapes, but a long-lived serving
# process sees an unbounded stream of (prompt-bucket x layer) shapes; LRU
# eviction keeps the memo from growing without limit while still making
# repeated shapes free.
_TILE_CACHE_CAP = 512

# Lazily loaded tuning-table state (repro.tune.table.TuningTable or None).
# Loaded once on the first tile resolution, dropped by clear_tile_cache() —
# so a test (or a process that just ran the tuner) can point REPRO_TUNE_TABLE
# somewhere else and have the next resolution pick it up.
_TUNE_STATE: Dict[str, object] = {"loaded": False, "table": None}


def _tuning_table():
    if not _TUNE_STATE["loaded"]:
        _TUNE_STATE["loaded"] = True
        try:
            from repro.tune.table import load_active_table

            _TUNE_STATE["table"] = load_active_table()
        except Exception:  # tune package absent/broken: heuristics only
            _TUNE_STATE["table"] = None
    return _TUNE_STATE["table"]


def _tuned_tile(
    backend: Optional[str], family: str, m: int, k: int, n: int,
    groups: int, itemsize: int,
) -> Optional[Tuple[int, int, int]]:
    """Tuning-table lookup, validated against the kernel's hard constraints.

    A table entry is untrusted input (hand-edited file, stale kernel
    revision): an illegal block shape falls back to the heuristic with a
    warning instead of reaching a ``pallas_call``.
    """
    if backend is None:
        return None
    b = _REGISTRY.get(backend)
    if b is None or b.tile_fn is None:
        return None  # no tile knob: a table entry for this name is inert
    table = _tuning_table()
    if table is None:
        return None
    tile = table.lookup(
        backend=backend, shape_family=family, m=m, k=k, n=n, g=groups,
        itemsize=itemsize,
    )
    if tile is None:
        return None
    m_align = 32 if b.family == "q8" else 8
    if not _kern.validate_block_shape(
        tile[0], tile[1], tile[2], elem_bytes=itemsize, m_align=m_align
    ):
        warnings.warn(
            f"tuning-table entry {tile} for backend {backend!r} "
            f"({family} {m}x{k}x{n}, g={groups}) violates kernel constraints; "
            "using the heuristic instead",
            RuntimeWarning,
            stacklevel=3,
        )
        return None
    return tile


# Resettable tile-lookup telemetry (distinct from the lru memo's own
# CacheInfo, whose hit/miss totals cannot be zeroed without dropping the
# memo): hits/misses feed the ``tile.lookups`` counter, the consecutive-miss
# streak feeds the ``on_miss_streak`` auto-retune seam (ROADMAP item 4).
_TILE_STATS_LOCK = threading.Lock()
_TILE_STATS = {"hits": 0, "misses": 0, "streak": 0}
# callback fn(key, streak) fired when the miss streak reaches the threshold
# (and again at each further multiple while it persists). ``None`` routes to
# the default repro.tune hook, which logs a "retune candidate" event.
_MISS_STREAK_HOOK: Dict[str, object] = {"fn": None, "threshold": 8}

# The key a miss-streak callback receives: everything the tuner needs to
# reproduce (and tune) the shape that keeps missing the memo/table.
TileKey = Tuple[Optional[str], str, int, int, int, int, int]


def on_miss_streak(
    callback: Optional[Callable[[TileKey, int], None]] = None,
    *,
    threshold: int = 8,
) -> None:
    """Register the sustained tile-cache-miss callback (the auto-retune seam).

    ``callback(key, streak)`` fires when ``threshold`` consecutive tile
    resolutions miss the memo — the signature of a long-lived process seeing
    a shape stream the tuning table doesn't cover — and again at every
    further multiple while the streak persists. ``key`` is ``(backend,
    shape_family, m, k, n, groups, itemsize)``. ``callback=None`` restores
    the default hook (``repro.tune.retune``: count + log the retune
    candidate, never retune implicitly). Exceptions in the callback are
    swallowed: a telemetry hook must never break tile resolution.
    """
    if threshold < 1:
        raise ValueError("miss-streak threshold must be >= 1")
    _MISS_STREAK_HOOK["fn"] = callback
    _MISS_STREAK_HOOK["threshold"] = int(threshold)


def _default_miss_streak(key: TileKey, streak: int) -> None:
    try:
        from repro.tune.retune import retune_candidate
    except Exception:
        return
    retune_candidate(key, streak)


def _note_tile_lookup(missed: bool, key: TileKey) -> None:
    with _TILE_STATS_LOCK:
        if missed:
            _TILE_STATS["misses"] += 1
            _TILE_STATS["streak"] += 1
            streak = _TILE_STATS["streak"]
        else:
            _TILE_STATS["hits"] += 1
            _TILE_STATS["streak"] = 0
            streak = 0
    if _obs.enabled():
        _obs.counter(
            "tile.lookups", result="miss" if missed else "hit"
        ).inc()
    if missed:
        thr = int(_MISS_STREAK_HOOK["threshold"])  # type: ignore[arg-type]
        if streak >= thr and streak % thr == 0:
            fn = _MISS_STREAK_HOOK["fn"] or _default_miss_streak
            try:
                fn(key, streak)  # type: ignore[operator]
            except Exception:
                pass


# The drift sibling of the miss-streak seam (ROADMAP item 4): on_miss_streak
# sees shapes the tuning table MISSES; on_util_gap sees shapes the table
# COVERS whose live roofline fraction (obs.attr attribution) keeps landing
# below a threshold — a tuned entry gone stale (new jax version, different
# device, workload drift). Same contract: fires at streak multiples,
# exceptions swallowed, None restores the default repro.tune hook.
_UTIL_GAP_HOOK: Dict[str, object] = {"fn": None, "threshold": 0.5, "streak": 4}
_UTIL_STREAKS: Dict[TileKey, int] = {}


def on_util_gap(
    callback: Optional[Callable[[TileKey, int, float], None]] = None,
    *,
    threshold: float = 0.5,
    streak: int = 4,
) -> None:
    """Register the tuned-but-underperforming callback (the drift-retune seam).

    Fed by :func:`repro.obs.attr.observe_step`: every attributed execution
    of a *tuned* GEMM class scores a roofline fraction; when a key's
    fraction falls below ``threshold`` x its own best observed fraction for
    ``streak`` consecutive observations, ``callback(key, streak_len,
    fraction)`` fires (and again at every further multiple while the gap
    persists). Relative-to-own-best, not absolute: a CPU run scores ~1e-4
    of the TPU-v5e roofline while being perfectly healthy — drift is a
    shape performing worse than *itself*, which is exactly the signature of
    a stale tuning-table entry. ``callback=None`` restores the default hook
    (``repro.tune.retune.retune_candidate(..., reason="util_gap")``: count +
    log, never retune implicitly). Exceptions in the callback are swallowed.
    Heuristic-tiled observations reset the streak only — an untuned shape is
    the miss-streak seam's business, not this one's.
    """
    if not (0.0 < threshold <= 1.0):
        raise ValueError("util-gap threshold must be in (0, 1]")
    if streak < 1:
        raise ValueError("util-gap streak must be >= 1")
    _UTIL_GAP_HOOK["fn"] = callback
    _UTIL_GAP_HOOK["threshold"] = float(threshold)
    _UTIL_GAP_HOOK["streak"] = int(streak)


def _default_util_gap(key: TileKey, streak: int, fraction: float) -> None:
    try:
        from repro.tune.retune import retune_candidate
    except Exception:
        return
    retune_candidate(key, streak, reason="util_gap")


# Best roofline fraction ever observed per tuned key: the self-relative
# baseline the gap test compares against.
_UTIL_BEST: Dict[TileKey, float] = {}


def _note_util_observation(key: TileKey, fraction: float, source: str) -> None:
    """One attributed utilization observation for ``key`` (obs.attr calls
    this). Only tuned tiles advance the gap streak."""
    if source != "tuned":
        _UTIL_STREAKS.pop(key, None)
        return
    with _TILE_STATS_LOCK:
        best = _UTIL_BEST.get(key, 0.0)
        if fraction > best:
            _UTIL_BEST[key] = fraction
            best = fraction
        thr = float(_UTIL_GAP_HOOK["threshold"])  # type: ignore[arg-type]
        if best > 0.0 and fraction < thr * best:
            streak = _UTIL_STREAKS.get(key, 0) + 1
            _UTIL_STREAKS[key] = streak
        else:
            _UTIL_STREAKS.pop(key, None)
            return
    if _obs.enabled():
        _obs.counter("gemm.util_gap_observations").inc()
    need = int(_UTIL_GAP_HOOK["streak"])  # type: ignore[arg-type]
    if streak >= need and streak % need == 0:
        fn = _UTIL_GAP_HOOK["fn"] or _default_util_gap
        try:
            fn(key, streak, fraction)  # type: ignore[operator]
        except Exception:
            pass


class _TileResolver:
    """The memoized block-shape resolver behind ``ops._tile_for``.

    Drop-in for the plain ``lru_cache`` it replaces (``cache_info`` /
    ``cache_clear`` keep their semantics) plus lookup telemetry: every call
    notes hit-or-miss into the resettable stats + the ``tile.lookups``
    counter and advances the miss streak (:func:`on_miss_streak`).

    The memo key carries the shape family and group count (a grouped GEMM
    must never share a memo slot — or a tuning-table entry — with a dense
    GEMM of the same (M, K, N): their pipelining behaviour differs) and the
    backend name, because tuned winners are measured per backend.
    Resolution order: tuned table first, the backend's ``tile_fn``
    heuristic second.
    """

    def __init__(self, maxsize: int) -> None:
        self._cached = functools.lru_cache(maxsize=maxsize)(self._resolve)

    @staticmethod
    def _resolve(
        m: int, k: int, n: int, itemsize: int, family: str, groups: int,
        backend: Optional[str],
    ) -> Tuple[int, int, int]:
        tuned = _tuned_tile(backend, family, m, k, n, groups, itemsize)
        if tuned is not None:
            return tuned
        b = _REGISTRY.get(backend) if backend else None
        tile_fn = b.tile_fn if (b is not None and b.tile_fn is not None) else (
            _kern.default_block_shape
        )
        return tile_fn(m, k, n, elem_bytes=itemsize)

    def __call__(
        self,
        m: int,
        k: int,
        n: int,
        itemsize: int,
        family: str = "dense",
        groups: int = 0,
        backend: Optional[str] = None,
    ) -> Tuple[int, int, int]:
        before = self._cached.cache_info().misses
        tile = self._cached(m, k, n, itemsize, family, groups, backend)
        missed = self._cached.cache_info().misses != before
        _note_tile_lookup(
            missed, (backend, family, m, k, n, groups, itemsize)
        )
        return tile

    def cache_info(self):
        return self._cached.cache_info()

    def cache_clear(self) -> None:
        self._cached.cache_clear()


_tile_for = _TileResolver(maxsize=_TILE_CACHE_CAP)


def tile_cache_info():
    """CacheInfo for the tile-selection memo (currsize never exceeds the cap).

    Lifetime totals of the underlying LRU — for *resettable* counters (the
    cross-test-bleed-safe surface) use :func:`tile_cache_stats`."""
    return _tile_for.cache_info()


def tile_cache_stats() -> Dict[str, int]:
    """Resettable tile-lookup stats: ``hits``/``misses`` since the last
    :func:`reset_tile_cache_stats`, the current consecutive ``miss_streak``,
    and the memo's ``currsize``/``maxsize``."""
    info = _tile_for.cache_info()
    with _TILE_STATS_LOCK:
        return {
            "hits": _TILE_STATS["hits"],
            "misses": _TILE_STATS["misses"],
            "miss_streak": _TILE_STATS["streak"],
            "currsize": info.currsize,
            "maxsize": info.maxsize,
        }


def reset_tile_cache_stats() -> None:
    """Zero the resettable lookup counters and the miss streak WITHOUT
    touching the memo itself (tests call this between cases so counts can't
    leak across suite order; warm tiles stay warm)."""
    with _TILE_STATS_LOCK:
        _TILE_STATS["hits"] = 0
        _TILE_STATS["misses"] = 0
        _TILE_STATS["streak"] = 0
        _UTIL_STREAKS.clear()
        _UTIL_BEST.clear()


def clear_tile_cache() -> None:
    """Drop the tile memo, the epilogue-fusion memo AND the loaded
    tuning-table state: the next tile resolution re-reads the table from
    ``REPRO_TUNE_TABLE`` / the default location. The miss streak resets too
    (post-clear misses are expected, not a retune signal)."""
    _tile_for.cache_clear()
    _fusion_for.cache_clear()
    _TUNE_STATE["loaded"] = False
    _TUNE_STATE["table"] = None
    with _TILE_STATS_LOCK:
        _TILE_STATS["streak"] = 0


def tunable_backends() -> List[str]:
    """Registered backends with a tile knob (a ``tile_fn``): the set the
    ``repro-tune`` CLI offers to tune."""
    _load_plugin_backends()
    return [n for n, b in _REGISTRY.items() if b.tile_fn is not None]


def _tile_itemsize(backend: str, dtype) -> int:
    """Element width the backend's tile selection keys on: q8 backends
    stream int8 panels whatever the caller-visible dtype."""
    b = _REGISTRY.get(backend)
    if b is not None and b.family == "q8":
        return 1
    return jnp.dtype(dtype).itemsize


def tile_for(
    backend: str, m: int, k: int, n: int, *, groups: int = 0,
    dtype=jnp.float32,
) -> Tuple[int, int, int]:
    """The (bm, bn, bk) block shape ``backend`` would run this GEMM with
    (``groups=0`` = the dense 2-D family, ``groups>0`` = the grouped family
    where (m, k, n) is the per-group shape)."""
    _load_plugin_backends()
    family = "grouped" if groups else "dense"
    return _tile_for(
        m, k, n, _tile_itemsize(backend, dtype),
        family=family, groups=groups, backend=backend,
    )


def tile_source(
    backend: str, m: int, k: int, n: int, *, groups: int = 0,
    dtype=jnp.float32,
) -> str:
    """``"tuned"`` if the tuning table decides this shape's blocks,
    ``"heuristic"`` if the backend's ``tile_fn`` does (including backends
    with no tile knob at all — the XLA paths always report heuristic)."""
    _load_plugin_backends()
    family = "grouped" if groups else "dense"
    tuned = _tuned_tile(
        backend, family, m, k, n, groups, _tile_itemsize(backend, dtype)
    )
    return "tuned" if tuned is not None else "heuristic"


def heuristic_tile(
    backend: str, m: int, k: int, n: int, *, dtype=jnp.float32
) -> Tuple[int, int, int]:
    """The backend's ``tile_fn`` choice, bypassing any loaded tuning table —
    the baseline column of ``BENCH_kernels.json``."""
    _load_plugin_backends()
    b = _REGISTRY.get(backend)
    itemsize = _tile_itemsize(backend, dtype)
    fn = b.tile_fn if (b is not None and b.tile_fn is not None) else (
        _kern.default_block_shape
    )
    return fn(m, k, n, elem_bytes=itemsize)


# ---------------------------------------------------------------------------
# Epilogue fusion decision (tuned verdict first, fuse-by-default second)
# ---------------------------------------------------------------------------


def epilogue_capable(name: str) -> bool:
    """Whether ``name``'s kernels fuse epilogues at the accumulator writeback
    (``epilogue_fused`` registration). Incapable backends still serve every
    ``epilogue=`` request through the post-hoc lane — this only reports
    *where* the pipeline runs."""
    _load_plugin_backends()
    b = _REGISTRY.get(name)
    if b is None:
        raise ValueError(
            f"unknown matmul backend {name!r}; registered: {registered_backends()}"
        )
    return b.epilogue_fused


@functools.lru_cache(maxsize=_TILE_CACHE_CAP)
def _fusion_for(
    m: int, k: int, n: int, itemsize: int,
    family: str = "dense", groups: int = 0, backend: Optional[str] = None,
) -> bool:
    """Memoized per-shape fuse-or-not verdict for an epilogue-capable backend.

    The tuning table's measured decision (``TuneEntry.fuse_epilogue``, written
    by :mod:`repro.tune` when it times fused vs post-hoc) wins; with no entry
    the default is to fuse — the writeback pass is free, the post-hoc pass is
    an extra HBM round trip, so fusion only loses when the epilogue operands'
    streaming perturbs the kernel's pipelining (exactly what the tuner
    measures).
    """
    table = _tuning_table()
    if table is not None:
        verdict = table.lookup_fusion(
            backend=backend, shape_family=family, m=m, k=k, n=n, g=groups,
            itemsize=itemsize,
        )
        if verdict is not None:
            return bool(verdict)
    return True


def fusion_source(
    backend: str, m: int, k: int, n: int, *, groups: int = 0,
    dtype=jnp.float32,
) -> str:
    """``"tuned"`` if the tuning table decides fused-vs-post-hoc for this
    shape on this backend, ``"default"`` if the fuse-by-default rule does
    (including backends with no fused writeback at all)."""
    _load_plugin_backends()
    family = "grouped" if groups else "dense"
    table = _tuning_table()
    if table is not None:
        verdict = table.lookup_fusion(
            backend=backend, shape_family=family, m=m, k=k, n=n, g=groups,
            itemsize=_tile_itemsize(backend, dtype),
        )
        if verdict is not None:
            return "tuned"
    return "default"


# ---------------------------------------------------------------------------
# Shape capture (the tuner's workload-harvest hook)
# ---------------------------------------------------------------------------

# When capture is active, every matmul/grouped_matmul records
# (shape_family, m, k, n, g, dtype_name) at trace time. Harvesting a model's
# GEMM workload is then one jax.eval_shape of its loss/prefill under
# capture_shapes() — zero FLOPs, exact shapes (repro.tune.capture).
_SHAPE_CAPTURE: List[list] = []


class capture_shapes:
    """Context manager recording every GEMM shape routed through the registry.

    Yields a list of ``(shape_family, m, k, n, g, dtype_name)`` tuples in
    call order (duplicates included — callers dedupe). Nestable; tracing
    (``jax.eval_shape`` / ``jit``) triggers the records, so no compute is
    needed to harvest a workload.
    """

    def __enter__(self):
        self._records: List[Tuple[str, int, int, int, int, str]] = []
        _SHAPE_CAPTURE.append(self._records)
        return self._records

    def __exit__(self, *exc):
        # Remove by identity, not equality: two nested captures with equal
        # contents (e.g. both empty) must each detach their OWN list.
        for i in range(len(_SHAPE_CAPTURE) - 1, -1, -1):
            if _SHAPE_CAPTURE[i] is self._records:
                del _SHAPE_CAPTURE[i]
                break
        return False


def _record_shape(family: str, m: int, k: int, n: int, g: int, dtype) -> None:
    if _SHAPE_CAPTURE:
        rec = (family, int(m), int(k), int(n), int(g), jnp.dtype(dtype).name)
        for records in _SHAPE_CAPTURE:
            records.append(rec)


def _note_gemm_call(
    shape_family: str, backend: str, m: int, k: int, n: int, groups: int,
    dtype, b_dtype=None, out_dtype=None,
) -> None:
    """Count one GEMM entry-point call into ``gemm.calls``.

    Labels carry the resolved backend, its numerics family, the shape
    family (dense/grouped) and — the introspection the autotuner feeds on —
    whether the tile and the fusion verdict came from the tuned table or
    the heuristic/default. When an :class:`repro.obs.attr.capture_gemms`
    bracket is active, the same facts (plus the actual operand dtypes, for
    honest byte accounting) are appended as a :class:`GemmRecord` so a timed
    span owner can attribute its measured step time. Host-side only: inside
    ``jit`` this runs once at trace time, never per step."""
    if not _obs.enabled():
        return
    b = _REGISTRY.get(backend)
    itemsize = jnp.dtype(dtype).itemsize
    tile = "tuned" if _tuned_tile(
        backend, shape_family, m, k, n, groups, itemsize
    ) is not None else "heuristic"
    fusion = "none"
    if b is not None and b.epilogue_fused:
        table = _tuning_table()
        verdict = None
        if table is not None:
            verdict = table.lookup_fusion(
                backend=backend, shape_family=shape_family, m=m, k=k, n=n,
                g=groups, itemsize=itemsize,
            )
        fusion = "tuned" if verdict is not None else "default"
    _obs.counter(
        "gemm.calls",
        backend=backend,
        family=b.family if b is not None else "?",
        shape=shape_family,
        tile=tile,
        fusion=fusion,
    ).inc()
    if _obs.attr.capturing():
        _obs.attr.record_call(_obs.attr.GemmRecord(
            shape_family=shape_family,
            backend=backend,
            family=b.family if b is not None else "?",
            m=int(m), k=int(k), n=int(n), g=int(groups),
            a_dtype=jnp.dtype(dtype).name,
            b_dtype=jnp.dtype(b_dtype if b_dtype is not None else dtype).name,
            out_dtype=jnp.dtype(
                out_dtype if out_dtype is not None else dtype
            ).name,
            tile_source=tile,
            tile_key=(
                backend, shape_family, int(m), int(k), int(n), int(groups),
                _tile_itemsize(backend, dtype),
            ),
        ))


def _maybe_audit_gemm(kind, backend, out, ref_fn, m, k, n, g=0):
    """Shadow-audit hook for quantized-family entry-point calls.

    Cheap rejections first (fp family, tracer output, metrics off) so the
    non-audited hot path pays a couple of host-side branches; the sampling
    gate itself lives in :mod:`repro.obs.audit`. Runs only on concrete
    outputs — inside ``jit`` the output is a tracer and the call is a no-op,
    which is what keeps the compiled HLO bit-identical with auditing on or
    off (the PR 7 zero-cost contract)."""
    if not _obs.enabled():
        return
    fam = family_of(backend)
    if fam == "fp":
        return
    if isinstance(out, jax.core.Tracer):
        return
    _obs.audit.maybe_audit_gemm(
        kind=kind, backend=backend, family=fam, out=out, ref_fn=ref_fn,
        m=int(m), k=int(k), n=int(n), g=int(g),
    )


def _note_degradation(
    requested: str, resolved: str, reason: str, hop: int
) -> None:
    """Telemetry twin of the degradation warning: a counter (labelled by
    requested/resolved backend and reason) plus a structured event carrying
    the fallback-chain hop index."""
    if not _obs.enabled():
        return
    _obs.counter(
        "gemm.degradations",
        requested=requested,
        resolved=resolved,
        reason=reason,
    ).inc()
    _obs.event(
        "degradation",
        requested=requested,
        resolved=resolved,
        reason=reason,
        hop=hop,
    )


def _pallas_fn(interpret: bool) -> BackendFn:
    name = "pallas_interpret" if interpret else "pallas"

    def run(a, b, c, out_dtype, ep_steps=(), ep_ops=()):
        bm, bn, bk = _tile_for(
            a.shape[0], a.shape[1], b.shape[1], jnp.dtype(a.dtype).itemsize,
            family="dense", backend=name,
        )
        return _kern.opope_gemm(
            a, b, c,
            block_m=bm, block_n=bn, block_k=bk,
            out_dtype=out_dtype, interpret=interpret,
            epilogue=ep_steps, epilogue_operands=ep_ops,
        )

    return run


def _pallas_grouped_fn(interpret: bool) -> GroupedFn:
    name = "pallas_interpret" if interpret else "pallas"

    def run(a, b, c, out_dtype, ep_steps=(), ep_ops=()):
        # Every group shares (M, K, N): tile selection is the single-group
        # choice, through the same bounded memo as the 2-D path — but under
        # the grouped family key (and group count), so a tuned grouped entry
        # never collides with a dense entry of the same per-group shape.
        bm, bn, bk = _tile_for(
            a.shape[1], a.shape[2], b.shape[2], jnp.dtype(a.dtype).itemsize,
            family="grouped", groups=a.shape[0], backend=name,
        )
        return _gkern.opope_gemm_grouped(
            a, b, c,
            block_m=bm, block_n=bn, block_k=bk,
            out_dtype=out_dtype, interpret=interpret,
            epilogue=ep_steps, epilogue_operands=ep_ops,
        )

    return run


def _xla_fn(a, b, c, out_dtype):
    return _ref.reference_matmul(a, b, c, out_dtype=out_dtype)


def _xla_grouped_fn(a, b, c, out_dtype):
    return _ref.reference_grouped_matmul(a, b, c, out_dtype=out_dtype)


register_backend(
    "pallas", _pallas_fn(interpret=False), available=_pallas_compiles,
    grouped=_pallas_grouped_fn(interpret=False),
    grouped_available=_pallas_grouped_compiles,
    tile_fn=_kern.default_block_shape,
    epilogue_fused=True,
)
register_backend(
    "pallas_interpret", _pallas_fn(interpret=True),
    grouped=_pallas_grouped_fn(interpret=True),
    tile_fn=_kern.default_block_shape,
    epilogue_fused=True,
)
register_backend("xla", _xla_fn, grouped=_xla_grouped_fn)


@functools.lru_cache(maxsize=None)
def _load_plugin_backends() -> None:
    """One-shot lazy import of packages that register extra backends.

    Resolving ``xla_q8``/``pallas_q8`` must not require callers to import
    :mod:`repro.quant` themselves; ``kernels`` must also not import ``quant``
    at module load (quant builds *on* the kernel layer). So the first
    resolution of an unknown name triggers the import here, once.
    """
    try:
        import repro.quant  # noqa: F401  (registers its backends on import)
    except ImportError:
        pass


def resolve_backend(name: Optional[str] = None) -> str:
    """Resolve a backend request to the name of an available backend.

    ``None`` means the process default; ``"auto"`` picks ``pallas`` when the
    compiled path lowers here, else ``xla``. An unavailable explicit request
    degrades along the backend's fallback chain (default
    ``pallas_interpret`` -> ``xla``) with a warning — but only onto members
    of the same numerics family: rather than silently change quantization
    behaviour, resolution raises.
    """
    name = name or _DEFAULT_BACKEND
    if name == "auto":
        # Consult the registry's probe (not _pallas_compiles directly) so a
        # re-registered "pallas" backend brings its own availability rule.
        return "pallas" if _probe_ok(_REGISTRY["pallas"]) else "xla"
    backend = _REGISTRY.get(name)
    if backend is None:
        _load_plugin_backends()
        backend = _REGISTRY.get(name)
    if backend is None:
        raise ValueError(
            f"unknown matmul backend {name!r}; registered: {registered_backends()}"
        )
    if _probe_ok(backend):
        return name
    for hop, fallback in enumerate(backend.fallback or _FALLBACK_CHAIN, 1):
        fb = _REGISTRY.get(fallback)
        # The family guard makes "degradation never changes numerics" a
        # runtime invariant, not just a registration convention: a backend
        # that inherited the default (fp) chain can never land a q8 request
        # on a full-precision engine — it raises instead.
        if (
            fallback != name
            and fb is not None
            and fb.family == backend.family
            and _probe_ok(fb)
        ):
            warnings.warn(
                f"matmul backend {name!r} unavailable on this platform; "
                f"degrading to {fallback!r}",
                RuntimeWarning,
                stacklevel=2,
            )
            _note_degradation(name, fallback, "backend_unavailable", hop)
            return fallback
    raise RuntimeError(f"no available matmul backend (requested {name!r})")


def resolve_grouped_backend(name: Optional[str] = None) -> str:
    """Resolve a backend request to one that has a grouped GEMM member.

    The request first resolves exactly like :func:`resolve_backend`
    (availability probes, fallback chains, the ``auto`` rule); if the
    resolved backend declares no grouped implementation, resolution continues
    along its fallback chain — with the same degradation warning — to the
    first available backend that does. Chains preserve the numerics family,
    so a grouped request never silently changes quantization behaviour.
    """
    resolved = resolve_backend(name)
    backend = _REGISTRY[resolved]
    if _grouped_ok(backend):
        return resolved
    for hop, fallback in enumerate(backend.fallback or _FALLBACK_CHAIN, 1):
        fb = _REGISTRY.get(fallback)
        # Same family guard as resolve_backend: a q8 backend missing its
        # grouped member raises rather than silently running grouped GEMMs
        # full-precision through the default (fp) chain.
        if (
            fallback != resolved
            and fb is not None
            and _grouped_ok(fb)
            and fb.family == backend.family
            and _probe_ok(fb)
        ):
            warnings.warn(
                f"matmul backend {resolved!r} has no usable grouped GEMM "
                f"member; degrading to {fallback!r}",
                RuntimeWarning,
                stacklevel=2,
            )
            _note_degradation(resolved, fallback, "no_grouped_member", hop)
            return fallback
    raise RuntimeError(
        f"no available grouped matmul backend (requested {name or resolved!r})"
    )


def grad_backend_of(name: str) -> str:
    """Backend the backward GEMMs of ``name`` run on (itself by default)."""
    b = _REGISTRY.get(name)
    return b.grad_backend if b is not None and b.grad_backend else name


def default_backend() -> str:
    return resolve_backend(None)


def set_default_backend(name: str) -> None:
    """Override backend globally (any registered name, or 'auto')."""
    global _DEFAULT_BACKEND
    if name != "auto" and name not in _REGISTRY:
        _load_plugin_backends()
    if name != "auto" and name not in _REGISTRY:
        raise ValueError(
            f"unknown matmul backend {name!r}; registered: {registered_backends()}"
        )
    _DEFAULT_BACKEND = name


# --------------------------------------------------------------------------
# matmul / linear entry points (custom_vjp keeps the backward in-dataflow)
# --------------------------------------------------------------------------


def _matmul_impl(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array],
    backend: str,
    out_dtype,
    ep_steps: Tuple[str, ...] = (),
    ep_ops: Tuple[jax.Array, ...] = (),
) -> jax.Array:
    be = _REGISTRY[backend]
    if not ep_steps:
        return be.fn(a, b, c, out_dtype)
    aq = a.q if hasattr(a, "q") else a  # pre-quantized A: shapes live on .q
    if be.epilogue_fused and _fusion_for(
        aq.shape[0], aq.shape[1], b.shape[1], _tile_itemsize(backend, aq.dtype),
        family="dense", backend=backend,
    ):
        return be.fn(a, b, c, out_dtype, ep_steps, ep_ops)
    # Post-hoc lane: fp32 accumulator out of the backend, the same op
    # pipeline, the same single final cast — numerically identical to the
    # fused writeback (fp32 -> fp32 "cast" is exact), and applied for ANY
    # resolved backend, so fallback degradation can never drop or
    # double-apply a requested epilogue.
    acc = be.fn(a, b, c, jnp.float32)
    return _epi.apply_epilogue(acc, ep_steps, ep_ops).astype(out_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _matmul(a, b, c, backend, out_dtype):
    return _matmul_impl(a, b, c, backend, out_dtype)


def _matmul_fwd(a, b, c, backend, out_dtype):
    return _matmul_impl(a, b, c, backend, out_dtype), (a, b)


def _matmul_bwd(backend, out_dtype, res, g):
    a, b = res
    # Backward = two more O-POPE GEMMs in the same dataflow; gradients are
    # accumulated in fp32 and cast back to the operand dtypes. Quantized
    # forwards run their backward on their registered full-precision
    # grad_backend (gradients always stay FP).
    backend = grad_backend_of(backend)
    da = _matmul_impl(g, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g, None, backend, b.dtype)
    dc = g  # c enters the accumulator linearly
    return da, db, dc


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
    epilogue=None,
) -> jax.Array:
    """``a @ b (+ c)`` with O-POPE semantics; a: [..., K], b: [K, N].

    Leading batch dims of ``a`` are flattened into M (the engine sees one tall
    GEMM — exactly how the paper maps ML layers onto the engine, Table I).
    ``c`` is either a full C operand matching ``a``'s batch dims x N, or a
    1-D ``[N]`` bias row broadcast inside the backend at the accumulator
    preload point (never materialized as an [M, N] array).

    ``epilogue`` is a pipeline of registered post-ops — a name (``"silu"``),
    a ``(name, operand)`` pair (``("residual", x)``), or a sequence of either
    (:mod:`repro.kernels.epilogue`) — applied to the fp32 accumulator before
    the single final cast: inside the kernel on epilogue-capable backends
    (per the tuner's fused-vs-post-hoc verdict), post-hoc on the rest, with
    identical numerics either way. A ``c`` operand passed alongside an
    epilogue is folded in as the pipeline's first step.

    ``a`` may also be a pre-quantized activation (anything with ``.q`` /
    ``.scale``, e.g. ``quant.QuantizedTensor`` — the product of a
    ``requant_int8`` epilogue upstream) on a q8-family backend: the backend
    skips its A-quantization pass and consumes the int8 values directly.
    This is a serving-only lane (no custom_vjp).
    """
    pre_q = hasattr(a, "q") and hasattr(a, "scale")
    arr = a.q if pre_q else a
    # Pre-quantized A defaults to fp32 output (the int8 storage dtype of the
    # input is not a meaningful default for the dequantized result).
    out_dtype = jnp.dtype(out_dtype or (jnp.float32 if pre_q else arr.dtype))
    backend = resolve_backend(backend)
    batch_shape = arr.shape[:-1]
    m = 1
    for d in batch_shape:
        m *= d
    _record_shape("dense", m, arr.shape[-1], b.shape[-1], 0, arr.dtype)
    _note_gemm_call(
        "dense", backend, m, arr.shape[-1], b.shape[-1], 0, arr.dtype,
        b_dtype=b.dtype, out_dtype=out_dtype,
    )
    n = b.shape[-1]
    steps, raw_ops = _epi.normalize_epilogue(epilogue)
    if steps and c is not None:
        # Fold C into the pipeline's head: C enters the accumulator linearly,
        # so preload-then-epilogue == bias/residual-step-then-rest.
        if c.ndim == 1:
            steps, raw_ops = ("bias",) + steps, (c,) + raw_ops
        else:
            steps, raw_ops = ("residual",) + steps, (c,) + raw_ops
        c = None

    if pre_q:
        if family_of(backend) != "q8":
            raise ValueError(
                f"pre-quantized activations need a q8-family backend; "
                f"{backend!r} is family {family_of(backend)!r}"
            )
        scale = jnp.asarray(a.scale)
        a2 = type(a)(
            arr.reshape(m, arr.shape[-1]),
            scale.reshape(m, 1) if scale.size == m else scale.reshape(1, 1),
        )
        ep_ops = _epi.canonicalize_operands(steps, raw_ops, n=n, m=m)
        out = _matmul_impl(a2, b, c, backend, out_dtype, steps, ep_ops)
        return out.reshape(*batch_shape, n)

    a2 = arr.reshape(m, arr.shape[-1])
    if steps:
        ep_ops = _epi.canonicalize_operands(steps, raw_ops, n=n, m=m)
        out = _matmul_ep(a2, b, ep_ops, backend, out_dtype, steps)
        ref = lambda: _matmul_impl(  # noqa: E731
            a2, b, None, grad_backend_of(backend), out_dtype, steps, ep_ops)
    elif c is None:
        out = _matmul_nc(a2, b, backend, out_dtype)
        ref = lambda: _matmul_impl(  # noqa: E731
            a2, b, None, grad_backend_of(backend), out_dtype)
    elif c.ndim == 1:
        out = _matmul_bias(a2, b, c, backend, out_dtype)
        bias = c
        ref = lambda: _matmul_impl(  # noqa: E731
            a2, b, bias, grad_backend_of(backend), out_dtype)
    else:
        c2 = c.reshape(m, n)
        out = _matmul(a2, b, c2, backend, out_dtype)
        ref = lambda: _matmul_impl(  # noqa: E731
            a2, b, c2, grad_backend_of(backend), out_dtype)
    _maybe_audit_gemm("dense", backend, out, ref, m, arr.shape[-1], n)
    return out.reshape(*batch_shape, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul_nc(a, b, backend, out_dtype):
    return _matmul_impl(a, b, None, backend, out_dtype)


def _matmul_nc_fwd(a, b, backend, out_dtype):
    return _matmul_impl(a, b, None, backend, out_dtype), (a, b)


def _matmul_nc_bwd(backend, out_dtype, res, g):
    a, b = res
    backend = grad_backend_of(backend)
    da = _matmul_impl(g, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g, None, backend, b.dtype)
    return da, db


_matmul_nc.defvjp(_matmul_nc_fwd, _matmul_nc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _matmul_bias(a, b, bias, backend, out_dtype):
    return _matmul_impl(a, b, bias, backend, out_dtype)


def _matmul_bias_fwd(a, b, bias, backend, out_dtype):
    return _matmul_impl(a, b, bias, backend, out_dtype), (a, b)


def _matmul_bias_bwd(backend, out_dtype, res, g):
    a, b = res
    backend = grad_backend_of(backend)
    da = _matmul_impl(g, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g, None, backend, b.dtype)
    dbias = g.sum(axis=0)  # the bias row enters every accumulator row once
    return da, db, dbias


_matmul_bias.defvjp(_matmul_bias_fwd, _matmul_bias_bwd)


# One custom_vjp covers every epilogue'd dense matmul: a C operand is folded
# into the pipeline as its first step by matmul() ("bias" for a [N] row,
# "residual" for a full operand — numerically identical, C enters the
# accumulator linearly), so no (c x epilogue) wrapper matrix is needed.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _matmul_ep(a, b, ep_ops, backend, out_dtype, ep_steps):
    return _matmul_impl(a, b, None, backend, out_dtype, ep_steps, ep_ops)


def _matmul_ep_fwd(a, b, ep_ops, backend, out_dtype, ep_steps):
    out = _matmul_impl(a, b, None, backend, out_dtype, ep_steps, ep_ops)
    return out, (a, b, ep_ops)


def _matmul_ep_bwd(backend, out_dtype, ep_steps, res, g):
    a, b, ep_ops = res
    backend = grad_backend_of(backend)
    # The fused forward never materializes the pre-epilogue accumulator, so
    # the backward recomputes it (one extra GEMM, fp32) — the standard
    # rematerialization trade for keeping the forward single-pass. Then the
    # epilogue pipeline backpropagates (STE/clip masks and broadcast
    # reductions live in epilogue_vjp) and the usual two transposed GEMMs
    # run on the fp32 cotangent of the accumulator.
    acc = _matmul_impl(a, b, None, backend, jnp.float32)
    g_acc, d_ops = _epi.epilogue_vjp(ep_steps, ep_ops, acc, g)
    da = _matmul_impl(g_acc, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g_acc, None, backend, b.dtype)
    d_ops = tuple(
        d.astype(o.dtype).reshape(o.shape) for d, o in zip(d_ops, ep_ops)
    )
    return da, db, d_ops


_matmul_ep.defvjp(_matmul_ep_fwd, _matmul_ep_bwd)


def linear(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
    epilogue=None,
) -> jax.Array:
    """Linear layer on the O-POPE path. The [N] bias rides the C-preload
    operand — the fused epilogue the paper's accumulator preload enables for
    free — and is broadcast inside the backend, so no [M, N] copy of it is
    ever built (serving decode steps would otherwise pay O(M*N) per linear).
    ``epilogue=`` post-ops run after the bias, exactly as :func:`matmul`."""
    return matmul(
        x, w, bias, backend=backend, out_dtype=out_dtype, epilogue=epilogue
    )


# --------------------------------------------------------------------------
# grouped matmul entry point (the batched-GEMM member of each backend family)
# --------------------------------------------------------------------------


def _grouped_impl(a, b, c, backend, out_dtype, ep_steps=(), ep_ops=()):
    be = _REGISTRY[backend]
    if not ep_steps:
        return be.grouped(a, b, c, out_dtype)
    aq = a.q if hasattr(a, "q") else a
    if be.epilogue_fused and _fusion_for(
        aq.shape[1], aq.shape[2], b.shape[2], _tile_itemsize(backend, aq.dtype),
        family="grouped", groups=aq.shape[0], backend=backend,
    ):
        return be.grouped(a, b, c, out_dtype, ep_steps, ep_ops)
    # Post-hoc lane — identical numerics to the fused writeback; see
    # _matmul_impl.
    acc = be.grouped(a, b, c, jnp.float32)
    return _epi.apply_epilogue(acc, ep_steps, ep_ops).astype(out_dtype)


def _grouped_bwd_gemms(backend, res, g):
    """dA[g] = dO[g] @ B[g]^T, dB[g] = A[g]^T @ dO[g] — two more grouped
    GEMMs in the same dataflow, on the forward backend's grad backend (so a
    quantized grouped forward backpropagates full-precision, like the 2-D
    path)."""
    a, b = res
    backend = resolve_grouped_backend(grad_backend_of(backend))
    da = _grouped_impl(g, b.transpose(0, 2, 1), None, backend, a.dtype)
    db = _grouped_impl(a.transpose(0, 2, 1), g, None, backend, b.dtype)
    return da, db


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _grouped_nc(a, b, backend, out_dtype):
    return _grouped_impl(a, b, None, backend, out_dtype)


def _grouped_nc_fwd(a, b, backend, out_dtype):
    return _grouped_impl(a, b, None, backend, out_dtype), (a, b)


def _grouped_nc_bwd(backend, out_dtype, res, g):
    return _grouped_bwd_gemms(backend, res, g)


_grouped_nc.defvjp(_grouped_nc_fwd, _grouped_nc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _grouped_bias(a, b, bias, backend, out_dtype):
    return _grouped_impl(a, b, bias, backend, out_dtype)


def _grouped_bias_fwd(a, b, bias, backend, out_dtype):
    return _grouped_impl(a, b, bias, backend, out_dtype), (a, b)


def _grouped_bias_bwd(backend, out_dtype, res, g):
    da, db = _grouped_bwd_gemms(backend, res, g)
    # each group's bias row enters every accumulator row of that group once
    return da, db, g.sum(axis=1)


_grouped_bias.defvjp(_grouped_bias_fwd, _grouped_bias_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _grouped_c(a, b, c, backend, out_dtype):
    return _grouped_impl(a, b, c, backend, out_dtype)


def _grouped_c_fwd(a, b, c, backend, out_dtype):
    return _grouped_impl(a, b, c, backend, out_dtype), (a, b)


def _grouped_c_bwd(backend, out_dtype, res, g):
    da, db = _grouped_bwd_gemms(backend, res, g)
    return da, db, g  # c enters the accumulator linearly


_grouped_c.defvjp(_grouped_c_fwd, _grouped_c_bwd)


# The grouped analogue of _matmul_ep: one custom_vjp for every epilogue'd
# grouped GEMM, with C folded into the pipeline head by grouped_matmul().
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _grouped_ep(a, b, ep_ops, backend, out_dtype, ep_steps):
    return _grouped_impl(a, b, None, backend, out_dtype, ep_steps, ep_ops)


def _grouped_ep_fwd(a, b, ep_ops, backend, out_dtype, ep_steps):
    out = _grouped_impl(a, b, None, backend, out_dtype, ep_steps, ep_ops)
    return out, (a, b, ep_ops)


def _grouped_ep_bwd(backend, out_dtype, ep_steps, res, g):
    a, b, ep_ops = res
    backend = resolve_grouped_backend(grad_backend_of(backend))
    # Recompute the pre-epilogue accumulator (see _matmul_ep_bwd), backprop
    # the pipeline, then the two transposed grouped GEMMs.
    acc = _grouped_impl(a, b, None, backend, jnp.float32)
    g_acc, d_ops = _epi.epilogue_vjp(ep_steps, ep_ops, acc, g)
    da = _grouped_impl(g_acc, b.transpose(0, 2, 1), None, backend, a.dtype)
    db = _grouped_impl(a.transpose(0, 2, 1), g_acc, None, backend, b.dtype)
    d_ops = tuple(
        d.astype(o.dtype).reshape(o.shape) for d, o in zip(d_ops, ep_ops)
    )
    return da, db, d_ops


_grouped_ep.defvjp(_grouped_ep_fwd, _grouped_ep_bwd)


def grouped_matmul(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
    epilogue=None,
) -> jax.Array:
    """``O[g] = A[g] @ B[g] (+ C[g])``; a: [G, M, K], b: [G, K, N].

    The grouped/batched-GEMM entry point of the backend registry — one
    launch for a whole family of same-shape GEMMs (MoE expert FFNs run their
    per-expert SwiGLU through here). Resolution, fallback chains, precision
    policies and the ``grad_backend`` rule are shared with :func:`matmul`:
    the same backend names select the grouped member of the same family, and
    a quantized grouped forward backpropagates through full-precision
    grouped GEMMs.

    ``c`` is ``None``, a full ``[G, M, N]`` preload, or a ``[G, N]``
    per-group bias row broadcast inside the backend at the accumulator
    preload point (never materialized as ``[G, M, N]``).

    ``epilogue`` post-ops apply per group to the fp32 accumulator before the
    single cast, exactly as in :func:`matmul` — operands: scalar, ``[N]`` /
    ``[G, N]`` row, or full ``[G, M, N]``. A ``c`` alongside an epilogue is
    folded in as the pipeline's first step.
    """
    if a.ndim != 3 or b.ndim != 3:
        raise ValueError(
            f"grouped_matmul wants a [G, M, K] @ [G, K, N]; got {a.shape} @ {b.shape}"
        )
    if a.shape[0] != b.shape[0] or a.shape[2] != b.shape[1]:
        raise ValueError(f"bad grouped GEMM shapes {a.shape} @ {b.shape}")
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = resolve_grouped_backend(backend)
    _record_shape(
        "grouped", a.shape[1], a.shape[2], b.shape[2], a.shape[0], a.dtype
    )
    _note_gemm_call(
        "grouped", backend, a.shape[1], a.shape[2], b.shape[2], a.shape[0],
        a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
    )
    steps, raw_ops = _epi.normalize_epilogue(epilogue)
    if steps:
        if c is not None:
            # Same linear-preload folding as matmul(): [G, N] row -> "bias",
            # full [G, M, N] -> "residual" at the pipeline head.
            name = "bias" if c.ndim == 2 else "residual"
            steps, raw_ops = (name,) + steps, (c,) + raw_ops
        ep_ops = _epi.canonicalize_operands(
            steps, raw_ops, n=b.shape[2], m=a.shape[1], groups=a.shape[0]
        )
        out = _grouped_ep(a, b, ep_ops, backend, out_dtype, steps)
        ref = lambda: _grouped_impl(  # noqa: E731
            a, b, None,
            resolve_grouped_backend(grad_backend_of(backend)), out_dtype,
            steps, ep_ops)
    elif c is None:
        out = _grouped_nc(a, b, backend, out_dtype)
        ref = lambda: _grouped_impl(  # noqa: E731
            a, b, None,
            resolve_grouped_backend(grad_backend_of(backend)), out_dtype)
    else:
        out = (_grouped_bias if c.ndim == 2 else _grouped_c)(
            a, b, c, backend, out_dtype)
        ref = lambda: _grouped_impl(  # noqa: E731
            a, b, c,
            resolve_grouped_backend(grad_backend_of(backend)), out_dtype)
    if not hasattr(a, "q"):  # pre-quantized A has no fp twin to audit against
        _maybe_audit_gemm(
            "grouped", backend, out, ref,
            a.shape[1], a.shape[2], b.shape[2], g=a.shape[0],
        )
    return out
