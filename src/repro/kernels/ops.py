"""Public jit'd matmul entry point used by every layer in the framework.

``matmul`` routes through one of three backends with identical numerics
(fp32 accumulation, single final cast — see `ref.py`):

* ``"pallas"``            — the O-POPE Pallas kernel, compiled (TPU).
* ``"pallas_interpret"``  — same kernel body, Pallas interpreter (CPU tests).
* ``"xla"``               — ``jax.lax.dot_general`` with
  ``preferred_element_type=f32``; used for the CPU dry-run, where Pallas
  cannot lower, and as the A/B comparison baseline in benchmarks.

The default ``"auto"`` picks pallas on TPU and xla elsewhere, so model code is
backend-agnostic. A ``custom_vjp`` makes the backward pass run the same
O-POPE dataflow (two more GEMMs: dA = dO @ B^T, dB = A^T @ dO) instead of
whatever XLA would pick for the transposed dots.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import opope_gemm as _kern
from . import ref as _ref

__all__ = ["matmul", "linear", "default_backend", "set_default_backend"]

_DEFAULT_BACKEND = "auto"


def default_backend() -> str:
    if _DEFAULT_BACKEND != "auto":
        return _DEFAULT_BACKEND
    platform = jax.devices()[0].platform
    return "pallas" if platform == "tpu" else "xla"


def set_default_backend(name: str) -> None:
    """Override backend globally ('pallas', 'pallas_interpret', 'xla', 'auto')."""
    global _DEFAULT_BACKEND
    if name not in ("pallas", "pallas_interpret", "xla", "auto"):
        raise ValueError(name)
    _DEFAULT_BACKEND = name


def _matmul_impl(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array],
    backend: str,
    out_dtype,
) -> jax.Array:
    if backend == "xla":
        return _ref.reference_matmul(a, b, c, out_dtype=out_dtype)
    interpret = backend == "pallas_interpret"
    return _kern.opope_gemm(a, b, c, out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _matmul(a, b, c, backend, out_dtype):
    return _matmul_impl(a, b, c, backend, out_dtype)


def _matmul_fwd(a, b, c, backend, out_dtype):
    return _matmul_impl(a, b, c, backend, out_dtype), (a, b)


def _matmul_bwd(backend, out_dtype, res, g):
    a, b = res
    # Backward = two more O-POPE GEMMs in the same dataflow; gradients are
    # accumulated in fp32 and cast back to the operand dtypes.
    da = _matmul_impl(g, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g, None, backend, b.dtype)
    dc = g  # c enters the accumulator linearly
    return da, db, dc


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """``a @ b (+ c)`` with O-POPE semantics; a: [..., K], b: [K, N].

    Leading batch dims of ``a`` are flattened into M (the engine sees one tall
    GEMM — exactly how the paper maps ML layers onto the engine, Table I).
    """
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or default_backend()
    batch_shape = a.shape[:-1]
    m = 1
    for d in batch_shape:
        m *= d
    a2 = a.reshape(m, a.shape[-1])
    if c is None:
        out = _matmul_nc(a2, b, backend, out_dtype)
    else:
        out = _matmul(a2, b, c.reshape(m, b.shape[-1]), backend, out_dtype)
    return out.reshape(*batch_shape, b.shape[-1])


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul_nc(a, b, backend, out_dtype):
    return _matmul_impl(a, b, None, backend, out_dtype)


def _matmul_nc_fwd(a, b, backend, out_dtype):
    return _matmul_impl(a, b, None, backend, out_dtype), (a, b)


def _matmul_nc_bwd(backend, out_dtype, res, g):
    a, b = res
    da = _matmul_impl(g, b.T, None, backend, a.dtype)
    db = _matmul_impl(a.T, g, None, backend, b.dtype)
    return da, db


_matmul_nc.defvjp(_matmul_nc_fwd, _matmul_nc_bwd)


def linear(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Linear layer on the O-POPE path. Bias rides the C-preload operand —
    the fused epilogue the paper's accumulator preload enables for free."""
    if bias is not None:
        batch = x.shape[:-1]
        m = 1
        for d in batch:
            m *= d
        c = jnp.broadcast_to(bias, (m, w.shape[-1])).reshape(*batch, w.shape[-1])
        return matmul(x, w, c, backend=backend, out_dtype=out_dtype)
    return matmul(x, w, backend=backend, out_dtype=out_dtype)
