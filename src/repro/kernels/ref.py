"""Pure-jnp oracles for every Pallas kernel in this package.

Each reference implements the exact numerical contract of its kernel —
including accumulation precision — so `assert_allclose` tolerances in the
tests reflect only reassociation noise, not semantic differences.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "reference_matmul",
    "reference_grouped_matmul",
    "reference_attention",
    "reference_chunked_scan",
]


def reference_matmul(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """Oracle for :func:`repro.kernels.opope_gemm.opope_gemm`.

    Contract (mirrors the O-POPE PE, §II-A): multiply in the input format,
    accumulate in fp32 (the TPU MXU's ``preferred_element_type`` — the
    analogue of the paper's widening accumulation), optionally add the
    preloaded C operand into the accumulator, cast once at the end.
    C is either [M, N] or an [N] bias row broadcast at the preload point.
    """
    out_dtype = out_dtype or a.dtype
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if c is not None:
        acc = acc + c.astype(jnp.float32)
    return acc.astype(out_dtype)


def reference_grouped_matmul(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """Oracle for :func:`repro.kernels.opope_grouped.opope_gemm_grouped`.

    ``O[g] = A[g] @ B[g] (+ C[g])`` with the same per-group contract as
    :func:`reference_matmul`: multiply in the input format, accumulate in
    fp32, optionally add the preloaded C operand (a full [G, M, N] tile or a
    [G, N] per-group bias row broadcast at the preload point), cast once.
    a: [G, M, K], b: [G, K, N].
    """
    out_dtype = out_dtype or a.dtype
    acc = jax.lax.dot_general(
        a, b, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    if c is not None:
        cf = c.astype(jnp.float32)
        acc = acc + (cf[:, None, :] if c.ndim == 2 else cf)
    return acc.astype(out_dtype)


def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """Oracle for :func:`repro.kernels.opope_attention.opope_attention`.

    Shapes: q [S, D], k/v [T, D] (single head; the kernel vmaps batch/heads).
    fp32 softmax and accumulation throughout.
    """
    out_dtype = out_dtype or q.dtype
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum(
        "sd,td->st", q, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        sq, tk = q.shape[0], k.shape[0]
        mask = jnp.tril(jnp.ones((sq, tk), dtype=bool), k=tk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("st,td->sd", p.astype(jnp.float32), v.astype(jnp.float32))
    return o.astype(out_dtype)


def reference_chunked_scan(
    decay: jax.Array, update: jax.Array, init: Optional[jax.Array] = None
) -> jax.Array:
    """Oracle for the state-resident chunked linear scan kernel.

    Computes ``h[t] = decay[t] * h[t-1] + update[t]`` over the leading axis in
    fp32 and returns all states. decay/update: [S, ...] broadcastable.
    """
    decay = decay.astype(jnp.float32)
    update = update.astype(jnp.float32)
    h0 = (
        jnp.zeros_like(update[0])
        if init is None
        else jnp.broadcast_to(init.astype(jnp.float32), update[0].shape)
    )

    def step(h, du):
        d, u = du
        h = d * h + u
        return h, h

    _, hs = jax.lax.scan(step, h0, (decay, update))
    return hs
