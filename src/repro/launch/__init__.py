"""Launchers: production mesh, multi-pod dry-run, train and serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time and
must only be imported as the program entry point (python -m repro.launch.dryrun).
"""

from .mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
