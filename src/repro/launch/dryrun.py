import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script

1. builds ``input_specs`` (ShapeDtypeStructs only — no allocation),
2. builds the parameter/optimizer/cache shape trees with ``jax.eval_shape``,
3. assigns shardings from ``repro.distributed.sharding``,
4. ``jax.jit(step).lower(...).compile()`` against the production mesh,
5. records ``memory_analysis()`` (fit proof), ``cost_analysis()`` (FLOPs /
   bytes) and the collective traffic parsed from the compiled HLO — the
   inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun

The 512 forced host devices exist ONLY in this process (the env var above is
set before any jax import, which locks the device count at first init).
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat, obs
from repro.configs import ARCHS, applicable_shapes, get_config, shape_by_name
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.hlo_census import census_hlo
from repro.core.roofline import TPU_V5E, model_flops, roofline_terms
from repro.distributed import (
    batch_shardings,
    cache_shardings,
    data_axes,
    param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.optim import AdamWConfig, init_opt_state
from repro.train.loop import make_train_step

__all__ = ["input_specs", "run_cell", "main"]


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (delegates to :func:`repro.models.api.input_specs`, the single owner of
    the per-family batch layout)."""
    return model_api.input_specs(
        cfg, batch=shape.global_batch, seq=shape.seq_len, kind=shape.kind
    )


def _param_specs(cfg: ArchConfig):
    import functools

    return jax.eval_shape(
        functools.partial(model_api.init_params, cfg), jax.random.key(0)
    )


def run_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh,
    *,
    mesh_name: str,
    keep_hlo: bool = False,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    t0 = time.time()
    n_chips = mesh.size
    params = _param_specs(cfg)
    p_sh = param_shardings(mesh, params)
    batch = input_specs(cfg, shape)
    b_sh = batch_shardings(mesh, cfg, batch)
    dp = data_axes(mesh)
    dp_axis = dp if len(dp) > 1 else dp[0]

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            import functools

            opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)
            opt = jax.eval_shape(
                functools.partial(init_opt_state, cfg=opt_cfg), params
            )
            # moments inherit the 2-D param sharding (ZeRO via FSDP x TP)
            o_sh = jax.tree.map(lambda s: s, p_sh)
            opt_sh = type(opt)(
                step=NamedSharding(mesh, P()), mu=o_sh, nu=o_sh
            )
            raw_step = make_train_step(cfg, opt_cfg, jit=False)
            fn = jax.jit(
                raw_step,
                in_shardings=(p_sh, opt_sh, b_sh),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params, opt, batch)
        elif shape.kind == "prefill":
            import functools

            def prefill_fn(params, batch):
                return model_api.prefill(
                    cfg, params, batch, shape.seq_len, jnp.bfloat16
                )

            out_caches = jax.eval_shape(prefill_fn, params, batch)[1]
            c_out_sh = cache_shardings(mesh, cfg, out_caches, layout="prefill")
            fn = jax.jit(
                prefill_fn,
                in_shardings=(p_sh, b_sh),
                out_shardings=(NamedSharding(mesh, P()), c_out_sh),
            )
            lowered = fn.lower(params, batch)
        else:  # decode
            import functools

            caches = jax.eval_shape(
                functools.partial(
                    model_api.init_state,
                    cfg,
                    shape.global_batch,
                    shape.seq_len,
                    jnp.bfloat16,
                )
            )
            c_sh = cache_shardings(mesh, cfg, caches)
            tok_sh = NamedSharding(
                mesh,
                P(dp_axis if shape.global_batch % _axis(mesh, dp_axis) == 0 else None, None),
            )

            def decode_fn(params, token, caches, pos):
                return model_api.decode(cfg, params, token, caches, pos)

            fn = jax.jit(
                decode_fn,
                in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P()), c_sh),
                donate_argnums=(2,),
            )
            lowered = fn.lower(
                params,
                batch["token"],
                caches,
                jax.ShapeDtypeStruct((), jnp.int32),
            )

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compat.normalize_memory_analysis(compiled)
    ca = compat.normalize_cost_analysis(compiled)
    hlo = compiled.as_text()
    # Loop-aware census: cost_analysis counts while bodies once (useless for
    # scanned layers); the census multiplies by known_trip_count. See
    # repro.core.hlo_census.
    census = census_hlo(hlo)

    flops_dev = census.flops
    bytes_dev = census.hbm_bytes
    mf = model_flops(
        model_api.param_count(cfg),
        shape.tokens_per_step,
        kind="train" if shape.kind == "train" else "infer",
        n_params_active=model_api.active_param_count(cfg),
    )
    rt = roofline_terms(
        flops_dev,
        bytes_dev,
        census.collective_bytes,
        hw=TPU_V5E,
        model_flops_total=mf,
        n_chips=n_chips,
    )

    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            # peak_bytes is the buffer-assignment high-water mark including
            # arguments, (aliased) outputs and live temps — the per-chip HBM
            # requirement (upper-bounded from components on JAX without
            # peak_memory_in_bytes). temp_bytes sums logical temp buffers
            # (reused buffers counted once each, not concurrent) — diagnostic
            # only.
            **ma,
            "hbm_need_bytes": ma["peak_bytes"],
            "fits_16gb": ma["peak_bytes"] < 16e9,
        },
        "cost": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
            "census": census.summary(),
        },
        "collectives": census.collective_by_kind,
        "model_flops_total": mf,
        "roofline": rt.summary(),
    }
    return rec


def _apply_overrides(cfg: ArchConfig, overrides):
    """Apply ``field=value`` (or ``moe.field=value``) config overrides."""
    for ov in overrides:
        key, _, raw = ov.partition("=")
        value: Any
        if raw.lower() in ("true", "false"):
            value = raw.lower() == "true"
        else:
            try:
                value = int(raw)
            except ValueError:
                try:
                    value = float(raw)
                except ValueError:
                    value = raw
        if key.startswith("moe."):
            if cfg.moe is None:
                continue
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **{key[4:]: value})
            )
        else:
            cfg = dataclasses.replace(cfg, **{key: value})
    return cfg


def _axis(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument(
        "--override", action="append", default=[],
        help="ArchConfig field override, e.g. attn_seq_shard=true, "
        "remat_policy=dots, moe.dispatch=sort, scan_chunk=16 (§Perf knobs)",
    )
    args = ap.parse_args()

    log = obs.get_logger("dryrun")
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = (
        ["single", "multi"] if args.mesh == "both" else [args.mesh]
    )
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            cfg = get_config(arch)
            cfg = _apply_overrides(cfg, args.override)
            shapes = (
                applicable_shapes(cfg)
                if args.shape == "all"
                else [shape_by_name(s) for s in args.shape.split(",")]
            )
            for shape in shapes:
                if shape.name == "long_500k" and not cfg.supports_long:
                    log.info("skip", arch=arch, shape=shape.name,
                             reason="full-attn")
                    continue
                out_path = os.path.join(
                    args.out, mesh_name, f"{arch}__{shape.name}.json"
                )
                os.makedirs(os.path.dirname(out_path), exist_ok=True)
                if args.skip_existing and os.path.exists(out_path):
                    log.info("cached", arch=arch, shape=shape.name,
                             mesh=mesh_name)
                    continue
                try:
                    rec = run_cell(
                        cfg, shape, mesh, mesh_name=mesh_name,
                        keep_hlo=args.keep_hlo,
                    )
                    r = rec["roofline"]
                    log.info(
                        "ok", arch=arch, shape=shape.name, mesh=mesh_name,
                        compile_s=rec["compile_s"],
                        hbm_gb=rec["memory"]["hbm_need_bytes"] / 1e9,
                        dominant=r["dominant"],
                        roofline_frac=r["roofline_fraction"],
                    )
                except Exception as e:
                    failures += 1
                    rec = {
                        "arch": arch,
                        "shape": shape.name,
                        "mesh": mesh_name,
                        "status": "fail",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-3000:],
                    }
                    log.info("fail", arch=arch, shape=shape.name,
                             mesh=mesh_name, error=str(e))
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
    log.info("done", failures=failures)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
