"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run must set
``XLA_FLAGS`` before any device query, and tests must keep seeing 1 device.

Mesh topology (TPU v5e pods of 256 chips):

* single pod:  (data=16, model=16)           — 256 chips
* multi pod:   (pod=2, data=16, model=16)    — 512 chips

``model`` maps onto the intra-pod ICI torus dimension with the highest
locality (TP traffic is the latency-critical all-reduce path); ``pod``
crosses the slower inter-pod links and carries only data-parallel gradient
all-reduces, which overlap with the backward pass.
"""

from __future__ import annotations

from repro import compat

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types="auto")


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for subprocess smoke tests (8 forced host devices)."""
    return compat.make_mesh(shape, axes, axis_types="auto")
