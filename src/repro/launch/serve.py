"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode through repro.serve.ServeEngine. Reduced configs
run real tokens on CPU; production shapes are exercised (lowered+compiled)
by the dry-run's decode cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.key(args.seed)
    params = api.init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab
        )
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )

    eng = ServeEngine(
        cfg=cfg,
        params=params,
        max_len=args.prompt_len + args.gen,
        cache_dtype=jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16,
        temperature=args.temperature,
    )
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.gen, key=key)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] first sequence:", toks[0].tolist())


if __name__ == "__main__":
    main()
