"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Two engines over the same compiled prefill/decode substrate:

* ``--engine continuous`` (default) — the continuous-batching subsystem:
  FIFO bucketed scheduler, slot-pooled KV cache, one fused masked decode
  step; requests from a Poisson-ish arrival trace join and leave mid-flight.
* ``--engine static`` — the lockstep ``ServeEngine`` baseline: one batch
  enters and exits together.

Reduced configs run real tokens on CPU; production shapes are exercised
(lowered+compiled) by the dry-run's decode cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import get_config
from repro.models import api
from repro.serve import (
    ContinuousEngine,
    ServeEngine,
    gen_len_spread,
    poisson_trace,
)


def _static(cfg, params, args) -> None:
    key = jax.random.key(args.seed)
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab
        )
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    eng = ServeEngine(
        cfg=cfg,
        params=params,
        max_len=args.prompt_len + args.gen,
        cache_dtype=jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16,
        temperature=args.temperature,
    )
    log = obs.get_logger("serve")
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.gen, key=key)
    dt = time.perf_counter() - t0
    log.info(
        "generated", shape=str(tuple(toks.shape)), wall_s=dt,
        tokens_per_sec=args.batch * args.gen / dt,
    )
    log.info("first_sequence", tokens=str(toks[0].tolist()))


def _continuous(cfg, params, args) -> None:
    gens = gen_len_spread(args.gen)
    trace = poisson_trace(
        args.n_requests, seed=args.seed, vocab=cfg.vocab,
        prompt_lens=(args.prompt_len // 4 or 1, args.prompt_len // 2 or 1,
                     args.prompt_len),
        gen_lens=gens, mean_interarrival=args.rate,
    )
    eng = ContinuousEngine(
        cfg=cfg,
        params=params,
        n_slots=args.slots,
        max_len=args.prompt_len + args.gen,
        cache_dtype=jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16,
        temperature=args.temperature,
        kv_format=args.kv_format,
    )
    log = obs.get_logger("serve")
    report = eng.timed_serve(trace, key=jax.random.key(args.seed))
    log.info(
        "served", requests=len(trace), tokens=report.generated_tokens,
        wall_s=report.wall_time_s, tokens_per_sec=report.tokens_per_sec,
    )
    log.info(
        "counters", decode_steps=report.decode_steps,
        prefill_batches=report.prefill_batches,
        mean_occupancy=report.mean_occupancy,
    )
    log.info(
        "latency", ttft_p50_s=report.ttft_p50, ttft_p99_s=report.ttft_p99,
        itl_p50_s=report.itl_p50, itl_p99_s=report.itl_p99,
    )
    log.info(
        "phases", queue_p50_s=report.queue_p50, queue_p99_s=report.queue_p99,
        attach_p50_s=report.attach_p50,
        chunk_prefill_p50_s=report.chunk_prefill_p50,
        slot_hwm=report.slot_hwm,
    )
    if report.goodput is not None:
        log.info("goodput", fraction=report.goodput)
    if report.kv_bytes_per_slot:
        log.info(
            "kv_cache", format=args.kv_format or "full-width",
            kb_per_slot=report.kv_bytes_per_slot / 1e3,
        )
    first = trace[0]
    log.info(
        "first_request", uid=first.uid, prompt_tokens=len(first.prompt),
        output=str(report.outputs[first.rid]),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--batch", type=int, default=4,
                    help="static engine: lockstep batch size")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous engine: decode slot pool size")
    ap.add_argument("--n-requests", type=int, default=12,
                    help="continuous engine: trace length")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="continuous engine: mean interarrival (decode steps)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-format", default=None,
                    choices=(None, "int8", "fp8_e4m3", "fp8_e5m2"),
                    help="continuous engine: narrow K/V lanes (~4x less "
                    "cache memory per slot)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--linger-seconds", type=float, default=0.0,
                    help="keep the process (and its REPRO_METRICS_PORT "
                    "scrape server) alive this long after the run, so "
                    "/metrics, /requests and /trace can be curled against "
                    "the frozen registry (Ctrl-C/SIGINT ends the linger "
                    "early but still runs the atexit dump hooks)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    log = obs.get_logger("serve")
    server = obs.http.maybe_serve_from_env()
    if server is not None:
        log.info(
            "metrics_server", port=server.port,
            endpoints="/metrics /requests /trace",
        )

    params = api.init_params(cfg, jax.random.key(args.seed))
    if args.engine == "static" or cfg.family in ("audio", "vlm"):
        if args.engine == "continuous":
            log.info("engine_fallback", family=cfg.family, engine="static")
        _static(cfg, params, args)
    else:
        _continuous(cfg, params, args)

    if args.linger_seconds > 0 and server is not None:
        # The run is done and nothing mutates the registry anymore: what
        # /metrics serves now is byte-identical to what REPRO_METRICS_DUMP
        # will write at exit — the property the CI scrape smoke asserts.
        log.info(
            "metrics_linger", port=server.port, seconds=args.linger_seconds
        )
        try:
            time.sleep(args.linger_seconds)
        except KeyboardInterrupt:
            pass


if __name__ == "__main__":
    main()
