"""``repro-stats``: surface the repo's telemetry (see :mod:`repro.obs`).

Metrics live in-process, so the CLI has two modes of access:

* **post-mortem** — read artifacts another run wrote: a snapshot JSON
  (``REPRO_METRICS_DUMP=snap.json`` makes any instrumented process dump one
  at exit) or the JSONL event log (``REPRO_EVENTS=events.jsonl``).
* **in-process** — drive a workload (the serve/train launchers) inside this
  process and report its registry when it finishes, optionally bracketing
  the run with ``jax.profiler.start_trace`` so the spans land on a
  TensorBoard/Perfetto timeline.

Examples::

    # pretty-print / export a snapshot another run dumped
    repro-stats snapshot --file snap.json
    repro-stats snapshot --file snap.json --prom > metrics.prom

    # tail the event log a serving or training process is appending to
    repro-stats tail --file events.jsonl -n 20 --kind train_step
    repro-stats tail --file events.jsonl --follow   # poll for new events

    # export the request-lifecycle trace as Chrome trace-event JSON
    # (REPRO_TRACE_DUMP=raw.json on the serving process writes the input)
    repro-stats trace --file raw.json --out timeline.json  # open in Perfetto
    repro-stats trace --file raw.json --summary            # phase table

    # run the serving driver here, then report (optionally with a profile)
    repro-stats serve --profile /tmp/trace -- --arch chatglm3-6b --reduced
    repro-stats train -- --arch chatglm3-6b --reduced --steps 20

    # rank GEMM shape buckets by attributed device time + utilization gap
    repro-stats top --file snap.json -n 10

    # diff the latest BENCH_history rows against the committed baseline
    repro-stats bench --dir BENCH_history --baseline first --current last
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys
from typing import Dict, List, Optional

from repro import obs

__all__ = ["main"]


def _fmt(v, width: int = 0) -> str:
    """``None``-safe number rendering ("n/a": no data is not a zero)."""
    s = "n/a" if v is None else f"{v:.4g}"
    return s.rjust(width) if width else s


def _print_snapshot(snap: Dict, *, prom: bool = False, as_json: bool = False,
                    out=None) -> None:
    out = out if out is not None else sys.stdout
    if prom:
        out.write(obs.prometheus_text(snap))
        return
    if as_json:
        json.dump(snap, out, indent=2)
        out.write("\n")
        return
    counters = snap.get("counters", {})
    gauges = snap.get("gauges", {})
    hists = snap.get("histograms", {})
    if not (counters or gauges or hists):
        print("(empty registry)", file=out)
        return
    if counters:
        print("counters:", file=out)
        for name, fam in counters.items():
            for labels, v in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                print(f"  {name}{tag} = {v:g}", file=out)
    if gauges:
        print("gauges:", file=out)
        for name, fam in gauges.items():
            for labels, v in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                print(f"  {name}{tag} = {v:g}", file=out)
    if hists:
        print("histograms:", file=out)
        for name, fam in hists.items():
            for labels, h in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                # Percentiles are None on an empty histogram, and only a
                # trailing window once the sample reservoir has evicted
                # (snapshot's percentile_mode) — say so instead of printing
                # a confident exact-looking number.
                win = ""
                if h.get("percentile_mode") == "windowed":
                    dropped = h.get("samples_dropped", 0)
                    win = f" [windowed: {dropped} dropped]"
                print(
                    f"  {name}{tag}: n={h['count']} mean={h['mean']:.6g} "
                    f"p50={_fmt(h['p50'])} p99={_fmt(h['p99'])} "
                    f"min={h['min']:.6g} max={h['max']:.6g}{win}",
                    file=out,
                )


def _load_snapshot(path: Optional[str]) -> Dict:
    if path is None:
        return obs.snapshot()
    with open(path) as f:
        return json.load(f)


def _cmd_snapshot(args) -> None:
    snap = _load_snapshot(args.file)
    _print_snapshot(snap, prom=args.prom, as_json=args.json)


def _cmd_tail(args) -> None:
    path = args.file or obs.event_log_path()
    if path is None:
        raise SystemExit(
            "no event log: pass --file or set REPRO_EVENTS=<path> on the "
            "producing process"
        )
    try:
        events = obs.read_events(path, n=None)
    except FileNotFoundError:
        # An instrumented run that emitted no events never creates the sink;
        # an empty tail is a state worth reporting, not a crash.
        print(f"no events recorded at {path}")
        return
    if args.kind:
        events = [e for e in events if e.get("kind") == args.kind]
    for e in events[-args.n:]:
        print(json.dumps(e, default=str))
    if getattr(args, "follow", False):
        try:
            for e in obs.follow_events(
                path, poll_interval=args.poll, start_at_end=True
            ):
                if args.kind and e.get("kind") != args.kind:
                    continue
                print(json.dumps(e, default=str), flush=True)
        except KeyboardInterrupt:
            return


def _cmd_trace(args) -> None:
    """Export the request-lifecycle buffer as Chrome trace-event JSON."""
    from repro.obs import tracing

    if args.file:
        with open(args.file) as f:
            snap = json.load(f)
    else:
        snap = tracing.snapshot()
    if not snap.get("requests"):
        print("no requests traced — run a continuous-engine workload with "
              "REPRO_METRICS=1 (and REPRO_TRACE_DUMP=<path> to export "
              "across processes)", file=sys.stderr)
    if args.summary:
        print(f"{'uid':>5} {'rid':>5} {'slot':>4} {'reason':<8} "
              f"{'queue_ms':>9} {'attach_ms':>9} {'chunk_ms':>9} "
              f"{'decode_ms':>9} {'total_ms':>9}")
        for req in snap.get("requests", []):
            by = {}
            for p in req.get("phases", []):
                if p.get("t1") is not None:
                    by[p["name"]] = by.get(p["name"], 0.0) + (p["t1"] - p["t0"])
            total = sum(by.values())
            print(f"{req['uid']:>5} {req['rid']:>5} "
                  f"{'-' if req.get('slot') is None else req['slot']:>4} "
                  f"{req.get('retire_reason') or 'live':<8} "
                  f"{by.get('queue', 0.0) * 1e3:>9.3f} "
                  f"{by.get('prefix_attach', 0.0) * 1e3:>9.3f} "
                  f"{(by.get('chunk_prefill', 0.0) + by.get('prefill', 0.0)) * 1e3:>9.3f} "
                  f"{by.get('decode', 0.0) * 1e3:>9.3f} {total * 1e3:>9.3f}")
        return
    doc = tracing.chrome_trace(snap)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f)
        n = sum(1 for e in doc["traceEvents"] if e.get("ph") == "b")
        print(f"[stats] {n} request span(s) -> {args.out} "
              f"(load in Perfetto / chrome://tracing)", file=sys.stderr)
    else:
        json.dump(doc, sys.stdout)
        sys.stdout.write("\n")


def _cmd_top(args) -> None:
    """Rank GEMM shape buckets by attributed device time + utilization gap.

    Joins the ``gemm.device_seconds`` counters with the
    ``gemm.roofline_fraction`` histograms (both written by
    ``repro.obs.attr`` during any timed serving/bench run) on their shared
    label set. The gap column is ``1 - mean fraction``: how far the bucket
    runs below the roofline bound it was costed against.
    """
    snap = _load_snapshot(args.file)
    device_s = snap.get("counters", {}).get("gemm.device_seconds", {})
    fractions = snap.get("histograms", {}).get("gemm.roofline_fraction", {})
    if not device_s:
        print("no utilization attribution recorded (gemm.device_seconds is "
              "empty) — run a serving/bench workload with REPRO_METRICS=1")
        return
    rows = []
    for labels, seconds in device_s.items():
        parts = dict(p.split("=", 1) for p in labels.split(",") if "=" in p)
        h = fractions.get(labels, {})
        rows.append({
            "bucket": parts.get("bucket", "?"),
            "backend": parts.get("backend", "?"),
            "tile": parts.get("tile", "?"),
            "seconds": seconds,
            "steps": h.get("count", 0),
            "frac_mean": h.get("mean"),
            "frac_p50": h.get("p50"),
            "windowed": h.get("percentile_mode") == "windowed",
        })
    rows.sort(key=lambda r: r["seconds"], reverse=True)
    print(f"{'bucket':<34} {'backend':<20} {'tile':<10} "
          f"{'device_s':>9} {'steps':>6} {'util p50':>9} {'gap':>7}")
    for r in rows[: args.n]:
        gap = None if r["frac_mean"] is None else 1.0 - r["frac_mean"]
        star = "~" if r["windowed"] else ""
        print(f"{r['bucket']:<34} {r['backend']:<20} {r['tile']:<10} "
              f"{r['seconds']:>9.4f} {r['steps']:>6} "
              f"{_fmt(r['frac_p50'], 9)}{star} {_fmt(gap, 7)}")


def _history_module():
    """Import ``benchmarks.history`` (repo-root layout; the history gate is
    a development/CI artifact, not an installed-package feature)."""
    try:
        from benchmarks import history
        return history
    except ImportError:
        import os

        root = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", "..")
        )
        sys.path.insert(0, root)
        try:
            from benchmarks import history
            return history
        finally:
            sys.path.remove(root)


def _pick_row(rows: List[Dict], sel: str, path: str) -> Dict:
    if sel == "first":
        return rows[0]
    if sel == "last":
        return rows[-1]
    try:
        return rows[int(sel)]
    except ValueError:
        pass
    for row in reversed(rows):  # newest row for the commit
        if row.get("meta", {}).get("git_commit", "").startswith(sel):
            return row
    raise SystemExit(f"no row matching {sel!r} in {path}")


def _cmd_bench(args) -> None:
    """Diff BENCH_history rows (the perf-regression gate). Exit 1 on any
    regression unless ``--warn-only``."""
    import glob
    import os

    hist = _history_module()
    if args.name:
        names = [args.name]
    else:
        names = sorted(
            os.path.splitext(os.path.basename(p))[0]
            for p in glob.glob(os.path.join(args.dir, "*.jsonl"))
        )
        if not names:
            raise SystemExit(f"no history files under {args.dir}")
    regressions = 0
    for name in names:
        path = hist.history_path(name, args.dir)
        try:
            rows = hist.load_rows(name, args.dir)
        except FileNotFoundError:
            raise SystemExit(f"no history at {path}")
        if not rows:
            raise SystemExit(f"empty history at {path}")
        baseline = _pick_row(rows, args.baseline, path)
        if args.current_file:
            with open(args.current_file) as f:
                current = json.load(f)
        else:
            current = _pick_row(rows, args.current, path)
        findings = hist.diff_rows(baseline, current)
        bad = [f for f in findings if f.status == "regression"]
        regressions += len(bad)
        b_meta = baseline.get("meta", {})
        c_meta = current.get("meta", {})
        print(f"{name}: baseline {b_meta.get('git_commit', '?')[:12]} "
              f"({b_meta.get('device_kind', '?')}, "
              f"jax {b_meta.get('jax_version', '?')}) vs current "
              f"{c_meta.get('git_commit', '?')[:12]} "
              f"({c_meta.get('device_kind', '?')}, "
              f"jax {c_meta.get('jax_version', '?')})")
        shown = findings if args.verbose else [
            f for f in findings if f.status != "ok"
        ]
        for f in shown:
            print("  " + f.row())
        ok = sum(1 for f in findings if f.status == "ok")
        print(f"  {ok} ok, {len(bad)} regression(s), "
              f"{len(findings) - ok - len(bad)} informational")
    if regressions and not args.warn_only:
        raise SystemExit(1)


@contextlib.contextmanager
def _maybe_profile(trace_dir: Optional[str]):
    if not trace_dir:
        yield
        return
    import jax

    jax.profiler.start_trace(trace_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
        print(f"[stats] profile written to {trace_dir}", file=sys.stderr)


def _run_driver(args, driver_main) -> None:
    """Run a launch driver in-process under the span/profile bracket, then
    report this process's registry."""
    if not obs.enabled():
        print("[stats] warning: REPRO_METRICS=0 — the run will record "
              "nothing", file=sys.stderr)
    sys.argv = [sys.argv[0]] + list(args.driver_args)
    with _maybe_profile(args.profile):
        with obs.span(f"stats.{args.cmd}"):
            driver_main()
    snap = obs.snapshot()
    if args.out:
        with open(args.out, "w") as f:
            _print_snapshot(snap, prom=args.prom, as_json=not args.prom,
                            out=f)
        print(f"[stats] snapshot -> {args.out}", file=sys.stderr)
    else:
        _print_snapshot(snap, prom=args.prom)


def _cmd_serve(args) -> None:
    from repro.launch.serve import main as serve_main

    _run_driver(args, serve_main)


def _cmd_train(args) -> None:
    from repro.launch.train import main as train_main

    _run_driver(args, train_main)


def _split_driver_args(argv: List[str]) -> (List[str], List[str]):
    """Everything after ``--`` goes to the wrapped driver verbatim."""
    if "--" in argv:
        i = argv.index("--")
        return argv[:i], argv[i + 1:]
    return argv, []


def main(argv: Optional[List[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    argv, driver_args = _split_driver_args(argv)

    ap = argparse.ArgumentParser(
        prog="repro-stats",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("snapshot", help="pretty-print / export a snapshot")
    sp.add_argument("--file", default=None,
                    help="snapshot JSON written by REPRO_METRICS_DUMP "
                         "(default: this process's live registry)")
    sp.add_argument("--prom", action="store_true",
                    help="Prometheus text exposition instead of pretty text")
    sp.add_argument("--json", action="store_true",
                    help="raw JSON instead of pretty text")
    sp.set_defaults(fn=_cmd_snapshot)

    tp = sub.add_parser("tail", help="print the last events of a JSONL log")
    tp.add_argument("--file", default=None,
                    help="event log path (default: $REPRO_EVENTS)")
    tp.add_argument("-n", type=int, default=20, help="number of events")
    tp.add_argument("--kind", default=None, help="filter by event kind")
    tp.add_argument("--follow", "-f", action="store_true",
                    help="after printing the last -n events, poll the file "
                         "and stream new ones (Ctrl-C to stop)")
    tp.add_argument("--poll", type=float, default=0.5,
                    help="follow-mode poll interval, seconds")
    tp.set_defaults(fn=_cmd_tail)

    rp = sub.add_parser(
        "trace",
        help="export the request-lifecycle trace as Chrome trace-event "
             "JSON (load in Perfetto / chrome://tracing)",
    )
    rp.add_argument("--file", default=None,
                    help="raw trace snapshot written by REPRO_TRACE_DUMP "
                         "(default: this process's live recorder)")
    rp.add_argument("--out", default=None,
                    help="write the Chrome trace JSON here (default: stdout)")
    rp.add_argument("--summary", action="store_true",
                    help="print a per-request phase table instead of JSON")
    rp.set_defaults(fn=_cmd_trace)

    op = sub.add_parser(
        "top",
        help="rank GEMM shape buckets by attributed device time and "
             "utilization gap (obs.attr)",
    )
    op.add_argument("--file", default=None,
                    help="snapshot JSON (default: live registry)")
    op.add_argument("-n", type=int, default=15, help="rows to show")
    op.set_defaults(fn=_cmd_top)

    bp = sub.add_parser(
        "bench",
        help="diff BENCH_history rows with per-metric tolerances "
             "(the perf-regression gate; exit 1 on regression)",
    )
    bp.add_argument("--dir", default="BENCH_history",
                    help="history directory (default: BENCH_history)")
    bp.add_argument("--name", default=None,
                    help="one history file (default: every *.jsonl in --dir)")
    bp.add_argument("--baseline", default="first",
                    help="baseline row: first|last|<index>|<commit-prefix>")
    bp.add_argument("--current", default="last",
                    help="current row: first|last|<index>|<commit-prefix>")
    bp.add_argument("--current-file", default=None,
                    help="read the current row from a JSON file instead")
    bp.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0")
    bp.add_argument("--verbose", action="store_true",
                    help="also print metrics that passed")
    bp.set_defaults(fn=_cmd_bench)

    for name, fn in (("serve", _cmd_serve), ("train", _cmd_train)):
        dp = sub.add_parser(
            name,
            help=f"run the {name} driver in-process, then report its "
                 f"registry (driver args after --)",
        )
        dp.add_argument("--profile", default=None, metavar="DIR",
                        help="bracket the run with jax.profiler.start_trace")
        dp.add_argument("--prom", action="store_true",
                        help="report as Prometheus text")
        dp.add_argument("--out", default=None,
                        help="write the report to a file instead of stdout")
        dp.set_defaults(fn=fn)

    args = ap.parse_args(argv)
    args.driver_args = driver_args
    args.fn(args)


if __name__ == "__main__":
    main()
