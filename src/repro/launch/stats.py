"""``repro-stats``: surface the repo's telemetry (see :mod:`repro.obs`).

Metrics live in-process, so the CLI has two modes of access:

* **post-mortem** — read artifacts another run wrote: a snapshot JSON
  (``REPRO_METRICS_DUMP=snap.json`` makes any instrumented process dump one
  at exit) or the JSONL event log (``REPRO_EVENTS=events.jsonl``).
* **in-process** — drive a workload (the serve/train launchers) inside this
  process and report its registry when it finishes, optionally bracketing
  the run with ``jax.profiler.start_trace`` so the spans land on a
  TensorBoard/Perfetto timeline.

Examples::

    # pretty-print / export a snapshot another run dumped
    repro-stats snapshot --file snap.json
    repro-stats snapshot --file snap.json --prom > metrics.prom

    # tail the event log a serving or training process is appending to
    repro-stats tail --file events.jsonl -n 20 --kind train_step

    # run the serving driver here, then report (optionally with a profile)
    repro-stats serve --profile /tmp/trace -- --arch chatglm3-6b --reduced
    repro-stats train -- --arch chatglm3-6b --reduced --steps 20
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys
from typing import Dict, List, Optional

from repro import obs

__all__ = ["main"]


def _print_snapshot(snap: Dict, *, prom: bool = False, as_json: bool = False,
                    out=None) -> None:
    out = out if out is not None else sys.stdout
    if prom:
        out.write(obs.prometheus_text(snap))
        return
    if as_json:
        json.dump(snap, out, indent=2)
        out.write("\n")
        return
    counters = snap.get("counters", {})
    gauges = snap.get("gauges", {})
    hists = snap.get("histograms", {})
    if not (counters or gauges or hists):
        print("(empty registry)", file=out)
        return
    if counters:
        print("counters:", file=out)
        for name, fam in counters.items():
            for labels, v in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                print(f"  {name}{tag} = {v:g}", file=out)
    if gauges:
        print("gauges:", file=out)
        for name, fam in gauges.items():
            for labels, v in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                print(f"  {name}{tag} = {v:g}", file=out)
    if hists:
        print("histograms:", file=out)
        for name, fam in hists.items():
            for labels, h in fam.items():
                tag = f"{{{labels}}}" if labels else ""
                print(
                    f"  {name}{tag}: n={h['count']} mean={h['mean']:.6g} "
                    f"p50={h['p50']:.6g} p99={h['p99']:.6g} "
                    f"min={h['min']:.6g} max={h['max']:.6g}",
                    file=out,
                )


def _load_snapshot(path: Optional[str]) -> Dict:
    if path is None:
        return obs.snapshot()
    with open(path) as f:
        return json.load(f)


def _cmd_snapshot(args) -> None:
    snap = _load_snapshot(args.file)
    _print_snapshot(snap, prom=args.prom, as_json=args.json)


def _cmd_tail(args) -> None:
    path = args.file or obs.event_log_path()
    if path is None:
        raise SystemExit(
            "no event log: pass --file or set REPRO_EVENTS=<path> on the "
            "producing process"
        )
    try:
        events = obs.read_events(path, n=None)
    except FileNotFoundError:
        # An instrumented run that emitted no events never creates the sink;
        # an empty tail is a state worth reporting, not a crash.
        print(f"no events recorded at {path}")
        return
    if args.kind:
        events = [e for e in events if e.get("kind") == args.kind]
    for e in events[-args.n:]:
        print(json.dumps(e, default=str))


@contextlib.contextmanager
def _maybe_profile(trace_dir: Optional[str]):
    if not trace_dir:
        yield
        return
    import jax

    jax.profiler.start_trace(trace_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
        print(f"[stats] profile written to {trace_dir}", file=sys.stderr)


def _run_driver(args, driver_main) -> None:
    """Run a launch driver in-process under the span/profile bracket, then
    report this process's registry."""
    if not obs.enabled():
        print("[stats] warning: REPRO_METRICS=0 — the run will record "
              "nothing", file=sys.stderr)
    sys.argv = [sys.argv[0]] + list(args.driver_args)
    with _maybe_profile(args.profile):
        with obs.span(f"stats.{args.cmd}"):
            driver_main()
    snap = obs.snapshot()
    if args.out:
        with open(args.out, "w") as f:
            _print_snapshot(snap, prom=args.prom, as_json=not args.prom,
                            out=f)
        print(f"[stats] snapshot -> {args.out}", file=sys.stderr)
    else:
        _print_snapshot(snap, prom=args.prom)


def _cmd_serve(args) -> None:
    from repro.launch.serve import main as serve_main

    _run_driver(args, serve_main)


def _cmd_train(args) -> None:
    from repro.launch.train import main as train_main

    _run_driver(args, train_main)


def _split_driver_args(argv: List[str]) -> (List[str], List[str]):
    """Everything after ``--`` goes to the wrapped driver verbatim."""
    if "--" in argv:
        i = argv.index("--")
        return argv[:i], argv[i + 1:]
    return argv, []


def main(argv: Optional[List[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    argv, driver_args = _split_driver_args(argv)

    ap = argparse.ArgumentParser(
        prog="repro-stats",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("snapshot", help="pretty-print / export a snapshot")
    sp.add_argument("--file", default=None,
                    help="snapshot JSON written by REPRO_METRICS_DUMP "
                         "(default: this process's live registry)")
    sp.add_argument("--prom", action="store_true",
                    help="Prometheus text exposition instead of pretty text")
    sp.add_argument("--json", action="store_true",
                    help="raw JSON instead of pretty text")
    sp.set_defaults(fn=_cmd_snapshot)

    tp = sub.add_parser("tail", help="print the last events of a JSONL log")
    tp.add_argument("--file", default=None,
                    help="event log path (default: $REPRO_EVENTS)")
    tp.add_argument("-n", type=int, default=20, help="number of events")
    tp.add_argument("--kind", default=None, help="filter by event kind")
    tp.set_defaults(fn=_cmd_tail)

    for name, fn in (("serve", _cmd_serve), ("train", _cmd_train)):
        dp = sub.add_parser(
            name,
            help=f"run the {name} driver in-process, then report its "
                 f"registry (driver args after --)",
        )
        dp.add_argument("--profile", default=None, metavar="DIR",
                        help="bracket the run with jax.profiler.start_trace")
        dp.add_argument("--prom", action="store_true",
                        help="report as Prometheus text")
        dp.add_argument("--out", default=None,
                        help="write the report to a file instead of stdout")
        dp.set_defaults(fn=fn)

    args = ap.parse_args(argv)
    args.driver_args = driver_args
    args.fn(args)


if __name__ == "__main__":
    main()
