"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop (repro.train.loop) on the local
device topology. On real hardware the same entry point runs under
``jax.distributed.initialize`` (one process per host); in this container it
drives CPU-sized reduced configs end-to-end — see
``examples/train_lm.py`` for the ~100M-parameter run.
"""

from __future__ import annotations

import argparse

import jax

from repro import obs
from repro.configs import get_config
from repro.data import MarkovLMDataset, make_batch_fn
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(
            "train launcher drives LM-family archs; vlm/audio are covered by "
            "their smoke tests and the dry-run"
        )

    ds = MarkovLMDataset(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=args.seed
    )
    opt = AdamWConfig(
        peak_lr=args.lr, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
    )
    loop = TrainLoopConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        log_every=max(1, args.steps // 20),
    )
    log = obs.get_logger("train")
    res = train(
        cfg, opt, loop, make_batch_fn(ds),
        init_key=jax.random.key(args.seed), log=log.raw,
    )
    log.info(
        "done", loss_first=res.losses[0], loss_last=res.losses[-1],
        stragglers=res.straggler_steps,
    )


if __name__ == "__main__":
    main()
