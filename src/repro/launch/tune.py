"""``repro-tune``: offline empirical tile tuning for the O-POPE backends.

Tunes a workload's GEMM shape set — explicit shapes, and/or every shape a
``configs/`` model runs (harvested via the registry's shape-capture mode,
zero FLOPs) — on each requested backend, and persists the winners to a
tuning table that ``repro.kernels.ops`` consults on every later run
(``$REPRO_TUNE_TABLE``, or the committed in-package default).

Examples::

    # tune explicit dense + grouped shapes on every tunable backend here
    repro-tune --shapes 512x512x512 1024x4096x1024 --grouped 8x64x512x256

    # tune everything chatglm3-6b's training step runs at batch 8, seq 2048
    repro-tune --arch chatglm3-6b --batch 8 --seq 2048

    # CI smoke: tiny shape, interpreter backend, throwaway table
    REPRO_TUNE_TABLE=/tmp/t.json repro-tune --shapes 64x128x128 \
        --backends pallas_interpret --iters 1 --top-k 2
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro import obs
from repro.kernels import ops
from repro.tune import (
    ENV_VAR,
    GemmShape,
    TUNABLE_BACKENDS,
    TableFormatError,
    TuningTable,
    active_table_path,
    device_kind,
    harvest_model_shapes,
    tune_workload,
)

__all__ = ["main"]


def _parse_dense(spec: str, dtype: str) -> GemmShape:
    try:
        m, k, n = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"bad --shapes entry {spec!r}; want MxKxN") from None
    return GemmShape("dense", m, k, n, 0, dtype)


def _parse_grouped(spec: str, dtype: str) -> GemmShape:
    try:
        g, m, k, n = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(
            f"bad --grouped entry {spec!r}; want GxMxKxN"
        ) from None
    return GemmShape("grouped", m, k, n, g, dtype)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro-tune",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--shapes", nargs="*", default=[], metavar="MxKxN",
                    help="dense GEMM shapes to tune")
    ap.add_argument("--grouped", nargs="*", default=[], metavar="GxMxKxN",
                    help="grouped GEMM shapes to tune (per-group MxKxN)")
    ap.add_argument("--arch", action="append", default=[],
                    help="configs/ model whose GEMM shapes to harvest and "
                         "tune (repeatable)")
    ap.add_argument("--batch", type=int, default=1,
                    help="harvest batch size (with --arch)")
    ap.add_argument("--seq", type=int, default=128,
                    help="harvest sequence length (with --arch)")
    ap.add_argument("--dtype", default="float32",
                    help="operand dtype for explicit --shapes/--grouped")
    ap.add_argument("--backends", nargs="*", default=None,
                    help="backends to tune (default: every tunable backend "
                         "available on this platform)")
    ap.add_argument("--table", default=None,
                    help=f"table path (default: {active_table_path()})")
    ap.add_argument("--fresh", action="store_true",
                    help="start from an empty table instead of merging into "
                         "the existing one")
    ap.add_argument("--top-k", type=int, default=4,
                    help="modeled candidates to measure per cell (the "
                         "heuristic is always measured too)")
    ap.add_argument("--iters", type=int, default=3,
                    help="steady-state timing samples per candidate")
    ap.add_argument("--warmup", type=int, default=1,
                    help="warmup (compile-absorbing) calls per candidate")
    ap.add_argument("--list-backends", action="store_true",
                    help="print tunable/available backends and exit")
    args = ap.parse_args(argv)
    log = obs.get_logger("tune")

    tunable = [
        b for b in ops.tunable_backends()
        if b in TUNABLE_BACKENDS and b in ops.available_backends()
    ]
    if args.list_backends:
        log.raw(f"tunable backends on {device_kind()}: {tunable}")
        return

    if args.backends is None:
        backends = tunable
    else:
        unknown = [b for b in args.backends if b not in TUNABLE_BACKENDS]
        if unknown:
            raise SystemExit(
                f"not tunable: {unknown} (no block_*= knob); "
                f"tunable: {sorted(TUNABLE_BACKENDS)}"
            )
        # Availability matters for explicit requests too: timing a compiled
        # backend where it cannot lower would die in the kernel, not here.
        unavailable = [
            b for b in args.backends if b not in ops.available_backends()
        ]
        if unavailable:
            raise SystemExit(
                f"not available on {device_kind()}: {unavailable}; "
                f"tunable here: {tunable}"
            )
        backends = list(args.backends)
    if not backends:
        raise SystemExit("no tunable backend available on this platform")

    shapes: List[GemmShape] = []
    shapes += [_parse_dense(s, args.dtype) for s in args.shapes]
    shapes += [_parse_grouped(s, args.dtype) for s in args.grouped]
    for arch in args.arch:
        harvested = harvest_model_shapes(
            arch, batch=args.batch, seq=args.seq
        )
        log.raw(f"harvested {len(harvested)} GEMM shapes from {arch} "
              f"(batch={args.batch}, seq={args.seq})")
        shapes += harvested
    shapes = list(dict.fromkeys(shapes))  # dedupe, keep order
    if not shapes:
        raise SystemExit("nothing to tune: pass --shapes/--grouped/--arch")

    path = args.table or active_table_path()
    table = TuningTable()
    if not args.fresh:
        try:
            table.merge(TuningTable.load(path))
            log.raw(f"merging into {len(table)} existing entries from {path}")
        except FileNotFoundError:
            pass
        except TableFormatError as e:
            log.raw(f"ignoring unusable existing table at {path}: {e}")

    log.raw(f"tuning {len(shapes)} shapes x {len(backends)} backends "
          f"on {device_kind()} (top-{args.top_k} of the modeled candidates, "
          f"{args.iters} samples each)")
    tune_workload(
        shapes, backends=backends, table=table,
        top_k=args.top_k, iters=args.iters, warmup=args.warmup,
        log=lambda line: log.raw("  " + line),
    )
    table.save(path)
    ops.clear_tile_cache()  # this process re-reads the table it just wrote
    log.raw(f"wrote {len(table)} entries -> {path}")
    if path == active_table_path():
        if os.environ.get(ENV_VAR):
            log.raw(f"active while REPRO_TUNE_TABLE={path} is set")
        else:
            log.raw("written to the default location; active automatically")
    else:
        log.raw(f"activate with: REPRO_TUNE_TABLE={path}")


if __name__ == "__main__":
    main(sys.argv[1:])
