"""Model zoo: all 10 assigned architecture families, pure-JAX, scan-stacked."""

from . import attention, encdec, layers, mamba, moe, transformer, vlm, xlstm

__all__ = [
    "attention",
    "encdec",
    "layers",
    "mamba",
    "moe",
    "transformer",
    "vlm",
    "xlstm",
]
