"""Family-dispatched model API: one surface for all 10 architectures.

Batch dicts (matching ``launch.dryrun.input_specs``):

* LM families (dense/moe/hybrid/ssm): ``{"tokens", "labels"}``
* vlm:   ``{"tokens", "labels", "patch_embeds"}``
* audio: ``{"frames", "tokens", "labels"}``

Decode state is ``(caches, pos)`` where ``caches`` is the family's stacked
cache pytree and ``pos`` the current sequence position (int32 scalar).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec as encdec_mod
from . import transformer as tf_mod
from . import vlm as vlm_mod
from .attention import KVCache

__all__ = [
    "init_params",
    "input_specs",
    "loss_fn",
    "prefill",
    "prefill_bucketed",
    "prefill_chunk",
    "decode",
    "decode_at",
    "init_state",
    "param_count",
    "active_param_count",
]


def init_params(cfg: ArchConfig, key: jax.Array):
    if cfg.family == "audio":
        return encdec_mod.init_encdec_params(cfg, key)
    if cfg.family == "vlm":
        return vlm_mod.init_vlm_params(cfg, key)
    return tf_mod.init_lm_params(cfg, key)


def input_specs(
    cfg: ArchConfig, *, batch: int, seq: int, kind: str = "train"
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    The single owner of the per-family batch layout (tokens/labels,
    vlm ``patch_embeds``, audio ``frames``; ``kind="decode"`` is one new
    token against caches of length ``seq``). ``launch.dryrun`` and the
    autotuner's workload harvest (``repro.tune.capture``) both build their
    abstract batches here — it lives in this module, not the dry-run
    launcher, because importing the launcher force-sets the host device
    count as an import side effect.
    """
    i32 = jnp.int32
    if kind in ("train", "prefill"):
        s_text = seq - cfg.n_img_tokens if cfg.family == "vlm" else seq
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((batch, s_text), i32),
        }
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, s_text), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        return specs
    return {"token": jax.ShapeDtypeStruct((batch, 1), i32)}


def loss_fn(
    cfg: ArchConfig, params, batch: Dict[str, jax.Array], *, backend=None
) -> jax.Array:
    """Training loss. ``backend`` is a matmul backend name or a
    :class:`repro.quant.policy.PrecisionPolicy` (role-resolved per layer);
    gradients through quantized backends run full-precision by registry rule,
    so the fp32 master path of the train step is untouched by any policy."""
    if cfg.family == "audio":
        return encdec_mod.encdec_loss(
            params, batch["frames"], batch["tokens"], batch["labels"], cfg,
            backend=backend,
        )
    if cfg.family == "vlm":
        return vlm_mod.vlm_loss(
            params, batch["tokens"], batch["patch_embeds"], batch["labels"],
            cfg, backend=backend,
        )
    return tf_mod.lm_loss(
        params, batch["tokens"], batch["labels"], cfg, backend=backend
    )


def init_state(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode caches sized for ``max_len`` total positions."""
    if cfg.family == "audio":
        return encdec_mod.init_decoder_caches(cfg, batch, max_len, dtype)
    return tf_mod.init_caches(cfg, batch, max_len, dtype)


def prefill(
    cfg: ArchConfig, params, batch: Dict[str, jax.Array], max_len: int,
    cache_dtype=jnp.bfloat16, *, backend=None,
) -> Tuple[jax.Array, Any]:
    """Process the full prompt; return (last-token logits [B,V], caches).

    ``backend`` is a matmul backend name or a
    :class:`repro.quant.policy.PrecisionPolicy` (role-resolved per layer) —
    the serving-side entry points accept the same precision plumbing as
    :func:`loss_fn`."""
    if cfg.family == "audio":
        enc_out = encdec_mod.encode(params, batch["frames"], cfg, backend=backend)
        caches = encdec_mod.init_decoder_caches(
            cfg, batch["tokens"].shape[0], max_len, cache_dtype
        )
        hidden, caches = encdec_mod.decoder_forward(
            params, batch["tokens"], cfg, enc_out=enc_out, caches=caches,
            mode="prefill", backend=backend,
        )
        logits = jnp.einsum(
            "bd,vd->bv", hidden[:, -1], params["embed"]["table"],
            preferred_element_type=jnp.float32,
        )
        return logits, caches

    b = batch["tokens"].shape[0]
    caches = tf_mod.init_caches(cfg, b, max_len, cache_dtype)
    extra = None
    if cfg.family == "vlm":
        extra = vlm_mod.project_image(params, batch["patch_embeds"])
    hidden, caches, _ = tf_mod.lm_forward(
        params, batch["tokens"], cfg, mode="prefill", caches=caches,
        extra_embeds=extra, backend=backend,
    )
    logits = tf_mod.lm_logits(params, hidden[:, -1:], cfg)[:, 0]
    return logits, caches


def decode(
    cfg: ArchConfig, params, token: jax.Array, caches, pos: jax.Array,
    *, backend=None,
) -> Tuple[jax.Array, Any]:
    """One decode step. token: [B, 1] -> (logits [B, V], new caches)."""
    if cfg.family == "audio":
        hidden, caches = encdec_mod.decoder_forward(
            params, token, cfg, caches=caches, mode="decode", backend=backend
        )
        logits = jnp.einsum(
            "bd,vd->bv", hidden[:, 0], params["embed"]["table"],
            preferred_element_type=jnp.float32,
        )
        return logits, caches
    b = token.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    hidden, caches, _ = tf_mod.lm_forward(
        params, token, cfg, mode="decode", caches=caches, positions=positions,
        backend=backend,
    )
    logits = tf_mod.lm_logits(params, hidden, cfg)[:, 0]
    return logits, caches


def prefill_bucketed(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    lengths: jax.Array,
    cache_dtype=jnp.bfloat16,
    *,
    backend=None,
) -> Tuple[jax.Array, Any]:
    """Prefill a right-padded prompt bucket: tokens [B, Lb], lengths [B].

    Rows shorter than the bucket are right-padded; causal attention makes the
    pad positions invisible to every real token, so the returned logits — read
    at each row's ``lengths[b] - 1`` — are exactly the unpadded prefill
    logits. The returned caches span the bucket length ``Lb`` (pad K/V beyond
    a row's length is masked out by the per-slot decode mask downstream).

    Token-prompt LM families only (audio needs encoder frames, vlm needs
    image embeddings). Padding flows *through* recurrent state (mamba/
    xlstm), so the serving scheduler uses exact-length buckets there.
    """
    if cfg.family in ("audio", "vlm"):
        raise NotImplementedError(
            f"bucketed prefill: token-prompt LM families only, not {cfg.family}"
        )
    b, lb = tokens.shape
    caches = tf_mod.init_caches(cfg, b, lb, cache_dtype)
    hidden, caches, _ = tf_mod.lm_forward(
        params, tokens, cfg, mode="prefill", caches=caches, backend=backend
    )
    last = hidden[jnp.arange(b), lengths.astype(jnp.int32) - 1]
    logits = tf_mod.lm_logits(params, last[:, None], cfg)[:, 0]
    return logits, caches


def prefill_chunk(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    caches,
    offsets: jax.Array,
    last_idx: jax.Array,
    *,
    backend=None,
) -> Tuple[jax.Array, Any]:
    """Advance a prompt-chunk window: tokens [B, C] appended at per-row
    ``offsets[b]``; returns (logits [B, V], caches).

    The resume-from-cached-length prefill entry: row ``b``'s chunk occupies
    absolute positions ``offsets[b] .. offsets[b]+C-1`` of its cache — which
    may start past 0 because earlier chunks (or a reused prefix-cache span)
    already fill positions below ``offsets[b]``. Like :func:`decode_at`,
    ``offsets`` is the source of truth for cache fill, so a cache attached
    from the prefix trie needs no per-layer counter surgery. Rows whose
    prompt is already exhausted pass a sentinel offset ``>= S_max`` — every
    write drops and their lane is pure ballast in the fused step.

    Logits are read at chunk index ``last_idx[b]`` (the row's final prompt
    token when this chunk finishes it; don't-care otherwise — callers mask).
    Token-prompt attention-only LM families; recurrent mixers raise inside
    the forward (state can't resume from a scatter).
    """
    if cfg.family in ("audio", "vlm"):
        raise NotImplementedError(
            f"chunked prefill: token-prompt LM families only, not {cfg.family}"
        )
    b, c = tokens.shape
    offsets = offsets.astype(jnp.int32)
    positions = offsets[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    caches = _with_slot_lengths(caches, offsets)
    hidden, caches, _ = tf_mod.lm_forward(
        params, tokens, cfg, mode="chunk", caches=caches,
        positions=positions, backend=backend,
    )
    last = hidden[jnp.arange(b), last_idx.astype(jnp.int32)]
    logits = tf_mod.lm_logits(params, last[:, None], cfg)[:, 0]
    return logits, caches


def decode_at(
    cfg: ArchConfig, params, token: jax.Array, caches, pos: jax.Array,
    *, backend=None,
) -> Tuple[jax.Array, Any]:
    """Slot-indexed decode step: per-row positions. token [B,1], pos [B].

    Row ``b`` appends its K/V at ``pos[b]`` and attends over its own history
    (``kp <= pos[b]``) — the entry point the continuous-batching pool drives,
    where each batch lane is an independently-positioned request slot. ``pos``
    is the source of truth: per-layer cache fill counters are overwritten from
    it, so a pool whose slots were joined/recycled by scatter stays coherent
    without per-layer bookkeeping.
    """
    if cfg.family == "audio":
        raise NotImplementedError(
            "slot-indexed decode: decoder-only LM families only"
        )
    pos = pos.astype(jnp.int32)
    caches = _with_slot_lengths(caches, pos)
    hidden, caches, _ = tf_mod.lm_forward(
        params, token, cfg, mode="decode", caches=caches,
        positions=pos[:, None], backend=backend,
    )
    logits = tf_mod.lm_logits(params, hidden, cfg)[:, 0]
    return logits, caches


def _with_slot_lengths(caches, pos: jax.Array):
    """Reset every stacked (Quant)KVCache fill counter to the per-slot
    positions."""
    from repro.quant.kvcache import QuantKVCache

    out = []
    for c in caches:
        if isinstance(c, (KVCache, QuantKVCache)):
            n_periods = c.k.shape[0]
            out.append(
                c._replace(
                    length=jnp.broadcast_to(
                        pos[None], (n_periods,) + pos.shape
                    )
                )
            )
        else:
            out.append(c)
    return tuple(out)


def param_count(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ArchConfig) -> int:
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    expert_params_per_layer = 3 * cfg.d_model * cfg.moe.d_ff_expert
    n_moe_layers = cfg.n_periods * sum(1 for b in cfg.pattern if b.ffn == "moe")
    routed_total = n_moe_layers * cfg.moe.n_experts * expert_params_per_layer
    routed_active = n_moe_layers * cfg.moe.top_k * expert_params_per_layer
    return total - routed_total + routed_active
