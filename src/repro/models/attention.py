"""GQA attention with O-POPE-style blockwise accumulation.

The full-sequence path (:func:`blockwise_attention`) applies the paper's
output-stationary insight one level up: the per-query-block softmax state
``(m, l, acc)`` stays resident while KV panels stream through — never
materializing the S x T score matrix. Query blocks are unrolled in Python so
causal / sliding-window structure prunes KV panels *statically*: HLO FLOPs
stay close to the useful FLOPs (this shows up directly in the roofline's
useful-compute ratio).

Features (driven by the arch configs): grouped KV heads, RoPE with partial
rotary fraction (chatglm3's 2-D RoPE), sliding windows (gemma2 local layers),
attention logit soft-capping (gemma2), QKV bias (qwen2.5), bidirectional mode
(whisper encoder), cross-attention (whisper decoder), and single-token decode
against a (possibly sequence-sharded) KV cache — split-K flash-decoding, with
the partial-softmax reduction handled by GSPMD.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import Initializer, apply_rope, dense_init, role_backend, softcap

__all__ = [
    "AttentionParams",
    "attention_init",
    "attention_apply",
    "blockwise_attention",
    "chunk_attention",
    "decode_attention",
    "KVCache",
]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


class KVCache(NamedTuple):
    """Decode-time cache. k/v: [B, S_max, H_kv * D]; length: current fill.

    ``length`` is a scalar when every row advances in lockstep (the static
    ``ServeEngine`` path) or an int32 ``[B]`` vector when rows are
    independently-positioned slots of the continuous-batching pool — each
    row then appends at its own ``length[b]`` and masks its own history.

    The head dim is stored FUSED: ``H_kv * D`` always divides the 16-way
    model axis (individual head counts often don't), and the fused layout is
    exactly what the K/V projections produce — so prefill writes the cache
    with zero resharding and decode shards TP-style over the head dim.
    """

    k: jax.Array
    v: jax.Array
    length: jax.Array  # int32: [] lockstep, or [B] per-slot

    @staticmethod
    def zeros(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
        return KVCache(
            k=jnp.zeros((batch, max_len, n_kv * head_dim), dtype),
            v=jnp.zeros((batch, max_len, n_kv * head_dim), dtype),
            length=jnp.zeros((), jnp.int32),
        )


def attention_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    init: Initializer,
    *,
    qkv_bias: bool = False,
):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, init, bias=qkv_bias),
        "wk": dense_init(kk, d_model, n_kv * head_dim, init, bias=qkv_bias),
        "wv": dense_init(kv, d_model, n_kv * head_dim, init, bias=qkv_bias),
        "wo": dense_init(ko, n_heads * head_dim, d_model, init),
    }


def _project_qkv(params, x, kv_x, n_heads, n_kv, head_dim, backend):
    """QKV projections on the O-POPE path (bias fused via C-preload)."""
    b, s, _ = x.shape
    t = kv_x.shape[1]
    backend = role_backend(backend, "attn_qkv")
    q = ops.linear(x, params["wq"]["w"], params["wq"].get("b"), backend=backend)
    k = ops.linear(kv_x, params["wk"]["w"], params["wk"].get("b"), backend=backend)
    v = ops.linear(kv_x, params["wv"]["w"], params["wv"].get("b"), backend=backend)
    return (
        q.reshape(b, s, n_heads, head_dim),
        k.reshape(b, t, n_kv, head_dim),
        v.reshape(b, t, n_kv, head_dim),
    )


def _block_scores(q, k, scale, cap):
    """Panel scores [B, Hkv, G, qc, kc] in fp32 (widening accumulation)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
    seq_shard: bool = False,
) -> jax.Array:
    """Online-softmax attention. q: [B,S,Hq,D]; k/v: [B,T,Hkv,D] -> [B,S,Hq,D].

    Memory: O(S*D + q_chunk*kv_chunk) per head group instead of O(S*T).
    Causal/window KV ranges are static per query block (Python unrolled), so
    pruned panels cost zero HLO FLOPs.

    ``seq_shard=True`` (context-parallel core, §Perf hillclimb): query rows
    shard over the ``model`` axis and KV panels replicate across it. Without
    this, head counts that don't divide the model axis (qwen's 40, every
    GQA kv<16) make GSPMD REPLICATE the score/PV einsums on all 16 model
    shards — 16x wasted FLOPs and a swarm of partial-sum all-reduces.
    """
    from repro.distributed.hints import constrain

    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    qc = min(q_chunk, s)
    while s % qc:
        qc -= 1
    kc = min(kv_chunk, t)
    while t % kc:
        kc -= 1
    nq, nkv = s // qc, t // kc

    qg = q.reshape(b, nq, qc, hkv, g, d)
    kr = k.reshape(b, nkv, kc, hkv, d)
    vr = v.reshape(b, nkv, kc, hkv, d)
    if seq_shard:
        dp = ("pod", "data")
        qg = constrain(qg, dp, None, "model", None, None, None)
        kr = constrain(kr, dp, None, None, None, None)
        vr = constrain(vr, dp, None, None, None, None)
    k_pos = jnp.arange(t).reshape(nkv, kc)

    # named_scope: the online-softmax state updates (exp / max / rescale) are
    # softmax math coupled to the streaming reduction, not GEMM-writeback
    # passes — exempted by the decode-step HLO census.
    with jax.named_scope("attn_core"):
        return _blockwise_body(
            qg, kr, vr, k_pos, b, s, t, hq, hkv, g, d, qc, kc, nq, nkv,
            causal, window, attn_softcap, q_offset, scale, q.dtype,
        )


def _blockwise_body(
    qg, kr, vr, k_pos, b, s, t, hq, hkv, g, d, qc, kc, nq, nkv,
    causal, window, attn_softcap, q_offset, scale, out_dtype,
):
    outs = []
    for i in range(nq):
        q_i = qg[:, i]  # [B, qc, Hkv, G, D]
        q_pos = q_offset + i * qc + jnp.arange(qc)
        # Static KV panel range for this query block:
        hi = nkv if not causal else min(
            nkv, math.ceil((q_offset + (i + 1) * qc) / kc)
        )
        lo = 0 if window is None else max(
            0, (q_offset + i * qc - window) // kc
        )
        m = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, qc), jnp.float32)
        acc = jnp.zeros((b, hkv, g, qc, d), jnp.float32)

        def panel(carry, j, q_i=q_i, q_pos=q_pos):
            m, l, acc = carry
            s_ij = _block_scores(q_i, kr[:, j], scale, attn_softcap)
            kp = k_pos[j]
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kp[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kp[None, :] > q_pos[:, None] - window
            s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            # bf16 x bf16 -> f32 accumulate; no f32 copy of the V panel.
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vr.dtype), vr[:, j],
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        if hi > lo:
            (m, l, acc), _ = jax.lax.scan(
                panel, (m, l, acc), jnp.arange(lo, hi)
            )
        out_i = acc / jnp.maximum(l[..., None], 1e-37)
        outs.append(out_i.transpose(0, 3, 1, 2, 4).reshape(b, qc, hq, d))
    return jnp.concatenate(outs, axis=1).astype(out_dtype)


def decode_attention(
    q: jax.Array,
    cache: KVCache,
    *,
    n_kv: int,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against the cache. q: [B,1,Hq,D] -> [B,1,Hq,D].

    With the cache sequence axis sharded (long-context cells) the einsums
    below become split-K partial softmaxes reduced by GSPMD — the
    flash-decoding pattern, no score matrix materialized beyond [.., S_max].
    """
    from repro.distributed.hints import constrain

    b, _, hq, d = q.shape
    t = cache.k.shape[1]
    hkv = n_kv
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    # Split-K layout: the decode cache shards its SEQUENCE axis — over
    # `model` for batched decode, over every axis when B=1 (long-context SP;
    # mirrors distributed.sharding.cache_shardings). The single query is
    # replicated across the sequence shards (bytes: one token). Scores stay
    # sequence-sharded; softmax stats and the PV partial reduce via psum —
    # flash-decoding assembled by GSPMD. The k/v constraints below pin the
    # post-reshape layout: without them GSPMD re-shards the whole cache to a
    # head-factorized layout (measured: a 2.1 GB all-gather per layer per
    # token on the 500k-context cell).
    if b == 1:
        batch_ax = None
        seq_ax = ("pod", "data", "model")
    else:
        batch_ax = ("pod", "data")
        seq_ax = "model"
    qg = constrain(q.reshape(b, 1, hkv, g, d), batch_ax, None, None, None, None)
    k = constrain(cache.k.reshape(b, t, hkv, d), batch_ax, seq_ax, None, None)
    v = constrain(cache.v.reshape(b, t, hkv, d), batch_ax, seq_ax, None, None)
    # named_scope: scores / masking / softmax / PV are the attention core —
    # reduction-coupled softmax math, not GEMM-writeback passes — exempted
    # by the decode-step HLO census.
    with jax.named_scope("attn_core"):
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
        ) * scale
        s = constrain(s, batch_ax, None, None, None, seq_ax)
        if attn_softcap is not None:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        kp = jnp.arange(t)
        ln = cache.length.reshape(-1)  # [] -> [1] (lockstep) or [B] (per-slot)
        valid = kp[None, :] < ln[:, None]
        if window is not None:
            valid &= kp[None, :] > ln[:, None] - 1 - window
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # bf16 x bf16 -> f32 accumulate (widening MAC); no f32 cache copy.
        o = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, d).astype(q.dtype)


def chunk_attention(
    q: jax.Array,
    cache: KVCache,
    q_pos: jax.Array,
    *,
    n_kv: int,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Chunked-prefill attention: C query tokens per row, each at its own
    absolute position, against the row's cache history.
    q: [B,C,Hq,D]; q_pos: [B,C] int32 -> [B,C,Hq,D].

    The multi-token sibling of :func:`decode_attention`: query token
    ``(b, j)`` attends over every cache position ``kp <= q_pos[b, j]`` — the
    chunk's own K/V has already been appended at those positions, so
    causality *within* the chunk and attention over the previously-filled
    prefix (earlier chunks, or a reused cached prefix) are one mask. Rows in
    the same dispatch may sit at different offsets (one mid-prompt, one
    resuming from a shared-prefix cache), which is what lets one compiled
    chunk step serve a mixed join batch.
    """
    b, c, hq, d = q.shape
    t = cache.k.shape[1]
    hkv = n_kv
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    qg = q.reshape(b, c, hkv, g, d)
    k = cache.k.reshape(b, t, hkv, d)
    v = cache.v.reshape(b, t, hkv, d)
    # named_scope: scores / masking / softmax / PV are the attention core —
    # reduction-coupled softmax math, not GEMM-writeback passes — exempted
    # by the decode-step HLO census.
    with jax.named_scope("attn_core"):
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
        ) * scale
        if attn_softcap is not None:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        kp = jnp.arange(t)
        valid = kp[None, None, :] <= q_pos[:, :, None]  # [B, C, T]
        if window is not None:
            valid &= kp[None, None, :] > q_pos[:, :, None] - window
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # bf16 x bf16 -> f32 accumulate (widening MAC); no f32 cache copy.
        o = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return o.transpose(0, 3, 1, 2, 4).reshape(b, c, hq, d).astype(q.dtype)


def attention_apply(
    params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: Optional[jax.Array] = None,
    rotary_frac: float = 1.0,
    rope_theta: float = 10000.0,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    cross_x: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    seq_shard: bool = False,
    backend: Optional[str] = None,
    residual: Optional[jax.Array] = None,
    chunk: bool = False,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Full attention block: projections + RoPE + core + output projection.

    ``residual`` fuses the caller's skip connection into the output
    projection's writeback (a ``residual`` epilogue step) — the attention
    output is materialized exactly once, already summed into the stream.

    Modes:
    * ``cache is None``      — training / prefill without cache.
    * ``cache`` + ``x.shape[1] == 1`` — single-token decode (append + attend).
    * ``cache`` + ``chunk=True`` — chunked prefill: append C tokens at the
      per-row ``positions`` (which may start past 0 — resuming from earlier
      chunks or a reused cached prefix), attend over the cache history.
    * ``cache`` + longer x   — prefill that fills and returns the cache.
    * ``cross_x``            — cross-attention (no RoPE on KV, not causal).
    """
    b, s, _ = x.shape
    kv_src = cross_x if cross_x is not None else x
    q, k, v = _project_qkv(params, x, kv_src, n_heads, n_kv, head_dim, backend)

    if cross_x is None:
        if positions is None:
            positions = jnp.arange(s)[None, :].astype(jnp.int32)
        if rotary_frac > 0:
            q = apply_rope(q, positions, rotary_frac=rotary_frac, theta=rope_theta)
            k = apply_rope(k, positions, rotary_frac=rotary_frac, theta=rope_theta)

    new_cache = None
    if cache is not None and chunk and s > 1:
        # Chunked prefill: scatter this chunk's K/V at the per-row absolute
        # positions, then attend each query token over its own history. Rows
        # that finished in an earlier chunk carry sentinel positions >= S_max
        # so every one of their writes drops.
        from repro.quant.kvcache import QuantKVCache

        if isinstance(cache, QuantKVCache):
            # Chunks fill standalone FULL-PRECISION caches; quantization
            # happens once, at the slot-pool join scatter, where scales are
            # calibrated over the complete prompt span (and adopted from the
            # cached prefix). Mid-prompt quantization would fix scales before
            # the span's amax is known — refuse loudly.
            raise NotImplementedError(
                "chunked prefill into a QuantKVCache is unsupported: chunk "
                "into a full-precision cache and quantize at the slot-pool "
                "join (serve.cache.scatter_slots)"
            )
        rows = jnp.arange(b)[:, None]
        kf = k.reshape(b, s, n_kv * head_dim).astype(cache.k.dtype)
        vf = v.reshape(b, s, n_kv * head_dim).astype(cache.v.dtype)
        new_cache = KVCache(
            k=cache.k.at[rows, positions].set(kf, mode="drop"),
            v=cache.v.at[rows, positions].set(vf, mode="drop"),
            length=positions[:, -1].astype(jnp.int32) + 1,
        )
        o = chunk_attention(
            q, new_cache, positions,
            n_kv=n_kv, window=window, attn_softcap=attn_softcap,
        )
    elif cache is not None and s == 1:
        # Decode: append one token (fused-head layout), attend over the cache.
        from repro.quant.kvcache import QuantKVCache

        idx = cache.length
        if isinstance(cache, QuantKVCache):
            # Narrow K/V lanes (serving): the appended token quantizes
            # through the slot's fixed per-head scales, and attention reads
            # a dequantized view built INSIDE this fused step — wide K/V
            # never exists outside it.
            kq, vq = cache.quantize_rows(
                k.reshape(b, n_kv * head_dim), v.reshape(b, n_kv * head_dim)
            )
            if idx.ndim:
                rows = jnp.arange(b)
                new_cache = cache._replace(
                    k=cache.k.at[rows, idx].set(kq, mode="drop"),
                    v=cache.v.at[rows, idx].set(vq, mode="drop"),
                    length=idx + 1,
                )
            else:
                new_cache = cache._replace(
                    k=jax.lax.dynamic_update_slice_in_dim(
                        cache.k, kq[:, None], idx, axis=1
                    ),
                    v=jax.lax.dynamic_update_slice_in_dim(
                        cache.v, vq[:, None], idx, axis=1
                    ),
                    length=cache.length + 1,
                )
            attend_over = KVCache(
                k=new_cache.dequant_k(jnp.float32),
                v=new_cache.dequant_v(jnp.float32),
                length=new_cache.length,
            )
        else:
            kf = k.reshape(b, 1, n_kv * head_dim).astype(cache.k.dtype)
            vf = v.reshape(b, 1, n_kv * head_dim).astype(cache.v.dtype)
            if idx.ndim:
                # Per-slot positions (continuous batching): each row writes at
                # its own fill point. Positions stay < S_max in practice (a
                # retired lane freezes at a valid position and its dead writes
                # are masked, then overwritten by the next join); mode="drop" is
                # defense-in-depth so an out-of-range position could never
                # clobber position 0.
                rows = jnp.arange(b)
                new_cache = KVCache(
                    k=cache.k.at[rows, idx].set(kf[:, 0], mode="drop"),
                    v=cache.v.at[rows, idx].set(vf[:, 0], mode="drop"),
                    length=idx + 1,
                )
            else:
                new_cache = KVCache(
                    k=jax.lax.dynamic_update_slice_in_dim(cache.k, kf, idx, axis=1),
                    v=jax.lax.dynamic_update_slice_in_dim(cache.v, vf, idx, axis=1),
                    length=cache.length + 1,
                )
            attend_over = new_cache
        o = decode_attention(
            q, attend_over, n_kv=n_kv, window=window, attn_softcap=attn_softcap
        )
    else:
        q_offset = 0
        o = blockwise_attention(
            q,
            k,
            v,
            causal=causal and cross_x is None,
            window=window,
            attn_softcap=attn_softcap,
            q_offset=q_offset,
            q_chunk=q_chunk,
            kv_chunk=kv_chunk,
            seq_shard=seq_shard,
        )
        if cache is not None:
            from repro.quant.kvcache import QuantKVCache

            if isinstance(cache, QuantKVCache):
                # Prefill writes raw K/V; quantization happens at the join
                # scatter (serve.cache), where per-slot scales are calibrated
                # from the finished prompt span. Filling a quantized cache
                # here would cast unscaled floats to int8 — corruption, not
                # quantization — so refuse loudly.
                raise NotImplementedError(
                    "prefill into a QuantKVCache is unsupported: prefill "
                    "full-precision caches and quantize at the slot-pool "
                    "join (serve.cache.scatter_slots)"
                )
            # Prefill: install computed K/V (fused-head layout, matching the
            # projection output sharding — no reshard).
            t = k.shape[1]
            kf = k.reshape(b, t, n_kv * head_dim).astype(cache.k.dtype)
            vf = v.reshape(b, t, n_kv * head_dim).astype(cache.v.dtype)
            new_cache = KVCache(
                k=jax.lax.dynamic_update_slice_in_dim(cache.k, kf, 0, axis=1),
                v=jax.lax.dynamic_update_slice_in_dim(cache.v, vf, 0, axis=1),
                length=jnp.asarray(s, jnp.int32),
            )
    out = ops.matmul(
        o.reshape(b, s, n_heads * head_dim), params["wo"]["w"],
        backend=role_backend(backend, "attn_out"),
        epilogue=[("residual", residual)] if residual is not None else None,
    )
    return out, new_cache
