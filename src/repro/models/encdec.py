"""Encoder-decoder backbone (whisper-base).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings [B, enc_seq, D] (the output the two
strided convs would produce). Everything downstream is real: a bidirectional
encoder, a causal decoder with cross-attention, learned positional
embeddings (whisper uses sinusoidal for the encoder; learned here for both —
noted in DESIGN.md), KV caches for decoder self-attention, and precomputed
cross-attention K/V at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from . import attention as attn_mod
from .attention import KVCache
from .layers import Initializer, embedding_init, layernorm, layernorm_init, mlp_apply, mlp_init

__all__ = [
    "init_encdec_params",
    "encode",
    "decoder_forward",
    "encdec_loss",
    "init_decoder_caches",
    "EncDecCaches",
]


class EncDecCaches(NamedTuple):
    self_kv: Any  # stacked KVCache over decoder layers
    cross_k: jax.Array  # [L, B, T_enc, H_kv, Dh]
    cross_v: jax.Array


def _enc_block_init(cfg: ArchConfig, key, init):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layernorm_init(cfg.d_model),
        "attn": attn_mod.attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim_, init
        ),
        "norm2": layernorm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, init, gated=False),
    }


def _dec_block_init(cfg: ArchConfig, key, init):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layernorm_init(cfg.d_model),
        "self_attn": attn_mod.attention_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim_, init
        ),
        "norm2": layernorm_init(cfg.d_model),
        "cross_attn": attn_mod.attention_init(
            k2, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim_, init
        ),
        "norm3": layernorm_init(cfg.d_model),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, init, gated=False),
    }


def init_encdec_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    init = Initializer(dtype=jnp.dtype(cfg.param_dtype))
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": init(ks[2], (cfg.enc_seq, cfg.d_model)),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(cfg, k, init))(enc_keys),
        "enc_norm": layernorm_init(cfg.d_model),
        "embed": embedding_init(ks[3], cfg.vocab, cfg.d_model, init),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(cfg, k, init))(dec_keys),
        "dec_norm": layernorm_init(cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ArchConfig, *, backend=None) -> jax.Array:
    """frames: [B, T_enc, D] (stub frontend output) -> encoder states."""
    x = frames + params["enc_pos"][None].astype(frames.dtype)

    def body(x, p):
        # Both residual adds ride GEMM writeback epilogues (attention wo /
        # MLP down projection).
        x, _ = attn_mod.attention_apply(
            p["attn"],
            layernorm(p["norm1"], x),
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=cfg.head_dim_,
            rotary_frac=0.0,
            causal=False,
            q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk,
            backend=backend,
            residual=x,
        )
        x = mlp_apply(
            p["mlp"], layernorm(p["norm2"], x), activation="gelu",
            backend=backend, residual=x,
        )
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x)


def _cross_kv(params_blocks, enc_out, cfg, backend):
    """Precompute cross-attention K/V for all decoder layers: [L,B,T,H,D]."""

    def one(p):
        b, t, _ = enc_out.shape
        k = ops.linear(enc_out, p["cross_attn"]["wk"]["w"], backend=backend)
        v = ops.linear(enc_out, p["cross_attn"]["wv"]["w"], backend=backend)
        return (
            k.reshape(b, t, cfg.n_kv, cfg.head_dim_),
            v.reshape(b, t, cfg.n_kv, cfg.head_dim_),
        )

    return jax.vmap(one)(params_blocks)


def init_decoder_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv = KVCache.zeros(batch, max_len, cfg.n_kv, cfg.head_dim_, dtype)
    self_kv = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), kv
    )
    cross = jnp.zeros(
        (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim_), dtype
    )
    return EncDecCaches(self_kv=self_kv, cross_k=cross, cross_v=cross)


def decoder_forward(
    params,
    tokens: jax.Array,
    cfg: ArchConfig,
    *,
    enc_out: Optional[jax.Array] = None,
    caches: Optional[EncDecCaches] = None,
    mode: str = "train",
    backend=None,
):
    """Decoder over tokens. Cross K/V come from ``enc_out`` (train/prefill)
    or from ``caches`` (decode). Returns (hidden, new_caches)."""
    x = params["embed"]["table"][tokens]
    b, s, _ = x.shape
    if enc_out is not None:
        ck, cv = _cross_kv(params["dec_blocks"], enc_out, cfg, backend)
    else:
        ck, cv = caches.cross_k, caches.cross_v

    have_cache = caches is not None
    # (whisper uses no RoPE — rotary_frac=0 — so decode positions are not
    # needed by the attention core; the cache length handles masking.)

    def body(x, xs):
        p, ckl, cvl, kv = xs if have_cache else (*xs, None)
        # All three residual adds ride GEMM writeback epilogues (self-attn
        # wo, cross-attn wo, MLP down projection).
        x, new_kv = attn_mod.attention_apply(
            p["self_attn"],
            layernorm(p["norm1"], x),
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=cfg.head_dim_,
            rotary_frac=0.0,
            causal=True,
            cache=kv,
            q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk,
            backend=backend,
            residual=x,
        )
        # Cross attention against precomputed K/V.
        q = ops.linear(
            layernorm(p["norm2"], x), p["cross_attn"]["wq"]["w"], backend=backend
        ).reshape(b, x.shape[1], cfg.n_heads, cfg.head_dim_)
        o = attn_mod.blockwise_attention(
            q, ckl, cvl, causal=False,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
        x = ops.matmul(
            o.reshape(b, x.shape[1], cfg.n_heads * cfg.head_dim_),
            p["cross_attn"]["wo"]["w"],
            backend=backend,
            epilogue=[("residual", x)],
        )
        x = mlp_apply(
            p["mlp"], layernorm(p["norm3"], x), activation="gelu",
            backend=backend, residual=x,
        )
        return x, new_kv

    xs = (params["dec_blocks"], ck, cv)
    if have_cache:
        xs = xs + (caches.self_kv,)
    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    x, new_kv = jax.lax.scan(body_fn, x, xs)
    x = layernorm(params["dec_norm"], x)
    new_caches = (
        EncDecCaches(self_kv=new_kv, cross_k=ck, cross_v=cv) if have_cache else None
    )
    return x, new_caches


def encdec_loss(
    params, frames: jax.Array, tokens: jax.Array, labels: jax.Array,
    cfg: ArchConfig, *, backend=None,
) -> jax.Array:
    from .transformer import _chunked_ce

    enc_out = encode(params, frames, cfg, backend=backend)
    hidden, _ = decoder_forward(
        params, tokens, cfg, enc_out=enc_out, mode="train", backend=backend
    )
    # Chunked CE: whisper's vocab (51865) cannot shard on the 16-way model
    # axis, so the full [B,S,V] logits tensor must never materialize.
    return _chunked_ce(params, hidden, labels, cfg)
