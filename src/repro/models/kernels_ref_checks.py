"""Shared naive references used by tests (kept in-package for reuse)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["naive_attention"]


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jax.Array:
    """Materialized-scores GQA attention oracle. q: [B,S,Hq,D]; k/v [B,T,Hkv,D]."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p.astype(jnp.float32), v.astype(jnp.float32))
    return o.reshape(b, s, hq, d).astype(q.dtype)
