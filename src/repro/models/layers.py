"""Shared neural-net building blocks (pure functional, param pytrees).

Every matrix multiply routes through :func:`repro.kernels.ops.matmul`, i.e.
the O-POPE GEMM path — the paper's engine is the framework's matmul substrate
(DESIGN.md §5). Norms and softmaxes compute in fp32 regardless of the
parameter dtype, matching the widening-accumulation discipline of the PE.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import epilogue as _epilogue
from repro.kernels import ops

__all__ = [
    "ACT2FN",
    "activation_fn",
    "Initializer",
    "role_backend",
    "dense_init",
    "embedding_init",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "softcap",
    "rope_frequencies",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
]


# The activation-name table — a view of the epilogue registry's
# ACT2FN-style table, so a name accepted here is exactly a name the
# ``epilogue=`` lane fuses ("gelu", "silu", "swish", "relu"). The single
# naming authority for every ``activation=`` string in the model stack;
# unknown names raise instead of silently falling back (the pre-refactor
# if/else branches turned any typo into the other activation).
ACT2FN = _epilogue.ACTIVATIONS


def activation_fn(name: str):
    """The callable for an activation name, fp32-in/fp32-out. Raises on
    unknown names — a typo must never silently become a different
    nonlinearity."""
    try:
        return ACT2FN[name]
    except KeyError:
        raise ValueError(
            f"unknown activation {name!r}; known: {sorted(ACT2FN)}"
        ) from None


class _PreQuantized(NamedTuple):
    """Minimal pre-quantized activation carrier (``.q``/``.scale`` — the
    duck-typed protocol ``ops.matmul`` accepts) so this module never imports
    the quant package just to chain a requant epilogue into the next GEMM."""

    q: jax.Array
    scale: jax.Array


def role_backend(backend, role: str):
    """Resolve the matmul backend for one layer role.

    ``backend`` is either a backend name (``str``/``None`` — applies to every
    role, the pre-policy behaviour) or a precision policy exposing
    ``backend_for(role)`` (:class:`repro.quant.policy.PrecisionPolicy`,
    duck-typed so this module never imports the quant package). Every matmul
    site in the model stack routes its ``backend=`` argument through here
    with its role name, which is what lets one policy object drive
    mixed-precision wiring across the whole model.
    """
    resolver = getattr(backend, "backend_for", None)
    return resolver(role) if resolver is not None else backend


@dataclasses.dataclass(frozen=True)
class Initializer:
    """Truncated-normal fan-in initializer with a configurable param dtype."""

    dtype: jnp.dtype = jnp.bfloat16
    stddev: float = 0.02

    def __call__(self, key: jax.Array, shape: Tuple[int, ...], fan_in: Optional[int] = None):
        std = self.stddev if fan_in is None else (1.0 / jnp.sqrt(fan_in)).astype(jnp.float32)
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(self.dtype)


def dense_init(key, d_in: int, d_out: int, init: Initializer, *, bias: bool = False):
    p = {"w": init(key, (d_in, d_out), fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), init.dtype)
    return p


def embedding_init(key, vocab: int, d_model: int, init: Initializer):
    return {"table": init(key, (vocab, d_model))}


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    # named_scope: norms are reduction-coupled (the rsqrt(var) factor needs
    # the full row), not GEMM-writeback material — the decode-step HLO census
    # (core.hlo_census.elementwise_passes) exempts this scope.
    with jax.named_scope("norm"):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
        return y.astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    with jax.named_scope("norm"):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --- rotary position embeddings ---------------------------------------------


def rope_frequencies(rotary_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies for the rotated ``rotary_dim`` (must be even)."""
    return 1.0 / (
        theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim)
    )


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    rotary_frac: float = 1.0,
    theta: float = 10000.0,
) -> jax.Array:
    """Apply RoPE to ``x`` [..., S, H, D] with ``positions`` [..., S].

    ``rotary_frac < 1`` rotates only the leading fraction of the head dim —
    chatglm3's 2-D RoPE rotates half the dimensions and leaves the rest as
    plain channels (rotary_frac=0.5).
    """
    d = x.shape[-1]
    rot = int(d * rotary_frac)
    rot -= rot % 2
    if rot == 0:
        return x
    # named_scope: the rotation is position-dependent (per-token cos/sin),
    # not a GEMM-writeback pass — exempted by the decode-step HLO census.
    with jax.named_scope("rope"):
        inv = rope_frequencies(rot, theta)
        ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
        cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
        sin = jnp.sin(ang)[..., :, None, :]
        x_rot, x_pass = x[..., :rot], x[..., rot:]
        x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
        r1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
        r2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
        rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
        return jnp.concatenate([rotated, x_pass], axis=-1) if rot < d else rotated


# --- gated MLP ----------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, init: Initializer, *, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": init(k1, (d_model, d_ff), fan_in=d_model),
        "w_down": init(k3, (d_ff, d_model), fan_in=d_ff),
    }
    if gated:
        p["w_gate"] = init(k2, (d_model, d_ff), fan_in=d_model)
    return p


def mlp_apply(
    params, x: jax.Array, *, activation: str = "silu", backend=None,
    role: str = "mlp", residual: Optional[jax.Array] = None,
):
    """SwiGLU (default) / GeGLU / plain-GELU MLP on the O-POPE matmul path.

    Every post-GEMM elementwise pass rides the ``epilogue=`` lane: the
    activation (and the gating multiply) fuse into the gate GEMM's writeback,
    and ``residual`` fuses the caller's skip connection into the down
    projection — the hidden and output tensors are each materialized exactly
    once. With a precision policy that declares a ``requant_for(role)`` scale
    (and a q8 backend for the role), the hidden activation is additionally
    written straight onto the int8 grid (a ``requant_int8`` epilogue step)
    and fed to the down GEMM pre-quantized — no dequant/re-quant round trip.

    ``role`` keys the precision-policy lookup (the shared-expert MLP inside
    MoE blocks passes ``role="moe"``)."""
    activation_fn(activation)  # validate the name early (unknown -> raises)
    resolver = getattr(backend, "requant_for", None)
    rq = resolver(role) if resolver is not None else None
    be = role_backend(backend, role)
    if rq is not None and ops.family_of(ops.resolve_backend(be)) != "q8":
        rq = None  # requant output only feeds a quantized consumer

    if "w_gate" in params:
        up = ops.matmul(x, params["w_up"], backend=be)
        hidden_ep = [activation, ("mul", up)]
        gemm_in, w_act = x, params["w_gate"]
    else:
        hidden_ep = [activation]
        gemm_in, w_act = x, params["w_up"]

    if rq is not None:
        scale = jnp.float32(rq)
        h_q = ops.matmul(
            gemm_in, w_act, backend=be,
            epilogue=[*hidden_ep, ("requant_int8", scale)],
            out_dtype=jnp.int8,
        )
        h = _PreQuantized(h_q, scale)
    else:
        h = ops.matmul(
            gemm_in, w_act, backend=be, epilogue=hidden_ep,
            out_dtype=x.dtype,
        )
    down_ep = [("residual", residual)] if residual is not None else None
    out_dtype = x.dtype if rq is not None else None
    return ops.matmul(
        h, params["w_down"], backend=be, epilogue=down_ep,
        out_dtype=out_dtype,
    )
