"""Shared neural-net building blocks (pure functional, param pytrees).

Every matrix multiply routes through :func:`repro.kernels.ops.matmul`, i.e.
the O-POPE GEMM path — the paper's engine is the framework's matmul substrate
(DESIGN.md §5). Norms and softmaxes compute in fp32 regardless of the
parameter dtype, matching the widening-accumulation discipline of the PE.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = [
    "Initializer",
    "role_backend",
    "dense_init",
    "embedding_init",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "softcap",
    "rope_frequencies",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
]


def role_backend(backend, role: str):
    """Resolve the matmul backend for one layer role.

    ``backend`` is either a backend name (``str``/``None`` — applies to every
    role, the pre-policy behaviour) or a precision policy exposing
    ``backend_for(role)`` (:class:`repro.quant.policy.PrecisionPolicy`,
    duck-typed so this module never imports the quant package). Every matmul
    site in the model stack routes its ``backend=`` argument through here
    with its role name, which is what lets one policy object drive
    mixed-precision wiring across the whole model.
    """
    resolver = getattr(backend, "backend_for", None)
    return resolver(role) if resolver is not None else backend


@dataclasses.dataclass(frozen=True)
class Initializer:
    """Truncated-normal fan-in initializer with a configurable param dtype."""

    dtype: jnp.dtype = jnp.bfloat16
    stddev: float = 0.02

    def __call__(self, key: jax.Array, shape: Tuple[int, ...], fan_in: Optional[int] = None):
        std = self.stddev if fan_in is None else (1.0 / jnp.sqrt(fan_in)).astype(jnp.float32)
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(self.dtype)


def dense_init(key, d_in: int, d_out: int, init: Initializer, *, bias: bool = False):
    p = {"w": init(key, (d_in, d_out), fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), init.dtype)
    return p


def embedding_init(key, vocab: int, d_model: int, init: Initializer):
    return {"table": init(key, (vocab, d_model))}


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --- rotary position embeddings ---------------------------------------------


def rope_frequencies(rotary_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies for the rotated ``rotary_dim`` (must be even)."""
    return 1.0 / (
        theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim)
    )


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    rotary_frac: float = 1.0,
    theta: float = 10000.0,
) -> jax.Array:
    """Apply RoPE to ``x`` [..., S, H, D] with ``positions`` [..., S].

    ``rotary_frac < 1`` rotates only the leading fraction of the head dim —
    chatglm3's 2-D RoPE rotates half the dimensions and leaves the rest as
    plain channels (rotary_frac=0.5).
    """
    d = x.shape[-1]
    rot = int(d * rotary_frac)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_frequencies(rot, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    r2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([rotated, x_pass], axis=-1) if rot < d else rotated


# --- gated MLP ----------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, init: Initializer, *, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": init(k1, (d_model, d_ff), fan_in=d_model),
        "w_down": init(k3, (d_ff, d_model), fan_in=d_ff),
    }
    if gated:
        p["w_gate"] = init(k2, (d_model, d_ff), fan_in=d_model)
    return p


def mlp_apply(
    params, x: jax.Array, *, activation: str = "silu", backend=None,
    role: str = "mlp",
):
    """SwiGLU (default) / GeGLU / plain-GELU MLP on the O-POPE matmul path.

    ``role`` keys the precision-policy lookup (the shared-expert MLP inside
    MoE blocks passes ``role="moe"``)."""
    backend = role_backend(backend, role)
    up = ops.matmul(x, params["w_up"], backend=backend)
    if "w_gate" in params:
        gate = ops.matmul(x, params["w_gate"], backend=backend)
        act = jax.nn.silu if activation == "silu" else jax.nn.gelu
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
        h = act(up.astype(jnp.float32)).astype(x.dtype)
    return ops.matmul(h, params["w_down"], backend=backend)
