"""Mamba (S6) selective-state-space mixer — jamba's sequence layer.

The CUDA selective-scan kernel of the original paper is GPU-specific
(warp-level scan with SRAM-resident state). The TPU-idiomatic adaptation is a
**state-resident chunked scan** (the O-POPE principle again: the [B, d_inner,
d_state] state is the output-stationary accumulator; token panels stream):

* the sequence is split into chunks of ``chunk`` tokens;
* inside a chunk an associative scan runs over the discretized
  ``(exp(Δ·A), Δ·B·x)`` pairs — materializing only [B, chunk, d_inner,
  d_state] instead of the full sequence;
* a ``lax.scan`` carries the state across chunks.

Decode is the exact single-step recurrence with a (conv-window, ssm-state)
cache. A Pallas realization of the chunk kernel lives in
``repro.kernels.opope_scan`` (validated in interpret mode); the jnp form here
is what the dry-run lowers.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import Initializer, activation_fn

# The conv output and the z-branch gate are silu-activated in the reference
# implementation; the name resolves through the shared ACT2FN table (the same
# registry the epilogue lane fuses from) rather than a hand-picked jax.nn fn.
_silu = activation_fn("silu")

__all__ = ["MambaState", "mamba_init", "mamba_apply", "mamba_decode_step"]


class MambaState(NamedTuple):
    """Decode cache: conv window [B, d_conv-1, d_inner], ssm [B, d_inner, N]."""

    conv: jax.Array
    ssm: jax.Array

    @staticmethod
    def zeros(batch: int, d_inner: int, d_state: int, d_conv: int, dtype):
        return MambaState(
            conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
            ssm=jnp.zeros((batch, d_inner, d_state), jnp.float32),
        )


def mamba_init(
    key,
    d_model: int,
    *,
    expand: int = 2,
    d_state: int = 16,
    d_conv: int = 4,
    dt_rank: Optional[int] = None,
    init: Initializer,
):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init(ks[0], (d_model, 2 * d_inner), fan_in=d_model),
        "conv_w": init(ks[1], (d_conv, d_inner), fan_in=d_conv),
        "conv_b": jnp.zeros((d_inner,), init.dtype),
        "x_proj": init(ks[2], (d_inner, dt_rank + 2 * d_state), fan_in=d_inner),
        "dt_proj": init(ks[3], (dt_rank, d_inner), fan_in=dt_rank),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        # A stored as log so exp(-softplus-ish) stays stable; D skip gain.
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))
        ),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init(ks[4], (d_inner, d_model), fan_in=d_inner),
    }


def _ssm_inputs(params, xc: jax.Array):
    """Project conv output to (dA, dBx, C) discretized SSM inputs (fp32)."""
    d_state = params["A_log"].shape[1]
    dt_rank = params["x_proj"].shape[1] - 2 * d_state
    proj = ops.matmul(xc, params["x_proj"]).astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        ops.matmul(dt.astype(xc.dtype), params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # [..., d_inner]
    a = -jnp.exp(params["A_log"])  # [d_inner, N]
    da = jnp.exp(dt[..., None] * a)  # [..., d_inner, N]
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bmat[..., None, :]
    return da, dbx, cmat


def _conv1d_causal(params, x: jax.Array, history: Optional[jax.Array] = None):
    """Depthwise causal conv over the sequence. x: [B,S,Di] (+ optional
    [B,d_conv-1,Di] left history for decode continuity)."""
    w = params["conv_w"].astype(jnp.float32)  # [K, Di]
    kw = w.shape[0]
    pad = (
        history.astype(jnp.float32)
        if history is not None
        else jnp.zeros((x.shape[0], kw - 1, x.shape[2]), jnp.float32)
    )
    xp = jnp.concatenate([pad, x.astype(jnp.float32)], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i] for i in range(kw)
    ) + params["conv_b"].astype(jnp.float32)
    return _silu(out).astype(x.dtype)


def mamba_apply(
    params,
    x: jax.Array,
    *,
    chunk: int = 64,
    backend: Optional[str] = None,
    return_state: bool = False,
):
    """Full-sequence selective scan. x: [B, S, D] -> [B, S, D].

    With ``return_state=True`` also returns the :class:`MambaState` after the
    last token (used by prefill to seed decoding)."""
    from repro.distributed.hints import constrain

    b, s, _ = x.shape
    xi = ops.matmul(x, params["in_proj"], backend=backend)
    xm, z = jnp.split(xi, 2, axis=-1)  # [B,S,Di] each
    xc = _conv1d_causal(params, xm)

    # Only the *projections* are computed full-sequence ([B,S,Di] / [B,S,N]);
    # the discretized [*, Di, N] expansion is chunk-local inside the scan —
    # the state-resident dataflow (a full-sequence expansion would be
    # ~0.5 TB at jamba's train_4k shape).
    d_state = params["A_log"].shape[1]
    dt_rank = params["x_proj"].shape[1] - 2 * d_state
    proj = ops.matmul(xc, params["x_proj"], backend=backend).astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        ops.matmul(dt.astype(xc.dtype), params["dt_proj"], backend=backend)
        .astype(jnp.float32)
        + params["dt_bias"]
    )  # [B,S,Di]
    a = -jnp.exp(params["A_log"])  # [Di, N]
    d_inner = dt.shape[-1]

    # Pin the TP sharding of the inner dim: GSPMD loses it through the
    # associative scan and replicates [*, Di, N] tensors 16x otherwise
    # (measured: the dominant HBM-traffic term of jamba's train cell, §Perf).
    dp = ("pod", "data")
    dt = constrain(dt, dp, None, "model")
    xc_f = constrain(xc.astype(jnp.float32), dp, None, "model")

    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck

    def chunked(t):
        return t.reshape(b, nc, ck, t.shape[-1]).transpose(1, 0, 2, 3)

    dt_c, x_c, b_c, c_c = map(chunked, (dt, xc_f, bmat, cmat))

    def chunk_step(h, inputs):
        dt_k, x_k, b_k, c_k = inputs  # [B, ck, Di] / [B, ck, N]
        da_k = jnp.exp(dt_k[..., None] * a)  # [B, ck, Di, N]
        dbx_k = (dt_k * x_k)[..., None] * b_k[..., None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (da_k, dbx_k), axis=1)
        hs = a_cum * h[:, None] + b_cum  # [B, ck, Di, N]
        y_k = jnp.einsum("bsdn,bsn->bsd", hs, c_k)  # project within the chunk
        return hs[:, -1], y_k

    h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    # checkpoint: the backward recomputes the chunk's [B,ck,Di,N] expansion
    # from the carried state instead of stacking it as a residual — the
    # state-resident discipline applied to AD (§Perf, jamba hillclimb).
    h_final, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), h0, (dt_c, x_c, b_c, c_c)
    )
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d_inner)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y * _silu(z.astype(jnp.float32))).astype(x.dtype)
    out = ops.matmul(y, params["out_proj"], backend=backend)
    if not return_state:
        return out
    kw = params["conv_w"].shape[0]
    if s >= kw - 1:
        conv_hist = xm[:, s - (kw - 1) :]
    else:  # pathological tiny prefill: left-pad with zeros
        conv_hist = jnp.concatenate(
            [jnp.zeros((b, kw - 1 - s, xm.shape[2]), xm.dtype), xm], axis=1
        )
    return out, MambaState(conv=conv_hist, ssm=h_final)


def mamba_decode_step(
    params,
    x: jax.Array,
    state: MambaState,
    *,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, MambaState]:
    """One-token recurrence. x: [B, 1, D] -> ([B, 1, D], new state)."""
    b = x.shape[0]
    xi = ops.matmul(x, params["in_proj"], backend=backend)
    xm, z = jnp.split(xi, 2, axis=-1)
    xc = _conv1d_causal(params, xm, history=state.conv)
    new_conv = jnp.concatenate([state.conv[:, 1:], xm], axis=1)
    da, dbx, cmat = _ssm_inputs(params, xc[:, 0])  # [B,Di,N],[B,N]
    h = da * state.ssm + dbx
    y = jnp.einsum("bdn,bn->bd", h, cmat) + params["D"] * xc[:, 0].astype(jnp.float32)
    y = (y * _silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = ops.matmul(y[:, None], params["out_proj"], backend=backend)
    return out, MambaState(conv=new_conv, ssm=h)
