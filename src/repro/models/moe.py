"""Mixture-of-Experts FFN with two dispatch strategies.

* ``"onehot"`` — the GShard/Switch formulation: dispatch/combine one-hot
  einsums over [tokens, experts, capacity]. Simple, fully dense, and the
  **paper-faithful baseline** here (every dispatch op is a GEMM on the
  O-POPE path), but its dispatch einsums burn HLO FLOPs proportional to
  ``T*E*C*D`` — visible as a poor useful-compute ratio in the roofline.
* ``"sort"`` — beyond-paper optimized path: sort token assignments by expert,
  scatter into per-expert capacity buffers, run the expert GEMMs, gather back.
  Dispatch costs data movement only; HLO FLOPs drop to the expert GEMMs
  (hillclimb #2 in EXPERIMENTS.md §Perf).

Both honor capacity: assignments past ``capacity_factor * T * top_k / E`` per
expert are dropped (standard token-dropping semantics). Expert weights are
stacked [E, ...] so EP sharding is a single spec on axis 0 (or TP inside the
expert when E doesn't divide the model axis — grok's E=8, DESIGN.md §4).

The per-expert SwiGLU itself (:func:`_expert_ffn`) runs as **grouped O-POPE
GEMMs** through the ``kernels.ops`` registry (``grouped_matmul``, expert axis
= group axis): the hottest MoE compute honors ``backend=`` and
``PrecisionPolicy`` role ``moe`` like every other matmul site, and its fp32
accumulation/final-cast discipline lives in the backend, not in caller-side
upcasts.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import Initializer

__all__ = ["moe_init", "moe_apply", "router_load_balancing_loss"]


def moe_init(
    key,
    d_model: int,
    d_ff_expert: int,
    n_experts: int,
    init: Initializer,
    *,
    n_shared: int = 0,
    d_ff_shared: Optional[int] = None,
):
    ks = jax.random.split(key, 5)
    p = {
        "router": init(ks[0], (d_model, n_experts), fan_in=d_model),
        "w_gate": init(ks[1], (n_experts, d_model, d_ff_expert), fan_in=d_model),
        "w_up": init(ks[2], (n_experts, d_model, d_ff_expert), fan_in=d_model),
        "w_down": init(ks[3], (n_experts, d_ff_expert, d_model), fan_in=d_ff_expert),
    }
    if n_shared:
        dsh = d_ff_shared or n_shared * d_ff_expert
        from .layers import mlp_init

        p["shared"] = mlp_init(ks[4], d_model, dsh, init)
    return p


def _expert_ffn(
    p, xs: jax.Array, *, backend=None, out_dtype=None
) -> jax.Array:
    """xs: [E, C, D] -> [E, C, D]; batched per-expert SwiGLU on stacked weights.

    All three per-expert GEMMs run as grouped O-POPE GEMMs through the
    backend registry (one launch per projection, the expert axis as the
    group axis), so the hottest MoE compute honors ``backend=`` /
    ``PrecisionPolicy`` role ``moe`` exactly like every dense matmul site.
    ``backend`` arrives role-resolved from :func:`moe_apply`. ``out_dtype``
    is the dtype of the final down-projection writeback — dispatch paths
    that combine in fp32 request fp32 straight from the accumulator (single
    final cast in the backend, not an upcast after the fact).
    """
    up = ops.grouped_matmul(xs, p["w_up"], backend=backend)
    # SiLU and the gating multiply ride the gate GEMM's writeback epilogue
    # (fp32 accumulator in, one final cast out) — the hidden tensor is
    # materialized exactly once, with no standalone activation pass.
    h = ops.grouped_matmul(
        xs, p["w_gate"], backend=backend,
        epilogue=["silu", ("mul", up)], out_dtype=xs.dtype,
    )
    return ops.grouped_matmul(
        h, p["w_down"], backend=backend, out_dtype=out_dtype
    )


def router_load_balancing_loss(gates: jax.Array, expert_mask: jax.Array) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e (fp32)."""
    e = gates.shape[-1]
    f = expert_mask.astype(jnp.float32).mean(axis=tuple(range(expert_mask.ndim - 1)))
    p = gates.astype(jnp.float32).mean(axis=tuple(range(gates.ndim - 1)))
    return e * jnp.sum(f * p)


def moe_apply(
    params,
    x: jax.Array,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    dispatch: str = "sort",
    group_size: int = 512,
    dropless: bool = False,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss).

    Tokens are processed in groups of ``group_size`` with per-group capacity
    (GShard semantics): dispatch structures stay O(T * E * C_g) instead of
    O(T * E * C_global), and — critically for SPMD — the group axis carries
    the batch sharding, so routing never sorts or one-hots across devices.

    ``dropless=True`` sets capacity to the group size — the provable
    no-overflow bound (each token contributes at most one assignment per
    expert) — so routing becomes a pure per-token function and autoregressive
    decode matches teacher forcing exactly. Capacity-based dropping remains
    the default: it is what the production roofline models.
    """
    from .layers import role_backend

    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    # Routing decisions are accuracy-critical: the router matmul carries its
    # own policy role so a quantized-MoE policy can (and by default does)
    # keep it full-precision.
    logits = ops.matmul(
        xf, params["router"], backend=role_backend(backend, "router")
    ).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)  # [T, E]
    top_vals, top_idx = jax.lax.top_k(gates, top_k)  # [T, K]
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    g = min(group_size, t)
    while t % g:
        g -= 1
    n_groups = t // g
    if dropless:
        capacity = g
    else:
        capacity = max(int(math.ceil(capacity_factor * g * top_k / n_experts)), 1)

    xg = xf.reshape(n_groups, g, d)
    vg = top_vals.reshape(n_groups, g, top_k)
    ig = top_idx.reshape(n_groups, g, top_k)

    # The routed expert FFNs carry the "moe" policy role (the same role the
    # shared-expert MLP uses below): one policy line quantizes all of them.
    expert_be = role_backend(backend, "moe")
    if dispatch == "onehot":
        y = _dispatch_onehot(params, xg, vg, ig, n_experts, capacity, expert_be)
    elif dispatch == "sort":
        y = _dispatch_sort(params, xg, vg, ig, n_experts, capacity, expert_be)
    else:
        raise ValueError(f"unknown MoE dispatch {dispatch!r}")
    y = y.reshape(t, d)

    if "shared" in params:
        from .layers import mlp_apply

        # The routed-expert sum rides the shared-expert down projection's
        # residual epilogue — one writeback produces routed + shared.
        y = mlp_apply(params["shared"], xf, backend=backend, role="moe", residual=y)

    mask = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32).sum(axis=1)
    aux = router_load_balancing_loss(gates, mask)
    return y.reshape(b, s, d), aux


def _positions_in_expert(ig: jax.Array, n_experts: int) -> jax.Array:
    """Per-group rank of each assignment within its expert. ig: [G, g, K]."""
    gshape = ig.shape
    ohf = jax.nn.one_hot(
        ig.reshape(gshape[0], -1), n_experts, dtype=jnp.int32
    )  # [G, g*K, E]
    pos = jnp.cumsum(ohf, axis=1) - 1
    return (pos * ohf).sum(-1).reshape(gshape)  # [G, g, K]


def _dispatch_onehot(params, xg, vg, ig, n_experts, capacity, backend=None):
    """GShard one-hot dispatch/combine einsums (dense baseline).

    Every routing op is a GEMM on the O-POPE path — simple and fully static,
    but the dispatch einsums cost 2*T*E*C*D FLOPs, which dwarfs the expert
    GEMMs for fine-grained MoE (deepseek) — visible in the roofline's
    useful-compute ratio and removed by the "sort" dispatch (§Perf).
    """
    pos = _positions_in_expert(ig, n_experts)  # [G, g, K]
    keep = pos < capacity
    oh_e = jax.nn.one_hot(ig, n_experts, dtype=xg.dtype)  # [G,g,K,E]
    oh_c = jax.nn.one_hot(pos, capacity, dtype=xg.dtype)  # [G,g,K,C]
    disp = jnp.einsum(
        "gske,gskc->gsec", oh_e * keep[..., None].astype(xg.dtype), oh_c
    )  # [G,g,E,C]
    comb = jnp.einsum(
        "gske,gskc->gsec",
        (oh_e.astype(jnp.float32) * (vg * keep)[..., None]),
        oh_c.astype(jnp.float32),
    )
    expert_in = jnp.einsum("gsec,gsd->gecd", disp, xg)
    e, c, d = n_experts, capacity, xg.shape[-1]
    # The fp32 the combine einsum consumes comes straight from the expert
    # GEMM's accumulator (out_dtype=fp32 at the writeback), not from an
    # upcast of an already-rounded narrow output.
    expert_out = _expert_ffn(
        params, expert_in.transpose(1, 0, 2, 3).reshape(e, -1, d),
        backend=backend, out_dtype=jnp.float32,
    ).reshape(e, -1, c, d).transpose(1, 0, 2, 3)  # [G,E,C,D] fp32
    return jnp.einsum("gsec,gecd->gsd", comb, expert_out).astype(xg.dtype)


def _dispatch_sort(params, xg, vg, ig, n_experts, capacity, backend=None):
    """Per-group sort-scatter dispatch (beyond-paper optimized path).

    Routing is pure data movement (argsort + scatter + gather within each
    group); HLO FLOPs reduce to the expert GEMMs. The group axis keeps all
    sorting device-local under the batch sharding.
    """
    n_groups, g, d = xg.shape
    k = ig.shape[-1]
    e_flat = ig.reshape(n_groups, g * k)
    tok_flat = jnp.tile(jnp.repeat(jnp.arange(g), k)[None], (n_groups, 1))
    w_flat = vg.reshape(n_groups, g * k)

    order = jnp.argsort(e_flat, axis=1)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    tok_sorted = jnp.take_along_axis(tok_flat, order, axis=1)
    w_sorted = jnp.take_along_axis(w_flat, order, axis=1)
    seg_start = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(n_experts)))(
        e_sorted
    )  # [G, E]
    rank = jnp.arange(g * k)[None] - jnp.take_along_axis(seg_start, e_sorted, axis=1)
    keep = rank < capacity
    dest = jnp.where(keep, e_sorted * capacity + rank, n_experts * capacity)

    def scatter_group(x_g, tok_g, dest_g):
        buf = jnp.zeros((n_experts * capacity + 1, d), x_g.dtype)
        return buf.at[dest_g].set(x_g[tok_g])[:-1]

    expert_in = jax.vmap(scatter_group)(xg, tok_sorted, dest)  # [G, E*C, D]
    expert_in = expert_in.reshape(n_groups, n_experts, capacity, d)
    # fp32 combine reads the expert GEMM's accumulator directly
    # (out_dtype=fp32 at the writeback), as in the onehot path.
    expert_out = _expert_ffn(
        params, expert_in.transpose(1, 0, 2, 3).reshape(n_experts, -1, d),
        backend=backend, out_dtype=jnp.float32,
    ).reshape(n_experts, n_groups, capacity, d).transpose(1, 0, 2, 3)

    def gather_group(out_g, dest_g, tok_g, w_g):
        flat = jnp.concatenate(
            [out_g.reshape(n_experts * capacity, d), jnp.zeros((1, d), out_g.dtype)]
        )
        y_sorted = flat[dest_g] * w_g[:, None]
        return jnp.zeros((g, d), jnp.float32).at[tok_g].add(y_sorted)

    y = jax.vmap(gather_group)(expert_out, dest, tok_sorted, w_sorted)
    return y.astype(xg.dtype)
