"""Decoder-only LM assembly: heterogeneous block programs under one layer-scan.

An :class:`~repro.configs.base.ArchConfig` declares a repeating *period* of
:class:`BlockDef` layers (e.g. gemma2: ``(local, global)``; jamba:
``(attn+moe, mamba+mlp, mamba+moe, ...)``). Parameters for each period
position are stacked over ``n_periods`` and the forward pass is a single
``lax.scan`` over periods — keeping the HLO (and compile time) independent of
depth, which is what makes the 40-cell x 2-mesh dry-run tractable.

Modes:
* ``train``   — full sequence, no caches, returns final hidden states.
* ``prefill`` — full sequence, fills and returns per-layer caches.
* ``decode``  — one token against the caches.
* ``chunk``   — chunked prefill: a fixed-width window of prompt tokens
  appended at per-row ``positions`` (attention-only patterns).

Caches are per-period-position stacked pytrees (KVCache / MambaState /
MLSTMState / SLSTMState), scanned alongside the parameters.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockDef
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import xlstm as xlstm_mod
from .attention import KVCache
from .layers import (
    Initializer,
    embedding_init,
    layernorm,
    layernorm_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)
from .moe import moe_apply, moe_init

__all__ = [
    "init_lm_params",
    "lm_forward",
    "lm_logits",
    "lm_loss",
    "init_caches",
]


def _norm_init(cfg: ArchConfig, d: int):
    return rmsnorm_init(d) if cfg.norm == "rmsnorm" else layernorm_init(d)


def _norm(cfg: ArchConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(cfg: ArchConfig, bd: BlockDef, key) -> Dict[str, Any]:
    init = Initializer(dtype=jnp.dtype(cfg.param_dtype))
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm_mixer": _norm_init(cfg, cfg.d_model)}
    if bd.mixer in ("attn", "attn_local"):
        p["attn"] = attn_mod.attention_init(
            keys[0],
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv,
            cfg.head_dim_,
            init,
            qkv_bias=cfg.qkv_bias,
        )
    elif bd.mixer == "mamba":
        assert cfg.mamba is not None
        p["mamba"] = mamba_mod.mamba_init(
            keys[0],
            cfg.d_model,
            expand=cfg.mamba.expand,
            d_state=cfg.mamba.d_state,
            d_conv=cfg.mamba.d_conv,
            init=init,
        )
    elif bd.mixer == "mlstm":
        p["mlstm"] = xlstm_mod.mlstm_init(keys[0], cfg.d_model, cfg.n_heads, init=init)
    elif bd.mixer == "slstm":
        p["slstm"] = xlstm_mod.slstm_init(keys[0], cfg.d_model, cfg.n_heads, init=init)
    elif bd.mixer != "none":
        raise ValueError(f"unknown mixer {bd.mixer!r}")

    if bd.ffn == "mlp":
        p["norm_ffn"] = _norm_init(cfg, cfg.d_model)
        p["mlp"] = mlp_init(keys[1], cfg.d_model, cfg.d_ff, init)
    elif bd.ffn == "moe":
        assert cfg.moe is not None
        p["norm_ffn"] = _norm_init(cfg, cfg.d_model)
        p["moe"] = moe_init(
            keys[1],
            cfg.d_model,
            cfg.moe.d_ff_expert,
            cfg.moe.n_experts,
            init,
            n_shared=cfg.moe.n_shared,
            d_ff_shared=cfg.moe.d_ff_shared,
        )
    elif bd.ffn != "none":
        raise ValueError(f"unknown ffn {bd.ffn!r}")
    return p


def init_lm_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    init = Initializer(dtype=jnp.dtype(cfg.param_dtype))
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": embedding_init(k_embed, cfg.vocab, cfg.d_model, init),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(k_head, (cfg.vocab, cfg.d_model))
    # Stack each period position over n_periods via vmap of the block init.
    blocks = []
    for pos, bd in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_blocks, pos), cfg.n_periods)
        blocks.append(jax.vmap(lambda k, bd=bd: _block_init(cfg, bd, k))(keys))
    params["blocks"] = tuple(blocks)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _block_cache(cfg: ArchConfig, bd: BlockDef, batch: int, max_len: int, dtype):
    if bd.mixer in ("attn", "attn_local"):
        return KVCache.zeros(batch, max_len, cfg.n_kv, cfg.head_dim_, dtype)
    if bd.mixer == "mamba":
        return mamba_mod.MambaState.zeros(
            batch, cfg.mamba.expand * cfg.d_model, cfg.mamba.d_state,
            cfg.mamba.d_conv, dtype,
        )
    if bd.mixer == "mlstm":
        return xlstm_mod.MLSTMState.zeros(batch, cfg.n_heads, cfg.head_dim_)
    if bd.mixer == "slstm":
        return xlstm_mod.SLSTMState.zeros(batch, cfg.n_heads, cfg.head_dim_)
    return None


def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked per-period caches, one entry per pattern position (or None)."""
    caches = []
    for bd in cfg.pattern:
        c = _block_cache(cfg, bd, batch, max_len, dtype)
        if c is None:
            caches.append(None)
        else:
            caches.append(
                jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_periods,) + x.shape
                    ),
                    c,
                )
            )
    return tuple(caches)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(
    cfg: ArchConfig,
    bd: BlockDef,
    p,
    x: jax.Array,
    *,
    positions,
    cache,
    backend=None,
    chunk=False,
):
    """One layer. Returns (x, new_cache, aux_loss)."""
    from .layers import role_backend

    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    h = _norm(cfg, p["norm_mixer"], x)
    mixer_out = None
    stream = x  # the residual stream after the mixer's skip connection
    # attention / mlp / moe resolve their own precision-policy roles inside;
    # the recurrent mixers take a plain backend name resolved here.
    mixer_be = role_backend(backend, "mixer")
    if chunk and bd.mixer not in ("attn", "attn_local", "none"):
        # Recurrent state can't resume mid-prompt from a cache scatter; the
        # engine gates chunked prefill to attention-only patterns.
        raise NotImplementedError("chunked prefill requires attention mixers")
    if bd.mixer in ("attn", "attn_local"):
        # The mixer's residual add rides the output projection's epilogue:
        # attention returns x + attn(h) in one writeback.
        stream, new_cache = attn_mod.attention_apply(
            p["attn"],
            h,
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=cfg.head_dim_,
            positions=positions,
            rotary_frac=cfg.rope_frac,
            rope_theta=cfg.rope_theta,
            window=cfg.window if bd.mixer == "attn_local" else None,
            attn_softcap=cfg.attn_softcap,
            cache=cache,
            q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk,
            seq_shard=cfg.attn_seq_shard,
            backend=backend,
            residual=x,
            chunk=chunk,
        )
        mixer_out = stream  # non-None marks "this block has a mixer"
    elif bd.mixer == "mamba":
        if cache is not None and x.shape[1] == 1:
            mixer_out, new_cache = mamba_mod.mamba_decode_step(
                p["mamba"], h, cache, backend=mixer_be
            )
        else:
            mixer_out, state = mamba_mod.mamba_apply(
                p["mamba"], h, chunk=cfg.scan_chunk, backend=mixer_be,
                return_state=True,
            )
            if cache is not None:
                new_cache = state  # prefill installs the post-sequence state
    elif bd.mixer == "mlstm":
        if cache is not None and x.shape[1] == 1:
            mixer_out, new_cache = xlstm_mod.mlstm_decode_step(
                p["mlstm"], h, cache, n_heads=cfg.n_heads, backend=mixer_be
            )
        else:
            mixer_out, state = xlstm_mod.mlstm_apply(
                p["mlstm"], h, n_heads=cfg.n_heads, chunk=cfg.scan_chunk,
                backend=mixer_be, return_state=True,
            )
            if cache is not None:
                new_cache = state
    elif bd.mixer == "slstm":
        if cache is not None and x.shape[1] == 1:
            mixer_out, new_cache = xlstm_mod.slstm_decode_step(
                p["slstm"], h, cache, n_heads=cfg.n_heads, backend=mixer_be
            )
        else:
            mixer_out, state = xlstm_mod.slstm_apply(
                p["slstm"], h, n_heads=cfg.n_heads, backend=mixer_be,
                return_state=True,
            )
            if cache is not None:
                new_cache = state

    if mixer_out is not None and stream is x:
        # Recurrent mixers (mamba/xlstm) keep a plain residual add: their
        # output projections live inside the mixer modules, behind gating.
        stream = x + mixer_out

    if cfg.parallel_block and bd.ffn != "none" and mixer_out is not None:
        # StableLM-2 style: attn and MLP read the same normed input and share
        # one residual add — x + mixer_out (already on `stream`) fuses into
        # the MLP down projection's writeback.
        return (
            mlp_apply(p["mlp"], h, backend=backend, residual=stream),
            new_cache,
            aux,
        )

    if bd.ffn == "mlp":
        # Pre-norm FFN with its skip connection fused into the down GEMM.
        stream = mlp_apply(
            p["mlp"], _norm(cfg, p["norm_ffn"], stream), backend=backend,
            residual=stream,
        )
    elif bd.ffn == "moe":
        y, aux = moe_apply(
            p["moe"],
            _norm(cfg, p["norm_ffn"], stream),
            n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            dispatch=cfg.moe.dispatch,
            group_size=cfg.moe.group_size,
            dropless=cfg.moe.dropless,
            backend=backend,
        )
        # The MoE output is a scatter-weighted expert combine (or, with a
        # shared expert, already carries the routed sum via a residual
        # epilogue inside moe_apply) — not a bare GEMM writeback, so its
        # block-residual add stays a plain op.
        stream = stream + y
    return stream, new_cache, aux


def lm_forward(
    params,
    tokens: jax.Array,
    cfg: ArchConfig,
    *,
    mode: str = "train",
    caches=None,
    positions: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,
    backend: Optional[str] = None,
):
    """Run the backbone. tokens: [B, S] -> hidden [B, S(+img), D].

    Returns ``(hidden, new_caches, aux_loss)``. ``extra_embeds`` (VLM) are
    prepended to the token embeddings before the block stack.
    """
    x = params["embed"]["table"][tokens]  # vocab-sharded gather
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    n_pos = len(cfg.pattern)
    have_caches = caches is not None
    chunk = mode == "chunk"  # chunked prefill: scatter-append at `positions`

    def period_body(carry, xs):
        x, aux = carry
        block_params = xs[:n_pos]
        block_caches = xs[n_pos:] if have_caches else (None,) * n_pos
        new_caches = []
        for pos, bd in enumerate(cfg.pattern):
            cache_in = block_caches[pos]
            placeholder = None
            if have_caches and isinstance(cache_in, jax.Array):
                placeholder, cache_in = cache_in, None  # zero-size stand-in
            x, nc, a = _block_apply(
                cfg,
                bd,
                block_params[pos],
                x,
                positions=positions,
                cache=cache_in,
                backend=backend,
                chunk=chunk,
            )
            aux = aux + a
            new_caches.append(nc if nc is not None else placeholder)
        return (x, aux), (tuple(new_caches) if have_caches else None)

    body = period_body
    if cfg.remat and mode == "train" and cfg.remat_policy != "none":
        if cfg.remat_policy == "dots":
            # Save GEMM outputs; recompute only the cheap elementwise chains
            # in the backward pass — trades HBM (we have headroom in every
            # train cell) for a ~25% FLOP cut vs full remat (§Perf).
            body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        else:
            body = jax.checkpoint(period_body)

    xs = tuple(params["blocks"])
    if have_caches:
        xs = xs + tuple(
            c if c is not None else _none_stack(cfg.n_periods) for c in caches
        )
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = _norm(cfg, params["final_norm"], x)
    return x, new_caches, aux


def _none_stack(n: int):
    return jnp.zeros((n, 0), jnp.float32)  # zero-size array: free to scan


def lm_logits(params, hidden: jax.Array, cfg: ArchConfig) -> jax.Array:
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum(
        "bsd,vd->bsv", hidden, table, preferred_element_type=jnp.float32
    )
    return softcap(logits, cfg.final_softcap)


def _chunked_ce(
    params,
    hidden: jax.Array,
    labels: jax.Array,
    cfg: ArchConfig,
    loss_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Chunked softmax cross-entropy (the [B,S,V] logits tensor never exists:
    at 152k vocab x 1M tokens it would be ~0.6 PB)."""
    b, s, d = hidden.shape
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    ck = min(cfg.loss_chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck
    h_c = hidden.reshape(b, nc, ck, d).transpose(1, 0, 2, 3)
    y_c = labels.reshape(b, nc, ck).transpose(1, 0, 2)
    m_c = (
        loss_mask.reshape(b, nc, ck).transpose(1, 0, 2).astype(jnp.float32)
        if loss_mask is not None
        else jnp.ones((nc, b, ck), jnp.float32)
    )

    def chunk_ce(carry, inp):
        h, y, m = inp
        logits = jnp.einsum(
            "bsd,vd->bsv", h, table, preferred_element_type=jnp.float32
        )
        logits = softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * m
        return (carry[0] + ce.sum(), carry[1] + m.sum()), None

    (total, count), _ = jax.lax.scan(
        chunk_ce, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h_c, y_c, m_c),
    )
    return total / jnp.maximum(count, 1.0)


def lm_loss(
    params,
    tokens: jax.Array,
    labels: jax.Array,
    cfg: ArchConfig,
    *,
    loss_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    hidden, _, aux = lm_forward(params, tokens, cfg, mode="train", backend=backend)
    if cfg.n_img_tokens:
        hidden = hidden[:, cfg.n_img_tokens :]
    return _chunked_ce(params, hidden, labels, cfg, loss_mask) + 0.01 * aux


# (parameter accounting lives in repro.models.api — family-dispatched)
