"""LLaVA-NeXT-style VLM wrapper (mistral-7b backbone).

The anyres vision tower is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings [B, n_img_tokens, D] (base tile + 4
anyres tiles x 576 patches for the full config). The multimodal projector
(2-layer MLP) *is* real and trainable; its output is prepended to the token
embeddings and the standard decoder-only backbone runs over the combined
sequence. Loss is masked to text positions.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from .layers import Initializer
from .transformer import init_lm_params, lm_forward, lm_loss

__all__ = ["init_vlm_params", "vlm_forward", "vlm_loss", "project_image"]


def init_vlm_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    init = Initializer(dtype=jnp.dtype(cfg.param_dtype))
    k_lm, k1, k2 = jax.random.split(key, 3)
    params = init_lm_params(cfg, k_lm)
    params["mm_projector"] = {
        "w1": init(k1, (cfg.d_model, cfg.d_model), fan_in=cfg.d_model),
        "w2": init(k2, (cfg.d_model, cfg.d_model), fan_in=cfg.d_model),
    }
    return params


def project_image(params, patch_embeds: jax.Array, *, backend=None) -> jax.Array:
    """2-layer GELU projector from vision space into the LM embedding space.

    The GELU rides the first GEMM's writeback epilogue — no standalone
    activation pass over the [B, n_img_tokens, D] intermediate."""
    h = ops.matmul(
        patch_embeds, params["mm_projector"]["w1"], backend=backend,
        epilogue=["gelu"],
    )
    return ops.matmul(h, params["mm_projector"]["w2"], backend=backend)


def vlm_forward(
    params,
    tokens: jax.Array,
    patch_embeds: jax.Array,
    cfg: ArchConfig,
    *,
    mode: str = "train",
    caches=None,
    backend: Optional[str] = None,
):
    img = project_image(params, patch_embeds, backend=backend)
    return lm_forward(
        params, tokens, cfg, mode=mode, caches=caches,
        extra_embeds=img, backend=backend,
    )


def vlm_loss(
    params,
    tokens: jax.Array,
    patch_embeds: jax.Array,
    labels: jax.Array,
    cfg: ArchConfig,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """CE over text positions only (image positions are context)."""
    from .transformer import _chunked_ce

    img = project_image(params, patch_embeds, backend=backend)
    hidden, _, aux = lm_forward(
        params, tokens, cfg, mode="train", extra_embeds=img, backend=backend
    )
    hidden = hidden[:, cfg.n_img_tokens :]  # CE over text positions only
    return _chunked_ce(params, hidden, labels, cfg) + 0.01 * aux
