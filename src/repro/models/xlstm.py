"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM mixers.

* **mLSTM** — matrix-memory cell with exponential gating. Train/prefill uses
  the chunkwise-parallel form (state [B, H, D, D] carried across chunks by a
  ``lax.scan``; intra-chunk contributions via a masked quadratic over the
  chunk — another instance of the state-resident streaming dataflow).
  Stabilized in log space with the running max-gate trick from the paper.
* **sLSTM** — scalar-memory cell with a per-head recurrent mix matrix; it is
  inherently sequential, so train/prefill runs a ``lax.scan`` over tokens
  (the paper itself notes sLSTM is not parallelizable).

Both support single-token decode with explicit state tuples, which is what
the 500k-token long-context cell lowers (state size is sequence-independent —
the reason this arch *runs* long_500k while full-attention archs skip it).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import Initializer

__all__ = [
    "MLSTMState",
    "SLSTMState",
    "mlstm_init",
    "mlstm_apply",
    "mlstm_decode_step",
    "slstm_init",
    "slstm_apply",
    "slstm_decode_step",
]


class MLSTMState(NamedTuple):
    c: jax.Array  # [B, H, D, D] matrix memory
    n: jax.Array  # [B, H, D] normalizer
    m: jax.Array  # [B, H] running log-gate max (stabilizer)

    @staticmethod
    def zeros(batch: int, n_heads: int, head_dim: int):
        return MLSTMState(
            c=jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            n=jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            m=jnp.full((batch, n_heads), -1e30, jnp.float32),
        )


class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, D] cell
    n: jax.Array  # [B, H, D] normalizer
    h: jax.Array  # [B, H, D] hidden (recurrent input)
    m: jax.Array  # [B, H, D] stabilizer

    @staticmethod
    def zeros(batch: int, n_heads: int, head_dim: int):
        z = jnp.zeros((batch, n_heads, head_dim), jnp.float32)
        return SLSTMState(c=z, n=z, h=z, m=z - 1e30)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(key, d_model: int, n_heads: int, *, init: Initializer):
    head_dim = d_model // n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": init(ks[0], (d_model, d_model), fan_in=d_model),
        "wk": init(ks[1], (d_model, d_model), fan_in=d_model),
        "wv": init(ks[2], (d_model, d_model), fan_in=d_model),
        "w_if": init(ks[3], (d_model, 2 * n_heads), fan_in=d_model),
        "b_if": jnp.zeros((2 * n_heads,), jnp.float32),
        "w_o": init(ks[4], (d_model, d_model), fan_in=d_model),
        "ogate": init(ks[5], (d_model, d_model), fan_in=d_model),
    }


def _mlstm_qkv(params, x, n_heads, backend):
    b, s, d = x.shape
    hd = d // n_heads
    q = ops.matmul(x, params["wq"], backend=backend).reshape(b, s, n_heads, hd)
    k = ops.matmul(x, params["wk"], backend=backend).reshape(b, s, n_heads, hd)
    v = ops.matmul(x, params["wv"], backend=backend).reshape(b, s, n_heads, hd)
    gates = ops.matmul(x, params["w_if"], backend=backend).astype(jnp.float32)
    gates = gates + params["b_if"]
    i_pre, f_pre = jnp.split(gates.reshape(b, s, 2, n_heads), 2, axis=2)
    return q, k, v, i_pre[:, :, 0], f_pre[:, :, 0]  # gate pre-acts [B,S,H]


def mlstm_apply(
    params,
    x: jax.Array,
    *,
    n_heads: int,
    chunk: int = 64,
    backend: Optional[str] = None,
    return_state: bool = False,
):
    """Chunkwise-parallel mLSTM. x: [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    hd = d // n_heads
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, x, n_heads, backend)
    logf = jax.nn.log_sigmoid(f_pre)  # [B,S,H]

    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck

    def reshape_c(t):
        return t.reshape(b, nc, ck, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qc, kc, vc = map(reshape_c, (q, k, v))  # [nc,B,ck,H,hd]
    ic, fc = map(reshape_c, (i_pre, logf))  # [nc,B,ck,H]

    def chunk_step(state, inp):
        c, n, m = state  # [B,H,hd,hd], [B,H,hd], [B,H]
        qk, kk, vk, ik, lfk = inp
        lf_cum = jnp.cumsum(lfk, axis=1)  # [B,ck,H] inclusive
        lf_tot = lf_cum[:, -1]  # [B,H]
        # log gate weight of token t's contribution at chunk end:
        # a_t = i_t + sum_{u>t} logf_u = i_t + lf_tot - lf_cum_t
        a = ik + (lf_tot[:, None] - lf_cum)  # [B,ck,H]
        m_new = jnp.maximum(lf_tot + m, a.max(axis=1))  # [B,H]
        # intra-chunk pairwise weights: D_ts = i_s + lf_cum_t - lf_cum_s (s<=t)
        dmat = (
            ik[:, None, :, :] + lf_cum[:, :, None, :] - lf_cum[:, None, :, :]
        )  # [B,t,s,H]
        mask = jnp.tril(jnp.ones((ck, ck), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.maximum(
            dmat.max(axis=2), (lf_cum + m[:, None]) - 1e-9
        )  # running stab per (t): also covers inter part
        m_t = jnp.maximum(m_intra, m[:, None] + lf_cum)  # [B,ck,H]
        w = jnp.exp(dmat - m_t[:, :, None, :])  # [B,t,s,H]
        scale = hd**-0.5
        qf = qk.astype(jnp.float32) * scale
        kf = kk.astype(jnp.float32)
        vf = vk.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * w
        intra = jnp.einsum("btsh,bshd->bthd", scores, vf)
        intra_n = jnp.einsum("btsh,bshd->bthd", scores, jnp.ones_like(kf)[..., :1])
        # inter-chunk: contribution of carried state, decayed to position t.
        w_inter = jnp.exp(m[:, None] + lf_cum - m_t)  # [B,ck,H]
        inter = jnp.einsum("bthd,bhde->bthe", qf, c) * w_inter[..., None]
        inter_n = jnp.einsum("bthd,bhd->bth", qf, n) * w_inter
        num = intra + inter
        den = jnp.abs(intra_n[..., 0] + inter_n)
        h_t = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # state update to chunk end:
        wa = jnp.exp(a - m_new[:, None])  # [B,ck,H]
        c_new = c * jnp.exp(m + lf_tot - m_new)[..., None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", kf, wa, vf
        )
        n_new = n * jnp.exp(m + lf_tot - m_new)[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kf, wa
        )
        return (c_new, n_new, m_new), h_t

    state0 = (
        jnp.zeros((b, n_heads, hd, hd), jnp.float32),
        jnp.zeros((b, n_heads, hd), jnp.float32),
        jnp.full((b, n_heads), -1e30, jnp.float32),
    )
    (c_f, n_f, m_f), hs = jax.lax.scan(chunk_step, state0, (qc, kc, vc, ic, fc))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, d)  # [B,S,D]
    og = jax.nn.sigmoid(
        ops.matmul(x, params["ogate"], backend=backend).astype(jnp.float32)
    )
    out = ops.matmul((hs * og).astype(x.dtype), params["w_o"], backend=backend)
    if return_state:
        return out, MLSTMState(c=c_f, n=n_f, m=m_f)
    return out


def mlstm_decode_step(
    params,
    x: jax.Array,
    state: MLSTMState,
    *,
    n_heads: int,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, MLSTMState]:
    """Exact single-token mLSTM recurrence. x: [B,1,D]."""
    b, _, d = x.shape
    hd = d // n_heads
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, x, n_heads, backend)
    qf = q[:, 0].astype(jnp.float32) * hd**-0.5  # [B,H,hd]
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i_t, lf_t = i_pre[:, 0], jax.nn.log_sigmoid(f_pre[:, 0])  # [B,H]
    m_new = jnp.maximum(state.m + lf_t, i_t)
    wf = jnp.exp(state.m + lf_t - m_new)[..., None]
    wi = jnp.exp(i_t - m_new)[..., None]
    c_new = state.c * wf[..., None] + (kf * wi)[..., :, None] * vf[..., None, :]
    n_new = state.n * wf + kf * wi
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    og = jax.nn.sigmoid(
        ops.matmul(x, params["ogate"], backend=backend).astype(jnp.float32)
    )
    out = (h.reshape(b, 1, d) * og).astype(x.dtype)
    return (
        ops.matmul(out, params["w_o"], backend=backend),
        MLSTMState(c=c_new, n=n_new, m=m_new),
    )


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, d_model: int, n_heads: int, *, init: Initializer):
    hd = d_model // n_heads
    ks = jax.random.split(key, 3)
    return {
        # fused input projection for i, f, z, o pre-activations
        "w_x": init(ks[0], (d_model, 4 * d_model), fan_in=d_model),
        "b": jnp.zeros((4 * d_model,), jnp.float32),
        # per-head recurrent (block-diagonal) mix of h_{t-1}
        "r": init(ks[1], (n_heads, hd, 4 * hd), fan_in=hd),
        "w_o": init(ks[2], (d_model, d_model), fan_in=d_model),
    }


def _slstm_cell(params, xw_t, state: SLSTMState, n_heads: int):
    """One sLSTM step. xw_t: [B, 4D] pre-projected input contribution."""
    b = xw_t.shape[0]
    d = xw_t.shape[1] // 4
    hd = d // n_heads
    rec = jnp.einsum(
        "bhd,hdk->bhk", state.h, params["r"].astype(jnp.float32)
    )  # [B,H,4hd]
    # Layout: the 4D projection is [i | f | z | o] blocks of d each.
    pre = (
        xw_t.astype(jnp.float32).reshape(b, 4, n_heads, hd).transpose(0, 2, 1, 3)
        + rec.reshape(b, n_heads, 4, hd)
        + params["b"].reshape(4, n_heads, hd).transpose(1, 0, 2)[None]
    )  # [B,H,4,hd]
    i_pre, f_pre, z_pre, o_pre = (pre[:, :, j] for j in range(4))
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(state.m + lf, i_pre)
    wf = jnp.exp(state.m + lf - m_new)
    wi = jnp.exp(i_pre - m_new)
    c_new = wf * state.c + wi * jnp.tanh(z_pre)
    n_new = wf * state.n + wi
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm_apply(
    params,
    x: jax.Array,
    *,
    n_heads: int,
    backend: Optional[str] = None,
    return_state: bool = False,
):
    """Sequential sLSTM over the sequence. x: [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    xw = ops.matmul(x, params["w_x"], backend=backend)  # [B,S,4D]
    state0 = SLSTMState.zeros(b, n_heads, d // n_heads)

    def step(state, xw_t):
        new = _slstm_cell(params, xw_t, state, n_heads)
        return new, new.h

    final, hs = jax.lax.scan(step, state0, xw.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = ops.matmul(hs, params["w_o"], backend=backend)
    if return_state:
        return out, final
    return out


def slstm_decode_step(
    params,
    x: jax.Array,
    state: SLSTMState,
    *,
    n_heads: int,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, SLSTMState]:
    b, _, d = x.shape
    xw = ops.matmul(x, params["w_x"], backend=backend)[:, 0]
    new = _slstm_cell(params, xw, state, n_heads)
    h = new.h.reshape(b, 1, d).astype(x.dtype)
    return ops.matmul(h, params["w_o"], backend=backend), new
