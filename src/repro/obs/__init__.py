"""``repro.obs`` — unified telemetry for the O-POPE substrate.

The paper's headline claim is *measured* utilization (99.97% FPU busy); a
reproduction aiming at production scale needs the same discipline about its
own numbers. This package is the one place runtime observability lives:

* :mod:`~repro.obs.metrics` — thread-safe Counter/Gauge/Histogram registry,
  ``snapshot()`` (nested dict), JSON + Prometheus-text exporters, and the
  ``REPRO_METRICS=0`` hard-off switch. All instrumentation in the repo is
  host-side Python (trace-time inside ``jit``), so telemetry adds **zero
  ops to compiled HLO** on or off — asserted on a jitted decode step by
  ``tests/test_obs.py``.
* :mod:`~repro.obs.spans` — ``span(name)``: ``jax.profiler.TraceAnnotation``
  + ``jax.named_scope`` on the device side, a wall-clock histogram on the
  host side.
* :mod:`~repro.obs.logging` — structured launch-script logging
  (``REPRO_LOG=text|json``) and the JSONL event log (``REPRO_EVENTS``,
  ``repro-stats tail``) the train loop's per-step records flow through.
* :mod:`~repro.obs.attr` — live utilization attribution: captured GEMM
  workloads costed with :mod:`repro.core.roofline`, measured step time
  attributed per shape bucket (``gemm.achieved_gflops`` /
  ``gemm.roofline_fraction``; ``repro-stats top``), feeding the
  ``ops.on_util_gap`` drift-retune seam.
* :mod:`~repro.obs.tracing` — request-scoped lifecycle tracing for the
  serving engine (``Request.uid``-keyed phase chains: queue → prefix-attach
  → chunk-prefill → decode, chunk-tick slices, token instants), exported
  as Chrome trace-event JSON (``repro-stats trace`` → Perfetto).
* :mod:`~repro.obs.http` — live scrape surface (``REPRO_METRICS_PORT``):
  ``/metrics`` (Prometheus text), ``/requests`` (in-flight phase ages),
  ``/trace`` (Chrome-trace JSON) on a stdlib ``http.server`` thread.
* :mod:`~repro.obs.audit` — shadow numerics auditor: ``REPRO_AUDIT=N``
  samples quantized-family GEMMs for fp re-execution on the
  ``grad_backend`` (``numerics.abs_err``/``rel_err``, NaN/Inf sentinels,
  ``numerics_drift`` events against per-family policies).

Instrumented layers: ``kernels.ops`` (per-call GEMM counters by
backend/family/tile/fusion source, degradation events, tile-cache hit/miss
+ the ``on_miss_streak`` auto-retune seam), ``serve.continuous``
(per-request lifecycle -> TTFT/ITL histograms, queue/occupancy gauges),
``train.loop`` (per-step wall/tokens-s/roofline events). The ``repro-stats``
CLI (``repro.launch.stats``) surfaces all of it.
"""

from . import attr, audit, http, tracing
from .logging import (
    Logger,
    clear_events,
    event,
    event_log_path,
    follow_events,
    get_logger,
    log_mode,
    read_events,
    recent_events,
    set_event_log,
)
from .metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Registry,
    counter,
    enabled,
    gauge,
    histogram,
    percentile,
    prometheus_text,
    reset,
    set_enabled,
    snapshot,
    to_json,
)
from .spans import span

__all__ = [
    "attr",
    "audit",
    "http",
    "tracing",
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "DEFAULT_BUCKETS",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
    "to_json",
    "prometheus_text",
    "percentile",
    "enabled",
    "set_enabled",
    "span",
    "Logger",
    "get_logger",
    "log_mode",
    "event",
    "clear_events",
    "set_event_log",
    "event_log_path",
    "recent_events",
    "read_events",
    "follow_events",
]
