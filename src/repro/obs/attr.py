"""Live utilization attribution: from "GEMMs ran" to "GEMMs ran *this well*".

O-POPE's headline number is utilization (99.97% of FPU cycles doing useful
MACs), and PR 7's registry records *that* GEMMs ran — this module closes the
gap by scoring *how well*, continuously, on the serving hot loop instead of
only in offline benches.

The mechanics respect the zero-cost contract: per-call device timing is
impossible under ``jit`` (the registry entry points run once at trace time),
so attribution works at the granularity a real wall-clock bracket exists:

1. A timed span owner (the continuous-batching engine's decode step, a
   bench loop) traces its compiled function under :class:`capture_gemms`;
   ``kernels.ops`` appends one :class:`GemmRecord` per registry call it
   traced — shapes, actual dtypes, resolved backend, tile source.
2. :func:`aggregate` folds the records into a :class:`StepWorkload`:
   per-(backend, family, shape-bucket, tile-source) FLOP/byte totals costed
   with :mod:`repro.core.roofline` (``gemm_bytes`` at honest widths, the
   same TPU-v5e reference the benches report against).
3. Each subsequent execution of that compiled step calls
   :func:`observe_step` with its measured wall seconds. The step time is
   attributed to the workload entries in proportion to their roofline-bound
   seconds, yielding per-entry ``gemm.achieved_gflops`` and
   ``gemm.roofline_fraction`` histograms plus a ``gemm.device_seconds``
   counter — the ranking feed for ``repro-stats top``.

Every observation of a *tuned* entry is also forwarded to
``ops._note_util_observation`` — the drift side of the auto-retune seam:
``ops.on_util_gap`` fires for shapes the tuning table covers but that keep
underperforming the threshold (sibling of ``on_miss_streak``, which only
sees shapes the table *misses*).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.roofline import HardwareSpec, TPU_V5E, gemm_bytes

from . import metrics as _metrics

__all__ = [
    "GemmRecord",
    "WorkloadEntry",
    "StepWorkload",
    "capture_gemms",
    "record_call",
    "capturing",
    "shape_bucket",
    "aggregate",
    "observe_step",
    "GFLOPS_BUCKETS",
    "FRACTION_BUCKETS",
]

# GFLOP/s bucket edges: wide enough to cover CPU interpret-mode kernels
# (sub-GFLOP/s) through compiled TPU GEMMs (tens of TFLOP/s).
GFLOPS_BUCKETS: Tuple[float, ...] = (
    0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0, 300.0,
    1e3, 3e3, 1e4, 3e4, 1e5, 3e5,
)

# Roofline-fraction edges: log-spaced below 0.1 (CPU runs scored against the
# TPU-v5e reference live here) and fine near 1.0 (where the paper's claim
# lives).
FRACTION_BUCKETS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 0.001, 0.0025, 0.005,
    0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0,
)


@dataclasses.dataclass(frozen=True)
class GemmRecord:
    """One registry GEMM call as captured at trace time by ``kernels.ops``."""

    shape_family: str  # "dense" | "grouped"
    backend: str
    family: str  # numerics family: "fp" | "q8"
    m: int
    k: int
    n: int
    g: int  # 0 for dense
    a_dtype: str
    b_dtype: str
    out_dtype: str
    tile_source: str  # "tuned" | "heuristic"
    tile_key: Tuple  # ops.TileKey — opaque here, passed back on util gaps


def _pow2_bucket(x: int) -> int:
    """Round up to the next power of two (M varies with live batch size;
    bucketing it keeps label cardinality bounded on a serving process)."""
    if x <= 1:
        return 1
    return 1 << (x - 1).bit_length()


def shape_bucket(rec: GemmRecord) -> str:
    """Stable label for a GEMM shape class: M pow2-bucketed, K/N/G exact
    (weights don't change shape at runtime; the activation row count does)."""
    mb = _pow2_bucket(rec.m)
    if rec.shape_family == "grouped":
        return f"grouped:{rec.g}x{mb}x{rec.k}x{rec.n}"
    return f"dense:{mb}x{rec.k}x{rec.n}"


def _record_cost(
    rec: GemmRecord, hw: HardwareSpec
) -> Tuple[float, float, float]:
    """(flops, bytes, roofline_s) of one record at honest dtype widths."""
    groups = max(rec.g, 1)
    flops = 2.0 * rec.m * rec.k * rec.n * groups
    scale_elems = (rec.m + rec.n) if rec.family == "q8" else 0
    nbytes = groups * gemm_bytes(
        rec.m, rec.k, rec.n,
        a_dtype=rec.a_dtype, b_dtype=rec.b_dtype, out_dtype=rec.out_dtype,
        scale_elems=scale_elems,
    )
    roofline_s = max(flops / hw.peak_flops, nbytes / hw.hbm_bw)
    return flops, float(nbytes), roofline_s


@dataclasses.dataclass
class WorkloadEntry:
    """Aggregated cost of one (backend, family, bucket, tile) class."""

    backend: str
    family: str
    bucket: str
    tile_source: str
    calls: int = 0
    flops: float = 0.0
    bytes: float = 0.0
    roofline_s: float = 0.0
    tile_key: Optional[Tuple] = None  # one representative key for retune


# Keyed by (backend, family, bucket, tile_source).
StepWorkload = Dict[Tuple[str, str, str, str], WorkloadEntry]


# --------------------------------------------------------------------------
# Capture (fed by kernels.ops._note_gemm_call; mirrors ops.capture_shapes)
# --------------------------------------------------------------------------

_CAPTURE: List[list] = []


def capturing() -> bool:
    """Cheap guard ``kernels.ops`` checks before building a record."""
    return bool(_CAPTURE)


def record_call(rec: GemmRecord) -> None:
    for records in _CAPTURE:
        records.append(rec)


class capture_gemms:
    """Context manager collecting every :class:`GemmRecord` the registry
    emits while active. Nestable; tracing triggers the records, so wrapping
    a ``jit`` call captures exactly the GEMMs of that compiled step (and
    nothing on cache hits — which is the signal the serving engine uses to
    know *when* a step traced)."""

    def __enter__(self) -> List[GemmRecord]:
        self._records: List[GemmRecord] = []
        _CAPTURE.append(self._records)
        return self._records

    def __exit__(self, *exc):
        # Identity-based detach, as in ops.capture_shapes: equal contents
        # must not make one capture pop another's list.
        for i in range(len(_CAPTURE) - 1, -1, -1):
            if _CAPTURE[i] is self._records:
                del _CAPTURE[i]
                break
        return False


# --------------------------------------------------------------------------
# Aggregation + attribution
# --------------------------------------------------------------------------


def aggregate(
    records: Sequence[GemmRecord], *, hw: HardwareSpec = TPU_V5E
) -> StepWorkload:
    """Fold captured records into per-class cost totals (roofline-costed)."""
    workload: StepWorkload = {}
    for rec in records:
        bucket = shape_bucket(rec)
        key = (rec.backend, rec.family, bucket, rec.tile_source)
        entry = workload.get(key)
        if entry is None:
            entry = workload[key] = WorkloadEntry(
                backend=rec.backend, family=rec.family, bucket=bucket,
                tile_source=rec.tile_source, tile_key=rec.tile_key,
            )
        flops, nbytes, roofline_s = _record_cost(rec, hw)
        entry.calls += 1
        entry.flops += flops
        entry.bytes += nbytes
        entry.roofline_s += roofline_s
    return workload


def observe_step(workload: StepWorkload, seconds: float) -> None:
    """Attribute one measured execution of ``workload`` to its entries.

    ``seconds`` (host-wall time of the compiled step) is split across the
    entries in proportion to their roofline-bound seconds — the best
    proportional estimate available without per-kernel device profiling —
    then each share scores its entry's ``gemm.achieved_gflops`` and
    ``gemm.roofline_fraction`` and accrues ``gemm.device_seconds``. Tuned
    entries additionally feed ``ops.on_util_gap`` drift detection.
    """
    if seconds <= 0.0 or not workload or not _metrics.enabled():
        return
    total_roofline = sum(e.roofline_s for e in workload.values())
    if total_roofline <= 0.0:
        return
    for entry in workload.values():
        share = entry.roofline_s / total_roofline
        attributed = seconds * share
        if attributed <= 0.0:
            continue
        achieved_gflops = entry.flops / attributed / 1e9
        fraction = entry.roofline_s / attributed
        labels = dict(
            backend=entry.backend, family=entry.family,
            bucket=entry.bucket, tile=entry.tile_source,
        )
        _metrics.histogram(
            "gemm.achieved_gflops", buckets=GFLOPS_BUCKETS, **labels
        ).observe(achieved_gflops)
        _metrics.histogram(
            "gemm.roofline_fraction", buckets=FRACTION_BUCKETS, **labels
        ).observe(fraction)
        _metrics.counter("gemm.device_seconds", **labels).inc(attributed)
        if entry.tile_key is not None:
            # Lazy import: ops imports repro.obs, so the reverse edge must
            # stay out of module scope.
            from repro.kernels import ops as _ops

            _ops._note_util_observation(
                entry.tile_key, fraction, entry.tile_source
            )
