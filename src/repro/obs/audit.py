"""Shadow numerics auditor: sampled fp re-execution of quantized GEMMs.

The precision rule lets serving run int8 (and eventually fp8) GEMM families;
this module answers "is the quantized path still telling the truth?" at
runtime instead of only in ``quant_bench``. A sampling gate (``REPRO_AUDIT=N``
→ audit one in N eligible calls; unset/0 → off) re-executes a
quantized-family GEMM's exact composition — epilogue included — on the
backend's registered full-precision ``grad_backend`` and records:

* ``numerics.abs_err`` / ``numerics.rel_err`` histograms (labelled by
  backend / family / shape family),
* ``numerics.nonfinite`` sentinel counters (NaN / Inf in the quantized
  output — a quantizer overflow never gets to hide in a latency histogram),
* a ``numerics_drift`` structured event + ``numerics.drift`` counter when
  the relative error exceeds the family's policy threshold
  (:func:`set_policy`; ``repro.quant`` registers the q8 policy).

Zero-cost contract: the auditor only ever runs on *concrete* outputs —
``kernels.ops`` skips it for tracers — so with sampling on or off the
compiled HLO of a jitted step is bit-identical (pinned by
``tests/test_obs.py``). The shadow GEMM itself is an eager host-side
re-execution: it costs wall time on the 1-in-N sampled call, never device
ops in anyone's compiled step.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Callable, Dict, Optional, Tuple

from . import metrics as _metrics
from .logging import event as _event

__all__ = [
    "AUDIT_ENV",
    "AuditPolicy",
    "audit_every",
    "set_audit_every",
    "set_policy",
    "get_policy",
    "maybe_audit_gemm",
    "ERR_BUCKETS",
]

AUDIT_ENV = "REPRO_AUDIT"

# Error-magnitude bucket edges (shared by abs and rel error histograms):
# fp32-roundoff (~1e-7) through catastrophically-wrong (>1).
ERR_BUCKETS: Tuple[float, ...] = (
    1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 3e-3, 0.01, 0.03, 0.1, 0.3,
    1.0, 3.0, 10.0,
)


@dataclasses.dataclass(frozen=True)
class AuditPolicy:
    """Per-numerics-family drift threshold. ``rel_err`` is max absolute
    error over the reference's max magnitude — scale-free, so one policy
    covers every layer size."""

    rel_err: float
    abs_err: Optional[float] = None  # optional absolute floor, same units


_LOCK = threading.Lock()
_POLICIES: Dict[str, AuditPolicy] = {}
# Runtime override for the env knob (tests; a serving process could flip it
# live). None → read the environment.
_EVERY_OVERRIDE: Optional[int] = None
_CALLS = 0  # eligible-call counter driving the 1-in-N gate


def audit_every() -> int:
    """Current sampling period: audit one in N eligible calls; 0 = off."""
    if _EVERY_OVERRIDE is not None:
        return _EVERY_OVERRIDE
    raw = os.environ.get(AUDIT_ENV, "")
    try:
        n = int(raw)
    except ValueError:
        return 0
    return max(n, 0)


def set_audit_every(n: Optional[int]) -> None:
    """Override the ``REPRO_AUDIT`` period at runtime (``None`` restores the
    environment's value). Resets the sampling phase."""
    global _EVERY_OVERRIDE, _CALLS
    with _LOCK:
        _EVERY_OVERRIDE = None if n is None else max(int(n), 0)
        _CALLS = 0


def set_policy(family: str, *, rel_err: float,
               abs_err: Optional[float] = None) -> None:
    """Register/replace the drift policy for a numerics family."""
    _POLICIES[family] = AuditPolicy(rel_err=float(rel_err), abs_err=abs_err)


def get_policy(family: str) -> Optional[AuditPolicy]:
    return _POLICIES.get(family)


def _should_sample() -> bool:
    every = audit_every()
    if every <= 0:
        return False
    global _CALLS
    with _LOCK:
        _CALLS += 1
        return _CALLS % every == 0


def maybe_audit_gemm(
    *,
    kind: str,
    backend: str,
    family: str,
    out,
    ref_fn: Callable[[], object],
    m: int,
    k: int,
    n: int,
    g: int = 0,
) -> Optional[Dict[str, float]]:
    """Audit one eligible (quantized-family, concrete-output) GEMM call.

    ``ref_fn`` recomputes the identical composition on the fp
    ``grad_backend`` — the caller (``kernels.ops``) builds the closure so
    this module never imports the registry. Returns the error summary when
    an audit ran (tests use it), else ``None``. Never raises: a diagnostics
    path must not take down the model that it is diagnosing.
    """
    if not _metrics.enabled() or not _should_sample():
        return None
    try:
        import numpy as np

        got = np.asarray(out, dtype=np.float64)
        labels = dict(backend=backend, family=family, shape=kind)
        n_nan = int(np.isnan(got).sum())
        n_inf = int(np.isinf(got).sum())
        if n_nan:
            _metrics.counter("numerics.nonfinite", sentinel="nan",
                             **labels).inc(n_nan)
        if n_inf:
            _metrics.counter("numerics.nonfinite", sentinel="inf",
                             **labels).inc(n_inf)
        ref = np.asarray(ref_fn(), dtype=np.float64)
        finite = np.isfinite(got)
        abs_err = float(np.max(np.abs(np.where(finite, got, 0.0) - ref))) \
            if ref.size else 0.0
        ref_scale = float(np.max(np.abs(ref))) if ref.size else 0.0
        rel_err = abs_err / (ref_scale + 1e-30)
        _metrics.counter("numerics.audits", **labels).inc()
        _metrics.histogram("numerics.abs_err", buckets=ERR_BUCKETS,
                           **labels).observe(abs_err)
        _metrics.histogram("numerics.rel_err", buckets=ERR_BUCKETS,
                           **labels).observe(rel_err)
        policy = _POLICIES.get(family)
        drifted = policy is not None and (
            rel_err > policy.rel_err
            or (policy.abs_err is not None and abs_err > policy.abs_err)
            or n_nan > 0
            or n_inf > 0
        )
        if drifted:
            _metrics.counter("numerics.drift", **labels).inc()
            _event(
                "numerics_drift",
                backend=backend,
                family=family,
                shape_family=kind,
                m=m, k=k, n=n, g=g,
                abs_err=abs_err,
                rel_err=rel_err,
                nan=n_nan,
                inf=n_inf,
                threshold=policy.rel_err,
            )
        return {
            "abs_err": abs_err, "rel_err": rel_err,
            "nan": float(n_nan), "inf": float(n_inf),
            "drifted": float(drifted),
        }
    except Exception:
        return None
