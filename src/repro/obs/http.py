"""Live scrape surface for the serving engine: a stdlib ``http.server``
thread exposing the telemetry this process already keeps.

Endpoints (GET):

* ``/metrics``  — the registry as Prometheus text exposition
  (:func:`repro.obs.metrics.prometheus_text`), byte-identical to
  ``repro-stats snapshot --prom`` over the same registry state.
* ``/requests`` — in-flight serving requests as JSON: current phase,
  phase age, total age (from :func:`repro.obs.tracing.active_requests`).
* ``/trace``    — the request-lifecycle buffer as Chrome trace-event JSON
  (:func:`repro.obs.tracing.chrome_trace`); save and load in Perfetto.

The server is a daemon thread (it never blocks interpreter exit) bound to
localhost by default — this is an operator scrape port, not a public API.
``launch/serve.py`` starts one when ``REPRO_METRICS_PORT`` is set
(:func:`maybe_serve_from_env`); anything else can call
:func:`serve_metrics` directly (port 0 picks an ephemeral port, read it
back from ``server.port``).
"""

from __future__ import annotations

import json
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from . import metrics as _m
from . import tracing as _tracing

__all__ = [
    "MetricsServer",
    "current_server",
    "maybe_serve_from_env",
    "serve_metrics",
    "shutdown",
]

_ENV_VAR = "REPRO_METRICS_PORT"

_INDEX = (
    "repro.obs scrape surface\n"
    "  /metrics   Prometheus text exposition\n"
    "  /requests  in-flight request states (JSON)\n"
    "  /trace     Chrome trace-event JSON (load in Perfetto)\n"
)


class _Handler(BaseHTTPRequestHandler):
    def log_message(self, *args) -> None:  # no per-request stderr chatter
        pass

    def do_GET(self) -> None:
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = _m.prometheus_text().encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/requests":
            body = json.dumps(_tracing.active_requests()).encode()
            ctype = "application/json"
        elif path == "/trace":
            body = json.dumps(_tracing.chrome_trace()).encode()
            ctype = "application/json"
        elif path in ("/", "/healthz"):
            body = _INDEX.encode()
            ctype = "text/plain; charset=utf-8"
        else:
            self.send_error(404, "unknown endpoint")
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class MetricsServer:
    """One scrape server: ``ThreadingHTTPServer`` + daemon accept thread."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1") -> None:
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-metrics-http",
            daemon=True,
        )
        self._thread.start()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


_server: Optional[MetricsServer] = None
_lock = threading.Lock()


def serve_metrics(port: int = 0, host: str = "127.0.0.1") -> MetricsServer:
    """Start (or return the already-running) scrape server."""
    global _server
    with _lock:
        if _server is None:
            _server = MetricsServer(port=port, host=host)
        return _server


def current_server() -> Optional[MetricsServer]:
    return _server


def shutdown() -> None:
    """Stop the scrape server if one is running (idempotent)."""
    global _server
    with _lock:
        if _server is not None:
            _server.stop()
            _server = None


def maybe_serve_from_env() -> Optional[MetricsServer]:
    """Start the server iff ``REPRO_METRICS_PORT`` is set (non-empty).
    ``REPRO_METRICS_PORT=0`` binds an ephemeral port (useful in tests/CI —
    read it back from the returned server)."""
    env = os.environ.get(_ENV_VAR, "")
    if not env:
        return None
    return serve_metrics(port=int(env))
