"""Structured logging + JSONL event log for scripted runs.

**Logger** (the satellite that retires the launch scripts' ad-hoc
``print()``\\ s): ``get_logger("serve").info("generated", tokens=128,
wall_s=1.2)`` writes either

* ``REPRO_LOG=text`` (default) — ``[serve] generated tokens=128 wall_s=1.2``
  (the human-facing shape the old prints had), or
* ``REPRO_LOG=json`` — one JSON object per line
  (``{"ts": ..., "component": "serve", "event": "generated", ...}``) so
  scripted runs produce machine-parseable output.

**Event log**: ``event(kind, **fields)`` appends a structured record to an
in-memory ring buffer (``recent_events``) and — when a sink is configured
via ``set_event_log(path)`` or ``REPRO_EVENTS=<path>`` — to a JSONL file.
The train loop emits one ``train_step`` event per step through this;
``repro-stats tail`` reads the file back. Event emission respects the
``REPRO_METRICS`` hard-off switch (the logger does not: turning telemetry
off must not silence a launch script's output).
"""

from __future__ import annotations

import collections
import json
import os
import sys
import threading
import time
from typing import Dict, List, Optional, TextIO

from . import metrics as _m

__all__ = [
    "Logger",
    "get_logger",
    "log_mode",
    "event",
    "clear_events",
    "set_event_log",
    "event_log_path",
    "recent_events",
    "read_events",
    "follow_events",
]

_LOG_ENV_VAR = "REPRO_LOG"
_EVENTS_ENV_VAR = "REPRO_EVENTS"


def log_mode() -> str:
    """``"text"`` or ``"json"`` (``REPRO_LOG``; unknown values mean text)."""
    mode = os.environ.get(_LOG_ENV_VAR, "text").lower()
    return "json" if mode == "json" else "text"


def _render_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    if isinstance(v, str) and (" " in v or not v):
        return repr(v)
    return str(v)


class Logger:
    """One named component's structured logger (stdout by default —
    launch-script output is the program's product, not a diagnostic)."""

    def __init__(self, component: str, stream: Optional[TextIO] = None):
        self.component = component
        self._stream = stream

    @property
    def stream(self) -> TextIO:
        return self._stream if self._stream is not None else sys.stdout

    def info(self, event_name: str, **fields) -> None:
        if log_mode() == "json":
            rec = {
                "ts": time.time(),
                "component": self.component,
                "event": event_name,
                **fields,
            }
            print(json.dumps(rec, default=str), file=self.stream, flush=True)
        else:
            parts = [f"[{self.component}] {event_name}"]
            parts += [f"{k}={_render_value(v)}" for k, v in fields.items()]
            print(" ".join(parts), file=self.stream, flush=True)

    def raw(self, msg: str) -> None:
        """A preformatted line (e.g. the train loop's own ``log=`` callback):
        passed through in text mode, wrapped as a ``message`` event in json
        mode so the stream stays machine-parseable."""
        if log_mode() == "json":
            self.info("message", msg=msg)
        else:
            print(msg, file=self.stream, flush=True)


_loggers: Dict[str, Logger] = {}
_loggers_lock = threading.Lock()


def get_logger(component: str) -> Logger:
    with _loggers_lock:
        lg = _loggers.get(component)
        if lg is None:
            lg = _loggers[component] = Logger(component)
        return lg


# ---------------------------------------------------------------------------
# JSONL event log
# ---------------------------------------------------------------------------

_RING_CAP = 1024
_events: collections.deque = collections.deque(maxlen=_RING_CAP)
_events_lock = threading.Lock()
_sink_path: Optional[str] = os.environ.get(_EVENTS_ENV_VAR) or None


def set_event_log(path: Optional[str]) -> Optional[str]:
    """Point the JSONL sink at ``path`` (None = ring buffer only); returns
    the previous sink path."""
    global _sink_path
    prev = _sink_path
    _sink_path = path
    return prev


def event_log_path() -> Optional[str]:
    return _sink_path


def event(kind: str, **fields) -> None:
    """Record a structured event (no-op when telemetry is hard-off)."""
    if not _m.enabled():
        return
    rec = {"ts": time.time(), "kind": kind, **fields}
    with _events_lock:
        _events.append(rec)
        if _sink_path:
            try:
                with open(_sink_path, "a") as f:
                    f.write(json.dumps(rec, default=str) + "\n")
            except OSError:
                pass  # a full disk must not take the serving loop down


def clear_events() -> None:
    """Drop the in-memory ring buffer (any JSONL sink file is untouched).
    Tests call this between cases; long-lived processes normally never do."""
    with _events_lock:
        _events.clear()


def recent_events(n: int = 50, *, kind: Optional[str] = None) -> List[dict]:
    """Most recent ``n`` ring-buffer events (newest last)."""
    with _events_lock:
        evts = list(_events)
    if kind is not None:
        evts = [e for e in evts if e.get("kind") == kind]
    return evts[-n:]


def read_events(path: str, n: Optional[int] = None) -> List[dict]:
    """Read (the last ``n`` lines of) a JSONL event file; bad lines skipped."""
    out = []
    with open(path) as f:
        lines = f.readlines()
    if n is not None:
        lines = lines[-n:]
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return out


def follow_events(
    path: str,
    *,
    poll_interval: float = 0.5,
    start_at_end: bool = False,
    stop=None,
):
    """Generator over events appended to a JSONL sink — the engine behind
    ``repro-stats tail --follow``. Polls (portable: no inotify); waits for
    the file to appear; yields each complete line as a parsed dict (a line
    mid-write — no trailing newline yet — is buffered until its newline
    lands; undecodable lines are skipped). ``start_at_end`` skips history
    and yields only events appended after the call. ``stop`` is an optional
    zero-arg callable polled between reads — return True to end the
    generator (tests and embedders; the CLI just Ctrl-C's)."""
    while not os.path.exists(path):
        if stop is not None and stop():
            return
        time.sleep(poll_interval)
    buf = ""
    with open(path) as f:
        if start_at_end:
            f.seek(0, os.SEEK_END)
        while True:
            chunk = f.readline()
            if not chunk:
                if stop is not None and stop():
                    return
                time.sleep(poll_interval)
                continue
            buf += chunk
            if not buf.endswith("\n"):
                continue  # partial line: writer mid-append
            line, buf = buf.strip(), ""
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                continue
