"""Zero-dependency, thread-safe metrics registry (the `repro.obs` core).

Three instrument kinds, all label-aware:

* :class:`Counter` — monotonically increasing float (``inc``);
* :class:`Gauge` — last-written value (``set``/``add``);
* :class:`Histogram` — fixed bucket edges for export plus a bounded raw
  sample reservoir for exact percentiles (``observe``/``percentile``).

Instruments are owned by a :class:`Registry`; the module-level default
registry is what the instrumented layers (``kernels.ops``,
``serve.continuous``, ``train.loop``) write into. ``snapshot()`` renders
the whole registry as a nested dict (stable key order), ``to_json`` /
``prometheus_text`` export it, and ``reset()`` drops every instrument —
wired into ``tests/conftest.py`` so suites can't order-depend on
accumulated counts.

**The hard-off switch.** ``REPRO_METRICS=0`` (or ``set_enabled(False)``)
makes every instrument-fetch return a shared null object whose methods are
no-ops. All instrumentation in this repo is *host-side Python* — it runs at
trace time inside ``jit``, never staging device ops — so telemetry adds
zero instructions to any compiled HLO whether on or off (asserted by
``tests/test_obs.py`` on a jitted decode step). The off switch exists to
drop even the host-side dict lookups on hot host loops.

``REPRO_METRICS_DUMP=<path>`` registers an atexit hook that writes the
final snapshot as JSON — any scripted run becomes observable after the
fact (``repro-stats snapshot --in <path>``).
"""

from __future__ import annotations

import collections
import json
import math
import os
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "DEFAULT_BUCKETS",
    "enabled",
    "set_enabled",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
    "to_json",
    "prometheus_text",
    "percentile",
]

# Latency-oriented default bucket edges (seconds). Wide enough for CPU-run
# decode steps (~ms..s) and TPU steps (~us..ms) alike; histograms accept
# custom edges where these don't fit.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)

# Bounded raw-sample reservoir per histogram: exact percentiles over the most
# recent observations without unbounded memory on long-lived servers.
_SAMPLE_CAP = 4096

_ENV_VAR = "REPRO_METRICS"
_DUMP_ENV_VAR = "REPRO_METRICS_DUMP"

_enabled = os.environ.get(_ENV_VAR, "1") not in ("0", "false", "off")


def enabled() -> bool:
    """Whether telemetry is on (default yes; ``REPRO_METRICS=0`` hard-off)."""
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Flip telemetry on/off at runtime; returns the previous state.

    Affects instrument fetches made *after* the call (handles are looked up
    per call site invocation, so instrumented layers react immediately).
    """
    global _enabled
    prev = _enabled
    _enabled = bool(flag)
    return prev


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Exact linear-interpolated percentile of ``values`` (q in [0, 100]).

    Returns ``None`` for an empty sequence — "no data" and "zero latency"
    are different facts, and conflating them once poisoned a serving
    report. Consumers serialize it as JSON ``null``.
    """
    if not values:
        return None
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


LabelValue = Union[str, int, float, bool]
LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, LabelValue]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class Counter:
    """Monotonic counter. ``inc`` is the only mutator (never decreases)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-value instrument (queue depth, occupancy, tokens/s)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram plus a bounded raw-sample reservoir.

    Buckets (cumulative, Prometheus-style ``le`` semantics on export) give a
    stable wire format; the reservoir (most recent ``_SAMPLE_CAP``
    observations) gives exact percentiles — bucket interpolation would make
    ``ttft_p99`` a function of edge placement, which is exactly the kind of
    lie a utilization paper repro must not tell.
    """

    __slots__ = ("_lock", "edges", "bucket_counts", "count", "sum",
                 "min", "max", "_samples")

    def __init__(self, buckets: Optional[Iterable[float]] = None) -> None:
        self._lock = threading.Lock()
        self.edges: Tuple[float, ...] = tuple(sorted(buckets or DEFAULT_BUCKETS))
        if not self.edges:
            raise ValueError("histogram needs at least one bucket edge")
        self.bucket_counts = [0] * (len(self.edges) + 1)  # +1 = +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: collections.deque = collections.deque(maxlen=_SAMPLE_CAP)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = 0
            while i < len(self.edges) and v > self.edges[i]:
                i += 1
            self.bucket_counts[i] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self._samples.append(v)

    def percentile(self, q: float) -> Optional[float]:
        with self._lock:
            return percentile(list(self._samples), q)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def samples_seen(self) -> int:
        """Total observations, whether or not still in the reservoir."""
        return self.count

    @property
    def samples_dropped(self) -> int:
        """Observations evicted from the reservoir: >0 means percentiles are
        computed over a trailing window, not the full history."""
        with self._lock:
            return self.count - len(self._samples)


class _NullInstrument:
    """The disabled-mode stand-in: every method is a no-op, every read zero."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, n: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> Optional[float]:
        return None

    value = 0.0
    count = 0
    sum = 0.0
    mean = 0.0
    samples_seen = 0
    samples_dropped = 0


_NULL = _NullInstrument()


class Registry:
    """Named, labelled instruments behind one lock; snapshot/reset/export."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Dict[LabelKey, Counter]] = {}
        self._gauges: Dict[str, Dict[LabelKey, Gauge]] = {}
        self._histograms: Dict[str, Dict[LabelKey, Histogram]] = {}
        self._histogram_buckets: Dict[str, Tuple[float, ...]] = {}

    # -- instrument fetch (get-or-create) -----------------------------------

    def counter(self, name: str, /, **labels: LabelValue) -> Counter:
        if not _enabled:
            return _NULL  # type: ignore[return-value]
        key = _label_key(labels)
        with self._lock:
            fam = self._counters.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                inst = fam[key] = Counter()
        return inst

    def gauge(self, name: str, /, **labels: LabelValue) -> Gauge:
        if not _enabled:
            return _NULL  # type: ignore[return-value]
        key = _label_key(labels)
        with self._lock:
            fam = self._gauges.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                inst = fam[key] = Gauge()
        return inst

    def histogram(
        self, name: str, /, *, buckets: Optional[Iterable[float]] = None,
        **labels: LabelValue,
    ) -> Histogram:
        if not _enabled:
            return _NULL  # type: ignore[return-value]
        key = _label_key(labels)
        with self._lock:
            fam = self._histograms.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                if buckets is not None:
                    self._histogram_buckets[name] = tuple(sorted(buckets))
                inst = fam[key] = Histogram(
                    self._histogram_buckets.get(name)
                )
        return inst

    # -- snapshot / reset ----------------------------------------------------

    def snapshot(self) -> Dict[str, Dict]:
        """Nested dict of every instrument, keys sorted for stable diffs.

        Shape::

            {"counters":   {name: {label_str: value}},
             "gauges":     {name: {label_str: value}},
             "histograms": {name: {label_str: {count, sum, mean, min, max,
                                               p50, p90, p99,
                                               samples_seen, samples_dropped,
                                               percentile_mode,
                                               buckets: {le: cumulative}}}}}

        Percentiles are ``None`` when the histogram is empty. Once the
        bounded reservoir evicts old samples, they cover a trailing window
        only — ``percentile_mode`` says ``"exact"`` vs ``"windowed"`` so
        readers (``repro-stats``) can tag them honestly.
        """
        with self._lock:
            counters = {
                name: {k: inst.value for k, inst in fam.items()}
                for name, fam in self._counters.items()
            }
            gauges = {
                name: {k: inst.value for k, inst in fam.items()}
                for name, fam in self._gauges.items()
            }
            hists = {
                name: dict(fam) for name, fam in self._histograms.items()
            }
        out: Dict[str, Dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(counters):
            out["counters"][name] = {
                _label_str(k): counters[name][k] for k in sorted(counters[name])
            }
        for name in sorted(gauges):
            out["gauges"][name] = {
                _label_str(k): gauges[name][k] for k in sorted(gauges[name])
            }
        for name in sorted(hists):
            fam_out = {}
            for k in sorted(hists[name]):
                h = hists[name][k]
                cumulative = 0
                buckets = {}
                for edge, c in zip(h.edges, h.bucket_counts):
                    cumulative += c
                    buckets[repr(edge)] = cumulative
                buckets["+Inf"] = h.count
                dropped = h.samples_dropped
                fam_out[_label_str(k)] = {
                    "count": h.count,
                    "sum": h.sum,
                    "mean": h.mean,
                    "min": h.min if h.count else 0.0,
                    "max": h.max if h.count else 0.0,
                    "p50": h.percentile(50),
                    "p90": h.percentile(90),
                    "p99": h.percentile(99),
                    "samples_seen": h.samples_seen,
                    "samples_dropped": dropped,
                    "percentile_mode": "windowed" if dropped else "exact",
                    "buckets": buckets,
                }
            out["histograms"][name] = fam_out
        return out

    def reset(self) -> None:
        """Drop every instrument (names, labels, values). Tests call this
        between cases; long-lived processes normally never do."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._histogram_buckets.clear()


# ---------------------------------------------------------------------------
# Default registry + module-level convenience API
# ---------------------------------------------------------------------------

_REGISTRY = Registry()


def counter(name: str, /, **labels: LabelValue) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, /, **labels: LabelValue) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(
    name: str, /, *, buckets: Optional[Iterable[float]] = None,
    **labels: LabelValue,
) -> Histogram:
    return _REGISTRY.histogram(name, buckets=buckets, **labels)


def snapshot() -> Dict[str, Dict]:
    return _REGISTRY.snapshot()


def reset() -> None:
    _REGISTRY.reset()


def to_json(indent: Optional[int] = 2) -> str:
    return json.dumps(snapshot(), indent=indent, sort_keys=False)


# ---------------------------------------------------------------------------
# Prometheus text exposition (rendered from a snapshot dict, so the CLI can
# export a file written by another process)
# ---------------------------------------------------------------------------


def _prom_name(name: str, suffix: str = "") -> str:
    safe = "".join(c if (c.isalnum() or c == "_") else "_" for c in name)
    return f"repro_{safe}{suffix}"


def _prom_labels(label_str: str) -> str:
    if not label_str:
        return ""
    pairs = [p.split("=", 1) for p in label_str.split(",")]
    inner = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + inner + "}"


def prometheus_text(snap: Optional[Dict[str, Dict]] = None) -> str:
    """Prometheus text-exposition rendering of ``snap`` (default: the live
    default registry). Counters get ``_total``, histograms the standard
    ``_bucket``/``_sum``/``_count`` triplet."""
    snap = snap if snap is not None else snapshot()
    lines: List[str] = []
    for name, fam in snap.get("counters", {}).items():
        pname = _prom_name(name, "_total")
        lines.append(f"# TYPE {pname} counter")
        for label_str, value in fam.items():
            lines.append(f"{pname}{_prom_labels(label_str)} {value}")
    for name, fam in snap.get("gauges", {}).items():
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} gauge")
        for label_str, value in fam.items():
            lines.append(f"{pname}{_prom_labels(label_str)} {value}")
    for name, fam in snap.get("histograms", {}).items():
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} histogram")
        for label_str, h in fam.items():
            for le, cum in h["buckets"].items():
                le_pairs = (label_str + "," if label_str else "") + f"le={le}"
                lines.append(f"{pname}_bucket{_prom_labels(le_pairs)} {cum}")
            lines.append(f"{pname}_sum{_prom_labels(label_str)} {h['sum']}")
            lines.append(f"{pname}_count{_prom_labels(label_str)} {h['count']}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# REPRO_METRICS_DUMP: write the final snapshot at interpreter exit
# ---------------------------------------------------------------------------

_dump_path = os.environ.get(_DUMP_ENV_VAR)
if _dump_path:
    import atexit

    def _dump_at_exit(path: str = _dump_path) -> None:
        try:
            with open(path, "w") as f:
                json.dump(snapshot(), f, indent=2)
        except OSError:
            pass  # a dump failure must never mask the run's own exit status

    atexit.register(_dump_at_exit)
