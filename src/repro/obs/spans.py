"""Trace spans: named brackets that show up on device *and* host.

``span(name)`` is one context manager serving both worlds:

* **device** — the body runs under ``jax.profiler.TraceAnnotation`` (the
  bracket appears on the TensorBoard/Perfetto trace timeline when a profile
  is being captured — see ``repro-stats --profile``) and ``jax.named_scope``
  (the name lands in HLO metadata for anything traced inside, without
  adding a single instruction);
* **host** — a wall-clock timer records the bracket duration into the
  ``span.seconds`` histogram, labelled by span name.

With telemetry off (``REPRO_METRICS=0``) the whole thing is a bare
``yield`` — no annotation objects, no timer, no scope — so a disabled
process is bit-for-bit the un-instrumented one.
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterator

from . import metrics as _m

__all__ = ["span"]


@contextlib.contextmanager
def span(name: str, **labels) -> Iterator[None]:
    """Bracket a region: profiler annotation + HLO scope + host wall timer."""
    if not _m.enabled():
        yield
        return
    import jax

    t0 = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
            yield
    finally:
        _m.histogram("span.seconds", name=name, **labels).observe(
            time.perf_counter() - t0
        )
