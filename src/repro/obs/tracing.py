"""Request-scoped lifecycle tracing for the serving engine.

``obs.metrics`` answers "how is the engine doing in aggregate"; this module
answers "why was *this* request's TTFT 40 ms". A global
:class:`TraceRecorder` stamps every lifecycle edge of a serving request —
arrival (queue enter), admission (queue exit, incl. the fall-through bucket
chosen), prefix-cache match/attach, each chunk-prefill tick, first token,
every decode ITL, retirement (EOS / budget-evict) — keyed by the stable
``Request.uid`` the scheduler assigns at construction.

Same zero-device-cost discipline as the registry: every stamp is host-side
Python at dispatch time; nothing here runs inside ``jit``, so the compiled
decode-step HLO is bit-identical with tracing on or off (pinned by
``tests/test_obs.py``).

A request's record is a chain of **contiguous phases** sharing the engine's
exact wall stamps — ``queue`` → ``prefix_attach`` → ``chunk_prefill`` →
``decode`` (chunked path) or ``queue`` → ``prefill`` → ``decode``
(monolithic path) — so the pre-decode phase durations sum *exactly* to the
``serve.ttft_seconds`` sample recorded for the same request. Nested slices
(one per chunk-prefill tick) and instants (admission, prefix attach, first
token, every token) hang off the phases for fine detail.

Export: :func:`chrome_trace` converts a snapshot to Chrome trace-event JSON
(loads in Perfetto / ``chrome://tracing``): one track per slot plus a queue
track, one async span per request (``ph: b/e`` keyed by uid) with the phase
chain as nested ``X`` complete events. ``repro-stats trace`` is the CLI
wrapper; ``obs.http``'s ``/trace`` endpoint serves it live.

Env knobs:

* ``REPRO_TRACE=0`` — disable tracing alone (metrics stay on). Tracing is
  also off whenever the registry is hard-off (``REPRO_METRICS=0``).
* ``REPRO_TRACE_DUMP=<path>`` — write the raw recorder snapshot (JSON) at
  interpreter exit, the tracing sibling of ``REPRO_METRICS_DUMP``;
  ``repro-stats trace --file`` converts it offline.
* ``REPRO_TRACE_CAP=<n>`` — retired-request ring size (default 4096).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from . import metrics as _m

__all__ = [
    "TraceRecorder",
    "active_requests",
    "annotate",
    "begin_phase",
    "begin_request",
    "chrome_trace",
    "enabled",
    "end_request",
    "instant",
    "recorder",
    "reset",
    "set_enabled",
    "set_slot",
    "slice_event",
    "snapshot",
    "validate_chrome_trace",
]

_ENV_VAR = "REPRO_TRACE"
_DUMP_ENV_VAR = "REPRO_TRACE_DUMP"
_CAP_ENV_VAR = "REPRO_TRACE_CAP"

# Per-request instant cap: decode emits one instant per token, and a
# pathological request could otherwise grow without bound. Drops count in
# the request's meta (``instants_dropped``) — silent truncation would read
# as "request emitted fewer tokens".
_MAX_INSTANTS = 4096

_forced: Optional[bool] = None  # set_enabled override (None = env default)


def enabled() -> bool:
    """Tracing is on iff the metrics registry is on AND tracing itself is
    not disabled (``set_enabled(False)`` or ``REPRO_TRACE=0``)."""
    if not _m.enabled():
        return False
    if _forced is not None:
        return _forced
    return os.environ.get(_ENV_VAR, "1").lower() not in ("0", "false", "off")


def set_enabled(flag: Optional[bool]) -> Optional[bool]:
    """Force tracing on/off for this process; ``None`` restores the env
    default. Returns the previous override."""
    global _forced
    prev = _forced
    _forced = flag
    return prev


class TraceRecorder:
    """Thread-safe recorder of per-request lifecycle events.

    Active requests live in a uid-keyed dict; retired ones move to a
    bounded ring (oldest dropped first). All timestamps are
    ``time.perf_counter()`` floats; the snapshot carries the
    ``(epoch, perf_counter)`` pair captured at construction so exporters
    can place the trace on the wall clock.
    """

    def __init__(self, cap: Optional[int] = None) -> None:
        if cap is None:
            cap = int(os.environ.get(_CAP_ENV_VAR, "4096") or "4096")
        self._lock = threading.Lock()
        self._active: Dict[int, Dict[str, Any]] = {}
        self._retired: deque = deque(maxlen=cap)
        self._epoch = time.time()
        self._perf0 = time.perf_counter()

    # -- recording ---------------------------------------------------------

    def begin_request(self, uid: int, rid: Any, ts: float) -> None:
        """Open a request's record with its ``queue`` phase (queue enter)."""
        if not enabled():
            return
        rec = {
            "uid": uid,
            "rid": rid,
            "slot": None,
            "phases": [{"name": "queue", "t0": ts, "t1": None}],
            "slices": [],
            "instants": [],
            "retired_ts": None,
            "retire_reason": None,
            "meta": {},
        }
        with self._lock:
            self._active[uid] = rec

    def set_slot(self, uid: int, slot: int) -> None:
        if not enabled():
            return
        with self._lock:
            rec = self._active.get(uid)
            if rec is not None:
                rec["slot"] = slot

    def annotate(self, uid: int, **fields: Any) -> None:
        if not enabled():
            return
        with self._lock:
            rec = self._active.get(uid)
            if rec is not None:
                rec["meta"].update(fields)

    def begin_phase(self, uid: int, name: str, ts: float) -> None:
        """Close the open phase at ``ts`` and open ``name`` — phases are
        contiguous by construction, so they tile the request's lifetime."""
        if not enabled():
            return
        with self._lock:
            rec = self._active.get(uid)
            if rec is None:
                return
            if rec["phases"] and rec["phases"][-1]["t1"] is None:
                rec["phases"][-1]["t1"] = ts
            rec["phases"].append({"name": name, "t0": ts, "t1": None})

    def slice_event(
        self, uid: int, name: str, t0: float, t1: float, **fields: Any
    ) -> None:
        """A nested timed slice inside the current phase (chunk ticks)."""
        if not enabled():
            return
        with self._lock:
            rec = self._active.get(uid)
            if rec is not None:
                rec["slices"].append(
                    {"name": name, "t0": t0, "t1": t1, **fields}
                )

    def instant(self, uid: int, name: str, ts: float, **fields: Any) -> None:
        if not enabled():
            return
        with self._lock:
            rec = self._active.get(uid)
            if rec is None:
                return
            if len(rec["instants"]) >= _MAX_INSTANTS:
                rec["meta"]["instants_dropped"] = (
                    rec["meta"].get("instants_dropped", 0) + 1
                )
                return
            rec["instants"].append({"name": name, "ts": ts, **fields})

    def end_request(self, uid: int, reason: str, ts: float) -> None:
        """Retire the request: close the open phase and move the record to
        the bounded ring."""
        if not enabled():
            return
        with self._lock:
            rec = self._active.pop(uid, None)
            if rec is None:
                return
            if rec["phases"] and rec["phases"][-1]["t1"] is None:
                rec["phases"][-1]["t1"] = ts
            rec["retired_ts"] = ts
            rec["retire_reason"] = reason
            self._retired.append(rec)

    # -- reading -----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Deep-copied view: retired requests first (oldest first), then
        still-active ones, plus the clock anchor for exporters."""
        import copy

        with self._lock:
            reqs = list(self._retired) + [
                self._active[k] for k in sorted(self._active)
            ]
            return {
                "clock": {"epoch": self._epoch, "perf": self._perf0},
                "requests": copy.deepcopy(reqs),
            }

    def active_requests(self, now: Optional[float] = None) -> List[Dict]:
        """In-flight request states for the ``/requests`` endpoint: current
        phase, phase age, and total age (seconds)."""
        if now is None:
            now = time.perf_counter()
        out = []
        with self._lock:
            for uid in sorted(self._active):
                rec = self._active[uid]
                open_phase = next(
                    (p for p in reversed(rec["phases"]) if p["t1"] is None),
                    None,
                )
                phase = open_phase["name"] if open_phase else "unknown"
                t_start = rec["phases"][0]["t0"] if rec["phases"] else now
                out.append(
                    {
                        "uid": uid,
                        "rid": rec["rid"],
                        "slot": rec["slot"],
                        "phase": phase,
                        "phase_age_s": (
                            now - open_phase["t0"] if open_phase else 0.0
                        ),
                        "age_s": now - t_start,
                        "tokens": sum(
                            1 for i in rec["instants"]
                            if i["name"] in ("first_token", "token")
                        ),
                        "meta": dict(rec["meta"]),
                    }
                )
        return out

    def reset(self) -> None:
        with self._lock:
            self._active.clear()
            self._retired.clear()
            self._epoch = time.time()
            self._perf0 = time.perf_counter()


_RECORDER = TraceRecorder()


def recorder() -> TraceRecorder:
    return _RECORDER


def begin_request(uid: int, rid: Any, ts: float) -> None:
    _RECORDER.begin_request(uid, rid, ts)


def set_slot(uid: int, slot: int) -> None:
    _RECORDER.set_slot(uid, slot)


def annotate(uid: int, **fields: Any) -> None:
    _RECORDER.annotate(uid, **fields)


def begin_phase(uid: int, name: str, ts: float) -> None:
    _RECORDER.begin_phase(uid, name, ts)


def slice_event(uid: int, name: str, t0: float, t1: float, **fields) -> None:
    _RECORDER.slice_event(uid, name, t0, t1, **fields)


def instant(uid: int, name: str, ts: float, **fields: Any) -> None:
    _RECORDER.instant(uid, name, ts, **fields)


def end_request(uid: int, reason: str, ts: float) -> None:
    _RECORDER.end_request(uid, reason, ts)


def snapshot() -> Dict[str, Any]:
    return _RECORDER.snapshot()


def active_requests(now: Optional[float] = None) -> List[Dict]:
    return _RECORDER.active_requests(now)


def reset() -> None:
    _RECORDER.reset()


# -- Chrome trace-event export ----------------------------------------------

_PID = 1
_QUEUE_TID = 0


def _slot_tid(slot: Optional[int]) -> int:
    return _QUEUE_TID if slot is None else int(slot) + 1


def chrome_trace(snap: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Convert a recorder snapshot to Chrome trace-event JSON (Perfetto /
    ``chrome://tracing`` loadable).

    Layout: one process ("repro.serve"), thread 0 is the queue track,
    thread ``slot + 1`` is that slot's track. Each request is one async
    nestable span (``ph: b``/``e``, ``id`` = uid) opened at arrival and
    closed at retirement; its contiguous phases are ``X`` complete events
    (the ``queue`` phase on the queue track, everything after admission on
    the slot track), chunk ticks are nested ``X`` slices, and admission /
    prefix-attach / token edges are ``i`` instants. Timestamps are
    microseconds relative to the recorder's clock anchor.
    """
    if snap is None:
        snap = snapshot()
    perf0 = float(snap["clock"]["perf"])

    def us(t: float) -> float:
        return (t - perf0) * 1e6

    events: List[Dict[str, Any]] = [
        {
            "ph": "M", "pid": _PID, "name": "process_name",
            "args": {"name": "repro.serve"},
        },
        {
            "ph": "M", "pid": _PID, "tid": _QUEUE_TID, "name": "thread_name",
            "args": {"name": "queue"},
        },
    ]
    named_tids = {_QUEUE_TID}
    for req in snap["requests"]:
        tid = _slot_tid(req.get("slot"))
        if tid not in named_tids:
            named_tids.add(tid)
            events.append(
                {
                    "ph": "M", "pid": _PID, "tid": tid, "name": "thread_name",
                    "args": {"name": f"slot {req['slot']}"},
                }
            )
        phases = req.get("phases") or []
        if not phases:
            continue
        uid = req["uid"]
        start = phases[0]["t0"]
        end_ts = req.get("retired_ts")
        events.append(
            {
                "ph": "b", "cat": "request", "id": uid,
                "name": f"req {req['rid']}", "pid": _PID, "tid": tid,
                "ts": us(start),
                "args": {"uid": uid, "rid": req["rid"], **req.get("meta", {})},
            }
        )
        for p in phases:
            t1 = p["t1"] if p["t1"] is not None else (end_ts or p["t0"])
            events.append(
                {
                    "ph": "X", "cat": "phase", "name": p["name"],
                    "pid": _PID,
                    "tid": _QUEUE_TID if p["name"] == "queue" else tid,
                    "ts": us(p["t0"]),
                    "dur": max(0.0, (t1 - p["t0"]) * 1e6),
                    "args": {"uid": uid},
                }
            )
        for s in req.get("slices", []):
            extra = {
                k: v for k, v in s.items() if k not in ("name", "t0", "t1")
            }
            events.append(
                {
                    "ph": "X", "cat": "slice", "name": s["name"],
                    "pid": _PID, "tid": tid, "ts": us(s["t0"]),
                    "dur": max(0.0, (s["t1"] - s["t0"]) * 1e6),
                    "args": {"uid": uid, **extra},
                }
            )
        for i in req.get("instants", []):
            extra = {k: v for k, v in i.items() if k not in ("name", "ts")}
            events.append(
                {
                    "ph": "i", "s": "t", "name": i["name"],
                    "pid": _PID, "tid": tid, "ts": us(i["ts"]),
                    "args": {"uid": uid, **extra},
                }
            )
        if end_ts is not None:
            events.append(
                {
                    "ph": "e", "cat": "request", "id": uid,
                    "name": f"req {req['rid']}", "pid": _PID, "tid": tid,
                    "ts": us(end_ts),
                    "args": {"reason": req.get("retire_reason")},
                }
            )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(doc: Dict[str, Any]) -> int:
    """Structural validation of a Chrome trace document; returns the number
    of request spans. Raises ``ValueError`` on: missing/empty
    ``traceEvents``, an async ``e`` without a matching open ``b`` (or vice
    versa), a negative ``X`` duration, or a closed request span with no
    nested phase slice. Used by the serving bench and the CI smoke."""
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents missing or empty")
    open_spans: Dict[Any, float] = {}
    closed: Dict[Any, tuple] = {}
    phases_by_uid: Dict[Any, int] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "b" and ev.get("cat") == "request":
            key = ev.get("id")
            if key in open_spans:
                raise ValueError(f"request span {key!r} opened twice")
            open_spans[key] = float(ev["ts"])
        elif ph == "e" and ev.get("cat") == "request":
            key = ev.get("id")
            if key not in open_spans:
                raise ValueError(f"request span {key!r} closed without open")
            t0 = open_spans.pop(key)
            t1 = float(ev["ts"])
            if t1 < t0:
                raise ValueError(f"request span {key!r} ends before it begins")
            closed[key] = (t0, t1)
        elif ph == "X":
            dur = ev.get("dur")
            if dur is None or float(dur) < 0:
                raise ValueError(
                    f"X event {ev.get('name')!r} has invalid dur {dur!r}"
                )
            if ev.get("cat") == "phase":
                uid = (ev.get("args") or {}).get("uid")
                phases_by_uid[uid] = phases_by_uid.get(uid, 0) + 1
    for key in closed:
        if not phases_by_uid.get(key):
            raise ValueError(f"request span {key!r} has no phase slices")
    return len(closed) + len(open_spans)


# ---------------------------------------------------------------------------
# REPRO_TRACE_DUMP: write the raw snapshot at interpreter exit (sibling of
# REPRO_METRICS_DUMP). `repro-stats trace --file <path>` converts offline.
# ---------------------------------------------------------------------------
_dump_path = os.environ.get(_DUMP_ENV_VAR)
if _dump_path:
    import atexit

    def _dump_at_exit(path: str = _dump_path) -> None:
        try:
            with open(path, "w") as f:
                json.dump(snapshot(), f)
        except Exception:
            pass  # never let telemetry break interpreter shutdown

    atexit.register(_dump_at_exit)
