"""Optimizer substrate: AdamW + cosine schedule, sharded moments."""
from .adamw import AdamWConfig, OptState, apply_updates, cosine_lr, init_opt_state
__all__ = ["AdamWConfig", "OptState", "apply_updates", "cosine_lr", "init_opt_state"]
