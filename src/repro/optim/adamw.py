"""AdamW with cosine schedule, global-norm clipping and sharded moments.

No optax dependency — the update is ~40 lines and owning it lets the moment
dtype be configured per architecture (grok-1 uses bf16 moments to fit HBM;
the quantization error is dominated by Adam's epsilon at our scales, and the
trade is recorded in DESIGN.md §4). Moments inherit the parameter shardings
(ZeRO-style: the 2-D param sharding already spreads them across the mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "apply_updates", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any  # first moments (tree like params)
    nu: Any  # second moments


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def _global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Decay is applied to matrices only (ndim >= 2)."""
    gnorm = _global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.mu)
    v_leaves = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
