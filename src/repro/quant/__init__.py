"""Mixed-precision subsystem: quantizers, quantized O-POPE backends,
precision policies, and quantized serving KV lanes.

The paper opens on the trade-off this package makes expressible in software:
quantization mitigates computational and data-movement costs, while
accuracy-sensitive work (training, routing, logits) stays in floating point.

* :mod:`repro.quant.quantize` — int8 / emulated-fp8 quantizers, per-tensor
  and per-channel scales, calibration from sample batches.
* :mod:`repro.quant.backends` — ``xla_q8`` and ``pallas_q8`` GEMM backends,
  registered through the ``repro.kernels.ops`` registry on import (the
  registry also imports this package lazily when either name is requested).
* :mod:`repro.quant.pallas_q8` — the int8 O-POPE Pallas kernel (int32
  resident accumulator, dequant at the writeback boundary).
* :mod:`repro.quant.policy` — :class:`PrecisionPolicy`, mapping model layer
  roles to backends; gradients stay fp32 by registry rule.
* :mod:`repro.quant.kvcache` — :class:`QuantKVCache`: narrow K/V lanes with
  per-slot, per-head scales for the continuous-batching slot pool.
"""

from . import backends as _backends  # registers xla_q8 / pallas_q8
from .backends import register_quant_backends
from .kvcache import (
    DEFAULT_KV_MARGIN,
    QuantKVCache,
    kv_bytes_per_slot,
    quantize_kv,
    quantize_kv_rows,
)
from .pallas_q8 import opope_gemm_q8, opope_gemm_q8_grouped, q8_block_shape
from .policy import ROLES, PrecisionPolicy, mlp_q8_policy, preferred_q8_backend
from .quantize import (
    FORMATS,
    QuantFormat,
    QuantizedTensor,
    amax_scale,
    calibrate_scale,
    dequantize,
    format_of,
    quantize,
    quantize_with_scale,
)

__all__ = [
    "FORMATS",
    "QuantFormat",
    "QuantizedTensor",
    "amax_scale",
    "calibrate_scale",
    "dequantize",
    "format_of",
    "quantize",
    "quantize_with_scale",
    "opope_gemm_q8",
    "opope_gemm_q8_grouped",
    "q8_block_shape",
    "register_quant_backends",
    "PrecisionPolicy",
    "mlp_q8_policy",
    "preferred_q8_backend",
    "ROLES",
    "QuantKVCache",
    "quantize_kv",
    "quantize_kv_rows",
    "kv_bytes_per_slot",
    "DEFAULT_KV_MARGIN",
]
