"""Quantized GEMM backends, registered through ``repro.kernels.ops``.

Two execution paths, one numerics contract (int8 dynamic symmetric
quantization — per-row scales on A, per-output-channel scales on B — exact
int32 accumulation, scales and the optional C operand applied in fp32 at the
accumulator, single final cast):

* ``xla_q8``   — ``lax.dot_general`` on the int8 values with
  ``preferred_element_type=int32``; the portable reference, available
  everywhere.
* ``pallas_q8`` — the O-POPE kernel with int8 operand streams and an int32
  resident accumulator (:mod:`repro.quant.pallas_q8`): same outer-product
  dataflow, a quarter of the fp32 path's operand traffic. Degrades to
  ``pallas_q8_interpret`` (same body, CPU interpreter) and then ``xla_q8`` —
  never to a full-precision path, so a degraded quantized request keeps
  quantized numerics.

Because int32 accumulation of int8 products is exact (no reassociation
error), ``xla_q8`` and ``pallas_q8`` agree bit-for-bit on the accumulator and
to fp32 rounding on the output — asserted in tests.

Both register ``grad_backend="xla"``: a backward pass through a quantized
matmul runs full-precision fp32-accumulated GEMMs on the saved (unquantized)
residuals. That is the paper's "training still requires higher-precision
floating-point" rule, enforced structurally — no caller can accidentally
backpropagate through int8.

Each backend also registers its **grouped member** (``[G,M,K] @ [G,K,N]``,
served by :func:`repro.kernels.ops.grouped_matmul`) with **per-group scales**
(A per-(group, row), B per-(group, column)): quantization error inside group
``g`` is bounded by group ``g``'s own amax, so one outlier expert in an MoE
stack cannot crush every other expert's resolution.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.lax as lax
import jax.numpy as jnp

from repro.kernels import ops
from repro.obs import audit

from .pallas_q8 import opope_gemm_q8, opope_gemm_q8_grouped, q8_block_shape
from .quantize import quantize

__all__ = ["register_quant_backends"]


def _quantize_operands(a: jax.Array, b: jax.Array):
    """Dynamic per-row (A) / per-output-channel (B) int8 quantization.

    Row/column granularity is the finest that still factorizes out of the
    GEMM: ``C[m,n] = sa[m] * sb[n] * sum_k qa[m,k] * qb[k,n]``.
    """
    aq = quantize(a, "int8", axis=0)  # scale [M, 1]
    bq = quantize(b, "int8", axis=1)  # scale [1, N]
    return aq, bq


def _a_values_scale(a):
    """The (int8 values, [M, 1] fp32 scale) of the A operand.

    A **pre-quantized** activation (anything with ``.q``/``.scale`` — the
    product of an upstream ``requant_int8`` epilogue) skips the dynamic
    quantization pass entirely: its values are consumed as-is and its
    per-tensor (or per-row) scale is broadcast to the kernel's [M, 1]
    layout. This is the "no round trip" half of the re-quant lane — layer
    N's writeback already put A on the int8 grid.
    """
    if hasattr(a, "q") and hasattr(a, "scale"):
        q = a.q
        s = jnp.asarray(a.scale, jnp.float32)
        s = s.reshape(-1, 1) if s.size == q.shape[0] else s.reshape(1, 1)
        return q, jnp.broadcast_to(s, (q.shape[0], 1))
    aq = quantize(a, "int8", axis=0)
    return aq.q, aq.scale


def _quantize_grouped_operands(a: jax.Array, b: jax.Array):
    """Per-group dynamic quantization of a grouped operand pair.

    A [G, M, K] gets per-(group, row) scales [G, M, 1]; B [G, K, N] gets
    per-(group, column) scales [G, 1, N] — the grouped generalization of the
    2-D granularity: within each group the scale outer product still
    factorizes out of the GEMM, and no amax is shared across groups (one
    outlier expert must not crush every other expert's resolution).
    """
    aq = quantize(a, "int8", axis=(0, 1))  # scale [G, M, 1]
    bq = quantize(b, "int8", axis=(0, 2))  # scale [G, 1, N]
    return aq, bq


def _xla_q8(a, b, c, out_dtype):
    a_vals, a_scale = _a_values_scale(a)
    bq = quantize(b, "int8", axis=1)  # scale [1, N]
    acc = lax.dot_general(
        a_vals, bq.q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * (a_scale * bq.scale)
    if c is not None:
        out = out + c.astype(jnp.float32)  # [M, N] tile or [N] bias row
    return out.astype(out_dtype)


def _xla_q8_grouped(a, b, c, out_dtype):
    aq, bq = _quantize_grouped_operands(a, b)
    acc = lax.dot_general(
        aq.q, bq.q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * (aq.scale * bq.scale)
    if c is not None:
        cf = c.astype(jnp.float32)
        out = out + (cf[:, None, :] if c.ndim == 2 else cf)
    return out.astype(out_dtype)


def _pallas_q8_fn(interpret: bool):
    name = "pallas_q8_interpret" if interpret else "pallas_q8"

    def run(a, b, c, out_dtype, ep_steps=(), ep_ops=()):
        a_vals, a_scale = _a_values_scale(a)
        bq = quantize(b, "int8", axis=1)
        # Through the registry's shared resolution path (tuning table first,
        # q8_block_shape heuristic second), keyed at itemsize=1 — the width
        # of the streamed panels, not the caller-visible dtype.
        bm, bn, bk = ops._tile_for(
            a_vals.shape[0], a_vals.shape[1], b.shape[1], 1,
            family="dense", backend=name,
        )
        return opope_gemm_q8(
            a_vals, a_scale, bq.q, bq.scale, c,
            block_m=bm, block_n=bn, block_k=bk,
            out_dtype=out_dtype, interpret=interpret,
            epilogue=ep_steps, epilogue_operands=ep_ops,
        )

    return run


def _pallas_q8_grouped_fn(interpret: bool):
    name = "pallas_q8_interpret" if interpret else "pallas_q8"

    def run(a, b, c, out_dtype, ep_steps=(), ep_ops=()):
        aq, bq = _quantize_grouped_operands(a, b)
        bm, bn, bk = ops._tile_for(
            a.shape[1], a.shape[2], b.shape[2], 1,
            family="grouped", groups=a.shape[0], backend=name,
        )
        return opope_gemm_q8_grouped(
            aq.q, aq.scale, bq.q, bq.scale, c,
            block_m=bm, block_n=bn, block_k=bk,
            out_dtype=out_dtype, interpret=interpret,
            epilogue=ep_steps, epilogue_operands=ep_ops,
        )

    return run


@functools.lru_cache(maxsize=None)
def _pallas_q8_compiles() -> bool:
    """Probe once whether the compiled int8 Pallas path lowers here."""
    try:
        if jax.devices()[0].platform != "tpu":
            return False
        a = jnp.zeros((32, 128), jnp.int8)
        sa = jnp.ones((32, 1), jnp.float32)
        b = jnp.zeros((128, 128), jnp.int8)
        sb = jnp.ones((1, 128), jnp.float32)
        opope_gemm_q8.lower(a, sa, b, sb, interpret=False).compile()
        return True
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def _pallas_q8_grouped_compiles() -> bool:
    """Probe the compiled grouped int8 grid separately (per-member
    availability): a grouped-only lowering failure degrades grouped_matmul
    along the q8 chain without demoting the 2-D pallas_q8 member."""
    try:
        if not _pallas_q8_compiles():
            return False
        ag = jnp.zeros((2, 32, 128), jnp.int8)
        sag = jnp.ones((2, 32, 1), jnp.float32)
        bg = jnp.zeros((2, 128, 128), jnp.int8)
        sbg = jnp.ones((2, 1, 128), jnp.float32)
        opope_gemm_q8_grouped.lower(ag, sag, bg, sbg, interpret=False).compile()
        return True
    except Exception:
        return False


def register_quant_backends() -> None:
    """Register (or re-register) the quantized backends. Idempotent.

    Every member declares ``family="q8"`` and a fallback chain that stays
    inside the family (``xla_q8`` — the always-available terminal — falls
    back to the interpreter q8 kernel, never to a full-precision path), plus
    a grouped GEMM member with per-group scales.
    """
    ops.register_backend(
        "xla_q8", _xla_q8,
        fallback=("pallas_q8_interpret",),
        grad_backend="xla",
        grouped=_xla_q8_grouped,
        family="q8",
    )
    ops.register_backend(
        "pallas_q8",
        _pallas_q8_fn(interpret=False),
        available=_pallas_q8_compiles,
        fallback=("pallas_q8_interpret", "xla_q8"),
        grad_backend="xla",
        grouped=_pallas_q8_grouped_fn(interpret=False),
        grouped_available=_pallas_q8_grouped_compiles,
        family="q8",
        tile_fn=q8_block_shape,
        epilogue_fused=True,
    )
    ops.register_backend(
        "pallas_q8_interpret",
        _pallas_q8_fn(interpret=True),
        fallback=("xla_q8",),
        grad_backend="xla",
        grouped=_pallas_q8_grouped_fn(interpret=True),
        family="q8",
        tile_fn=q8_block_shape,
        epilogue_fused=True,
    )
    # Shadow-audit drift policy for the family (obs.audit, REPRO_AUDIT=N):
    # per-row/per-channel int8 keeps max error within a few quantization
    # steps of the reference's max magnitude — well under 5% on any real
    # activation/weight distribution. Breaching it means a wrong scale, an
    # overflow, or a kernel bug, not ordinary quantization noise.
    audit.set_policy("q8", rel_err=0.05)


register_quant_backends()
