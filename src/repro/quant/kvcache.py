"""Quantized K/V lanes for the slot-pooled serving cache.

Serving memory is dominated by the decode KV cache: per slot the pool holds
``2 * max_len * n_kv * head_dim`` elements per attention layer. Narrowing
those lanes to one byte drops per-slot cache memory ~4x (vs fp32 lanes), so
an engine with the same HBM budget admits proportionally more concurrent
requests — the data-movement side of the paper's quantization trade-off,
applied to the serving state instead of the GEMM operands.

Layout (mirrors :class:`repro.models.attention.KVCache`, fused head dim):

* ``k`` / ``v`` — narrow values ``[..., S_max, n_kv * head_dim]`` (int8 or an
  fp8 storage dtype),
* ``k_scale`` / ``v_scale`` — fp32 **per-slot, per-head** scales
  ``[..., n_kv]``: calibrated once per request at *join* time from its
  prefilled K/V (per-head amax over the prompt span, with headroom margin
  for later decode tokens), then fixed for the request's lifetime so every
  append and every read dequantizes consistently,
* ``length`` — the fill counter, exactly as in ``KVCache``.

Dequantization happens **inside the fused decode step** (the attention layer
widens the narrow lanes right before the score/PV einsums — see
``repro.models.attention``); nothing outside the step ever sees wide K/V.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from .quantize import QuantFormat, format_of

__all__ = [
    "QuantKVCache",
    "quantize_kv",
    "quantize_kv_rows",
    "adopt_scale_floor",
    "kv_bytes_per_slot",
    "DEFAULT_KV_MARGIN",
]

# Join-time calibration headroom: decode-time K/V can exceed the prompt-span
# amax; 1.25x costs ~a third of a bit of resolution and makes clipping rare.
DEFAULT_KV_MARGIN = 1.25

_TINY = 1e-12


class QuantKVCache(NamedTuple):
    """Narrow-lane decode cache with per-slot, per-head fp32 scales.

    Structurally a drop-in for ``KVCache`` in every cache pytree (same
    ``length`` contract, same leading axes), so the layer-scan, the slot
    scatter, and the donation machinery treat it identically.
    """

    k: jax.Array  # [..., S_max, n_kv * head_dim], narrow dtype
    v: jax.Array
    k_scale: jax.Array  # [..., n_kv] fp32
    v_scale: jax.Array
    length: jax.Array  # int32: [] lockstep, or [B] per-slot

    @property
    def n_kv(self) -> int:
        return self.k_scale.shape[-1]

    @property
    def fmt(self) -> QuantFormat:
        return format_of(self.k.dtype)

    @staticmethod
    def zeros(
        batch: int, max_len: int, n_kv: int, head_dim: int,
        fmt: Union[str, QuantFormat] = "int8",
    ) -> "QuantKVCache":
        f = format_of(fmt)
        shape = (batch, max_len, n_kv * head_dim)
        return QuantKVCache(
            k=jnp.zeros(shape, f.dtype),
            v=jnp.zeros(shape, f.dtype),
            k_scale=jnp.ones((batch, n_kv), jnp.float32),
            v_scale=jnp.ones((batch, n_kv), jnp.float32),
            length=jnp.zeros((), jnp.int32),
        )

    # -- dequant (inside the fused decode step) -----------------------------

    def dequant_k(self, dtype=jnp.float32) -> jax.Array:
        return _dequant(self.k, self.k_scale, dtype)

    def dequant_v(self, dtype=jnp.float32) -> jax.Array:
        return _dequant(self.v, self.v_scale, dtype)

    # -- append (decode step writes through the fixed slot scales) ----------

    def quantize_rows(self, kf: jax.Array, vf: jax.Array):
        """Quantize one appended token per row: kf/vf [..., n_kv * head_dim]
        with this cache's per-slot scales. Values beyond the calibrated
        range clip (the margin makes that rare)."""
        return (
            _quant_rows(kf, self.k_scale, self.fmt),
            _quant_rows(vf, self.v_scale, self.fmt),
        )


def _dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    *lead, s, f = q.shape
    n_kv = scale.shape[-1]
    x = q.reshape(*lead, s, n_kv, f // n_kv).astype(jnp.float32)
    x = x * scale[..., None, :, None]
    return x.reshape(*lead, s, f).astype(dtype)


def _quant_rows(x: jax.Array, scale: jax.Array, fmt: QuantFormat) -> jax.Array:
    *lead, f = x.shape
    n_kv = scale.shape[-1]
    xs = x.reshape(*lead, n_kv, f // n_kv).astype(jnp.float32) / scale[..., :, None]
    return fmt.cast(xs).reshape(*lead, f)


def quantize_kv_rows(
    k: jax.Array,
    v: jax.Array,
    n_kv: int,
    *,
    fmt: Union[str, QuantFormat] = "int8",
    margin: float = DEFAULT_KV_MARGIN,
    k_scale_floor: Optional[jax.Array] = None,
    v_scale_floor: Optional[jax.Array] = None,
):
    """Calibrate per-(slot, head) scales from full-precision K/V rows and
    quantize them. k/v: [..., S, n_kv * head_dim] (the prefilled prompt
    span); amax reduces over positions and head-dim, keeping heads.

    ``k_scale_floor`` / ``v_scale_floor`` ([..., n_kv], broadcastable) lower-
    bound the calibrated scales — the prefix-cache **scale adoption** hook: a
    quantized cached prefix was originally quantized at some scale ``s0``;
    when its (dequantized) span is re-quantized into a fresh slot, the floor
    ``s0`` is adopted outright while the row's amax fits its representable
    range (``amax <= qmax * s0`` — the floor already carries the original
    calibration margin), making the round trip ``cast(q * s0 / s0) == q``
    **bitwise-exact** whenever the prefix dominates the prompt; a suffix
    whose values exceed that range recalibrates with margin, still never
    *finer* than the floor — re-quantizing a coarse prefix at a finer scale
    would fabricate precision that the narrow lanes never carried.

    Returns ``(k_q, v_q, k_scale, v_scale)`` with scales shaped [..., n_kv].
    """
    f = format_of(fmt)

    def one(x, floor):
        *lead, s, fused = x.shape
        xh = x.reshape(*lead, s, n_kv, fused // n_kv).astype(jnp.float32)
        amax = jnp.max(jnp.abs(xh), axis=(-3, -1))  # [..., n_kv]
        scale = jnp.maximum(amax * margin, _TINY) / f.qmax
        if floor is not None:
            fl = floor.astype(jnp.float32)
            # The floor already carries its own calibration margin (it was
            # amax * margin / qmax at insert time), so adopt it outright
            # whenever the values fit its representable range
            # (amax <= qmax * floor). Re-applying ``margin`` to a
            # round-tripped amax would nudge the scale one rounding step
            # past the floor (round(qmax/margin) * margin > qmax) and break
            # the bitwise ``cast(q * s0 / s) == q`` adoption guarantee.
            scale = jnp.where(
                amax <= f.qmax * fl, fl, jnp.maximum(scale, fl)
            )
        q = f.cast(xh / scale[..., None, :, None]).reshape(*lead, s, fused)
        return q, scale

    k_q, k_scale = one(k, k_scale_floor)
    v_q, v_scale = one(v, v_scale_floor)
    return k_q, v_q, k_scale, v_scale


def adopt_scale_floor(prefix_scales: jax.Array, n_rows: int) -> jax.Array:
    """Broadcast a cached prefix's per-(period, head) scales [P, n_kv] to the
    per-row floor layout [P, n_rows, n_kv] that :func:`quantize_kv_rows`
    expects for a stacked [P, rows, S, fused] join batch. Rows that attach
    this prefix adopt its scales as a lower bound (see ``quantize_kv_rows``);
    rows without a prefix pass 0 — a no-op floor."""
    return jnp.broadcast_to(
        prefix_scales.astype(jnp.float32)[:, None, :],
        (prefix_scales.shape[0], n_rows, prefix_scales.shape[-1]),
    )


def quantize_kv(
    cache,
    n_kv: Optional[int] = None,
    *,
    fmt: Union[str, QuantFormat] = "int8",
    margin: float = DEFAULT_KV_MARGIN,
) -> QuantKVCache:
    """Quantize a full-precision KVCache-like (``.k``/``.v``/``.length``)
    into a :class:`QuantKVCache` with freshly calibrated per-row, per-head
    scales. ``n_kv`` defaults to treating the whole fused head dim as one
    head (a single per-row scale)."""
    n_kv = n_kv if n_kv is not None else 1
    k_q, v_q, k_scale, v_scale = quantize_kv_rows(
        cache.k, cache.v, n_kv, fmt=fmt, margin=margin
    )
    return QuantKVCache(
        k=k_q, v=v_q, k_scale=k_scale, v_scale=v_scale, length=cache.length
    )


def kv_bytes_per_slot(caches) -> float:
    """Mean K/V-cache bytes held per slot across a pool cache pytree.

    Counts k/v value lanes plus scale sidecars of every (Quant)KVCache entry
    (stacked [n_periods, n_slots, ...]); recurrent states and placeholders
    are excluded — the comparison is about the attention cache lanes. Pools
    with no attention layers (pure-SSM families) report 0.0.
    """
    total = 0.0
    n_slots = None
    for c in caches:
        if isinstance(c, QuantKVCache):
            arrs = (c.k, c.v, c.k_scale, c.v_scale)
        elif hasattr(c, "k") and hasattr(c, "v"):
            arrs = (c.k, c.v)
        else:
            continue
        n_slots = c.k.shape[1]
        total += sum(a.size * jnp.dtype(a.dtype).itemsize for a in arrs)
    if not n_slots:
        return 0.0
    return total / n_slots
