"""O-POPE GEMM, int8 operands: same dataflow, quarter the operand traffic.

This is the quantized variant of :func:`repro.kernels.opope_gemm.opope_gemm`
(the OpenGeMM observation — arXiv:2411.09543 — that the paper's utilization
story replays at int8). The dataflow is identical:

* the grid is ``(m, n, k)`` with ``k`` innermost/sequential,
* the accumulator tile stays resident in VMEM scratch across the K loop —
  but as **int32** (the exact sum of int8 products; integer accumulation is
  associative, so this backend is bit-deterministic across tilings),
* A/B panels stream as **int8** — 1 byte/element where the fp path moves 2-4,
* dequantization happens only at the accumulator boundary: the per-row /
  per-column fp32 scales multiply the finished int32 tile at **writeback**,
  and the optional C operand (full tile or [N] bias row) is added there in
  fp32 — the same accumulator preload/writeback points the paper fuses its
  epilogue into, so no dequantized copy of A or B ever exists.

Block shapes are rounded to the int8 sublane tile (32) so the compiled path
lines up with the MXU's int8 layout; the interpreter path (CPU tests) runs
the same body.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import epilogue as _ep
from repro.kernels.opope_grouped import _pad3

__all__ = ["opope_gemm_q8", "opope_gemm_q8_grouped", "q8_block_shape"]


def _q8_kernel(aq_ref, as_ref, bq_ref, bs_ref, o_ref, acc_ref, *, k_steps: int):
    """One (m, n, k) grid step: rank-block_k int8 panel update, int32 resident."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[...], bq_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        # Dequant at the accumulator writeback point: one fp32 multiply by
        # the rank-1 scale outer product, single final cast.
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[...] * bs_ref[...])
        o_ref[...] = scaled.astype(o_ref.dtype)


def _q8_preload_kernel(
    aq_ref, as_ref, bq_ref, bs_ref, c_ref, o_ref, acc_ref, *, k_steps: int
):
    """As :func:`_q8_kernel` with the C operand fused at the same boundary.

    The integer accumulator cannot hold the fp32 C tile during the K loop, so
    the preload moves to the writeback: ``O = deq(acc) + C`` — numerically
    identical (C enters the sum linearly) and still zero extra HBM round-trip.
    C is a full (bm, bn) tile or a (1, bn) bias row broadcast down M.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[...], bq_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[...] * bs_ref[...])
        scaled = scaled + jnp.broadcast_to(
            c_ref[...].astype(jnp.float32), scaled.shape
        )
        o_ref[...] = scaled.astype(o_ref.dtype)


def _q8_epilogue_kernel(*refs, k_steps: int, steps, has_c: bool):
    """Epilogue-fused q8 grid step: dequant the int32 resident tile, add the
    C operand if present, run the op pipeline, single cast — all at the one
    writeback, so the quantized path's post-ops cost zero extra HBM traffic
    exactly like the fp kernels'.

    ``refs`` order: aq, as, bq, bs, (c if ``has_c``), one ref per
    operand-taking epilogue step, o, acc scratch.
    """
    aq_ref, as_ref, bq_ref, bs_ref = refs[0], refs[1], refs[2], refs[3]
    idx = 5 if has_c else 4
    c_ref = refs[4] if has_c else None
    ep_refs = refs[idx:-2]
    o_ref, acc_ref = refs[-2], refs[-1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[...], bq_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[...] * bs_ref[...])
        if c_ref is not None:
            scaled = scaled + jnp.broadcast_to(
                c_ref[...].astype(jnp.float32), scaled.shape
            )
        scaled = _ep.apply_epilogue(
            scaled, steps, tuple(r[...] for r in ep_refs)
        )
        o_ref[...] = scaled.astype(o_ref.dtype)


def q8_block_shape(m: int, k: int, n: int, elem_bytes: int = 1):
    """Block-shape **heuristic** for int8 operands: the fp selection at
    elem_bytes=1 with the M block rounded to the int8 sublane tile (32).

    This is the ``tile_fn`` the q8 backends register with the ops registry —
    pure (no memo, no table): the registry's ``_tile_for`` wraps it with the
    shared bounded LRU memo and consults the tuning table first, exactly like
    the fp backends.
    """
    from repro.kernels.opope_gemm import default_block_shape

    bm, bn, bk = default_block_shape(m, k, n, elem_bytes=elem_bytes)
    return _rup(bm, 32), bn, bk


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "out_dtype", "interpret", "epilogue",
    ),
)
def opope_gemm_q8(
    a_q: jax.Array,
    a_scale: jax.Array,
    b_q: jax.Array,
    b_scale: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    epilogue=(),
    epilogue_operands=(),
) -> jax.Array:
    """``O = (a_q @ b_q) * (a_scale * b_scale) (+ C)`` on the O-POPE grid.

    a_q: [M, K] int8 with per-row scales a_scale [M, 1] (fp32);
    b_q: [K, N] int8 with per-column scales b_scale [1, N] (fp32).
    ``epilogue``/``epilogue_operands`` fuse a registered post-op pipeline
    after the dequant (and C add) on the resident tile — see
    :func:`repro.kernels.opope_gemm.opope_gemm` for the operand conventions.
    ``interpret=True`` runs the body in the Pallas interpreter (CPU tests).
    """
    if a_q.ndim != 2 or b_q.ndim != 2 or a_q.shape[1] != b_q.shape[0]:
        raise ValueError(f"bad GEMM shapes {a_q.shape} @ {b_q.shape}")
    m, k = a_q.shape
    _, n = b_q.shape
    if a_scale.shape != (m, 1):
        raise ValueError(f"a_scale shape {a_scale.shape} != {(m, 1)}")
    if b_scale.shape != (1, n):
        raise ValueError(f"b_scale shape {b_scale.shape} != {(1, n)}")
    out_dtype = jnp.dtype(out_dtype or jnp.float32)

    # M blocks stay 32-aligned (int8 sublane tile) whatever the caller asked.
    bm = _rup(min(block_m, _rup(m, 32)), 32)
    bn = min(block_n, _rup(n, 128))
    bk = min(block_k, _rup(k, 128))
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    a_p = _pad2(a_q, mp, kp)
    b_p = _pad2(b_q, kp, np_)
    # Pad scales with ones: padded rows/cols contribute zero products, and a
    # nonzero pad keeps the writeback multiply well-defined.
    as_p = _pad2(a_scale.astype(jnp.float32), mp, 1, value=1.0)
    bs_p = _pad2(b_scale.astype(jnp.float32), 1, np_, value=1.0)
    k_steps = kp // bk

    grid = (mp // bm, np_ // bn, k_steps)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
    ]
    operands = [a_p, as_p, b_p, bs_p]
    if c is not None:
        if c.ndim == 1:
            if c.shape != (n,):
                raise ValueError(f"C preload shape {c.shape} != {(n,)} or {(m, n)}")
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
            operands.append(_pad2(c[None, :].astype(jnp.float32), 1, np_))
        else:
            if c.shape != (m, n):
                raise ValueError(f"C preload shape {c.shape} != {(m, n)}")
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
            operands.append(_pad2(c.astype(jnp.float32), mp, np_))
        kernel = functools.partial(_q8_preload_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(_q8_kernel, k_steps=k_steps)

    if epilogue:
        # Same operand streaming as the fp kernel's epilogue path (zero-pad
        # is safe: pad regions are sliced off below).
        it = iter(epilogue_operands)
        for name in epilogue:
            kind = _ep.op_kind(name)
            if kind == "none":
                continue
            x = next(it)
            if kind == "scalar":
                in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
                operands.append(x.reshape(1, 1))
            elif kind == "row":
                in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
                operands.append(_pad2(x.reshape(1, n), 1, np_))
            else:  # full
                in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
                operands.append(_pad2(x.reshape(m, n), mp, np_))
        kernel = functools.partial(
            _q8_epilogue_kernel,
            k_steps=k_steps,
            steps=epilogue,
            has_c=c is not None,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def _q8_grouped_kernel(
    aq_ref, as_ref, bq_ref, bs_ref, o_ref, acc_ref, *, k_steps: int
):
    """One (g, m, n, k) grid step: int8 panel update of group g's int32 tile.

    Scales are per-group (rank-1 outer product within each group) — the
    dequant multiply at writeback uses only group g's rows/columns, so no
    amax is ever shared across a group boundary.
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[0], bq_ref[0], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[0] * bs_ref[0])
        o_ref[...] = scaled.astype(o_ref.dtype)[None]


def _q8_grouped_preload_kernel(
    aq_ref, as_ref, bq_ref, bs_ref, c_ref, o_ref, acc_ref, *, k_steps: int
):
    """As :func:`_q8_grouped_kernel` with group g's C operand fused at the
    writeback boundary (full (1, bm, bn) tile or (1, 1, bn) bias row)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[0], bq_ref[0], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[0] * bs_ref[0])
        scaled = scaled + jnp.broadcast_to(
            c_ref[0].astype(jnp.float32), scaled.shape
        )
        o_ref[...] = scaled.astype(o_ref.dtype)[None]


def _q8_grouped_epilogue_kernel(*refs, k_steps: int, steps, has_c: bool):
    """Grouped analogue of :func:`_q8_epilogue_kernel`: dequant group g's
    int32 tile, add its C operand if present, run the op pipeline, single
    cast — all at the one writeback. Epilogue operand blocks carry a leading
    group dim, dropped with ``ref[0]`` before broadcasting."""
    aq_ref, as_ref, bq_ref, bs_ref = refs[0], refs[1], refs[2], refs[3]
    idx = 5 if has_c else 4
    c_ref = refs[4] if has_c else None
    ep_refs = refs[idx:-2]
    o_ref, acc_ref = refs[-2], refs[-1]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        aq_ref[0], bq_ref[0], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _writeback():
        scaled = acc_ref[...].astype(jnp.float32) * (as_ref[0] * bs_ref[0])
        if c_ref is not None:
            scaled = scaled + jnp.broadcast_to(
                c_ref[0].astype(jnp.float32), scaled.shape
            )
        scaled = _ep.apply_epilogue(
            scaled, steps, tuple(r[0] for r in ep_refs)
        )
        o_ref[...] = scaled.astype(o_ref.dtype)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "out_dtype", "interpret", "epilogue",
    ),
)
def opope_gemm_q8_grouped(
    a_q: jax.Array,
    a_scale: jax.Array,
    b_q: jax.Array,
    b_scale: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    epilogue=(),
    epilogue_operands=(),
) -> jax.Array:
    """``O[g] = (a_q[g] @ b_q[g]) * (a_scale[g] * b_scale[g]) (+ C[g])``.

    a_q: [G, M, K] int8 with per-(group, row) scales a_scale [G, M, 1] (fp32);
    b_q: [G, K, N] int8 with per-(group, column) scales b_scale [G, 1, N].
    ``c`` is ``None``, a full ``[G, M, N]`` operand, or a ``[G, N]``
    per-group bias row. The grid is ``(G, m, n, k)`` with ``k`` innermost —
    the grouped analogue of :func:`opope_gemm_q8` with an int32 resident
    accumulator per (g, m, n) tile.
    """
    if a_q.ndim != 3 or b_q.ndim != 3 or a_q.shape[0] != b_q.shape[0] \
            or a_q.shape[2] != b_q.shape[1]:
        raise ValueError(f"bad grouped GEMM shapes {a_q.shape} @ {b_q.shape}")
    g, m, k = a_q.shape
    _, _, n = b_q.shape
    if a_scale.shape != (g, m, 1):
        raise ValueError(f"a_scale shape {a_scale.shape} != {(g, m, 1)}")
    if b_scale.shape != (g, 1, n):
        raise ValueError(f"b_scale shape {b_scale.shape} != {(g, 1, n)}")
    out_dtype = jnp.dtype(out_dtype or jnp.float32)

    bm = _rup(min(block_m, _rup(m, 32)), 32)
    bn = min(block_n, _rup(n, 128))
    bk = min(block_k, _rup(k, 128))
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    a_p = _pad3(a_q, g, mp, kp)
    b_p = _pad3(b_q, g, kp, np_)
    as_p = _pad3(a_scale.astype(jnp.float32), g, mp, 1, value=1.0)
    bs_p = _pad3(b_scale.astype(jnp.float32), g, 1, np_, value=1.0)
    k_steps = kp // bk

    grid = (g, mp // bm, np_ // bn, k_steps)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
        pl.BlockSpec((1, bm, 1), lambda gg, i, j, kk: (gg, i, 0)),
        pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j)),
    ]
    operands = [a_p, as_p, b_p, bs_p]
    if c is not None:
        if c.ndim == 2:
            if c.shape != (g, n):
                raise ValueError(
                    f"C preload shape {c.shape} != {(g, n)} or {(g, m, n)}"
                )
            in_specs.append(
                pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j))
            )
            operands.append(_pad3(c[:, None, :].astype(jnp.float32), g, 1, np_))
        else:
            if c.shape != (g, m, n):
                raise ValueError(
                    f"C preload shape {c.shape} != {(g, n)} or {(g, m, n)}"
                )
            in_specs.append(
                pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j))
            )
            operands.append(_pad3(c.astype(jnp.float32), g, mp, np_))
        kernel = functools.partial(_q8_grouped_preload_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(_q8_grouped_kernel, k_steps=k_steps)

    if epilogue:
        it = iter(epilogue_operands)
        for name in epilogue:
            kind = _ep.op_kind(name)
            if kind == "none":
                continue
            x = next(it)
            if kind == "scalar":
                in_specs.append(
                    pl.BlockSpec((1, 1, 1), lambda gg, i, j, kk: (0, 0, 0))
                )
                operands.append(x.reshape(1, 1, 1))
            elif kind == "row":
                in_specs.append(
                    pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j))
                )
                operands.append(_pad3(x.reshape(g, 1, n), g, 1, np_))
            else:  # full
                in_specs.append(
                    pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j))
                )
                operands.append(_pad3(x.reshape(g, m, n), g, mp, np_))
        kernel = functools.partial(
            _q8_grouped_epilogue_kernel,
            k_steps=k_steps,
            steps=epilogue,
            has_c=c is not None,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :m, :n]


def _rup(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def _pad2(x: jax.Array, d0: int, d1: int, value=0) -> jax.Array:
    if x.shape == (d0, d1):
        return x
    return jnp.pad(
        x, ((0, d0 - x.shape[0]), (0, d1 - x.shape[1])), constant_values=value
    )
