"""Precision policies: which layer roles run on which matmul backend.

A :class:`PrecisionPolicy` is passed anywhere the models accept a
``backend=`` (it duck-types via ``backend_for``; see
``repro.models.layers.role_backend``). Each matmul site in the model stack
declares a *role* and the policy maps roles to registered backend names:

==============  ============================================================
role            matmul sites
==============  ============================================================
``attn_qkv``    attention Q/K/V projections
``attn_out``    attention output projection
``mlp``         dense MLP up/gate/down projections
``moe``         all MoE expert compute: the routed per-expert SwiGLU (three
                grouped GEMMs through ``ops.grouped_matmul`` — per-group
                scales when quantized) and the shared-expert MLP
``router``      MoE router logits (routing decisions are accuracy-critical)
``mixer``       mamba / xLSTM in/out projections
==============  ============================================================

Unlisted roles fall through to ``default`` (``None`` = the process default
backend, i.e. full precision). Logits, norms and softmaxes never route
through the registry and always compute in fp32 — so "attention/logits stay
high-precision, MLP linears go q8" is::

    PrecisionPolicy(rules={"mlp": "xla_q8", "moe": "xla_q8"})

Gradients are not a role: every quantized backend registers
``grad_backend="xla"`` (see :mod:`repro.quant.backends`), so the backward
pass of ANY policy runs full-precision fp32-accumulated GEMMs — the paper's
"accuracy-sensitive tasks such as training still require higher-precision
floating-point formats", enforced below the policy layer where it cannot be
misconfigured.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

__all__ = ["PrecisionPolicy", "preferred_q8_backend", "mlp_q8_policy", "ROLES"]

ROLES = ("attn_qkv", "attn_out", "mlp", "moe", "router", "mixer")


def preferred_q8_backend() -> str:
    """The best available quantized GEMM backend on this platform: the
    compiled Pallas q8 kernel where it lowers, else the XLA int8 path (never
    the interpreter — a model-wide policy must not fall into the Python
    executor)."""
    from repro.kernels import ops

    b = ops._REGISTRY.get("pallas_q8")
    if b is not None and ops._probe_ok(b):
        return "pallas_q8"
    return "xla_q8"


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Role -> backend mapping. ``None`` means the process default backend.

    The special backend value ``"q8"`` resolves to
    :func:`preferred_q8_backend` at call time, so one policy object serves
    TPU (compiled kernel) and CPU (XLA int8) hosts.
    """

    rules: Mapping[str, Optional[str]] = dataclasses.field(default_factory=dict)
    default: Optional[str] = None
    name: str = "policy"
    # Role -> calibrated per-tensor scale for a ``requant_int8`` output
    # epilogue: layer N's GEMM writes its result already on the int8 grid of
    # that scale, so layer N+1's quantized GEMM consumes it with no
    # dequantize/re-quantize round trip (and no second amax pass). Roles
    # without an entry write full-precision outputs as before. Scales come
    # from calibration (``quantize.calibrate_scale``) — serving-only, like
    # the pre-quantized-A lane it feeds.
    requant: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        unknown = set(self.rules) - set(ROLES)
        if unknown:
            raise ValueError(
                f"policy {self.name!r}: unknown roles {sorted(unknown)}; "
                f"known: {list(ROLES)}"
            )
        unknown_rq = set(self.requant) - set(ROLES)
        if unknown_rq:
            raise ValueError(
                f"policy {self.name!r}: unknown requant roles "
                f"{sorted(unknown_rq)}; known: {list(ROLES)}"
            )

    def backend_for(self, role: str) -> Optional[str]:
        backend = self.rules.get(role, self.default)
        if backend == "q8":
            backend = preferred_q8_backend()
        return backend

    def requant_for(self, role: str) -> Optional[float]:
        """The calibrated re-quant scale a ``role``'s GEMM output should be
        written at (a ``requant_int8`` epilogue step), or None to write
        full-precision."""
        return self.requant.get(role)

    def describe(self) -> Dict[str, str]:
        """role -> resolved backend table (for reports and benchmarks)."""
        return {
            role: (self.backend_for(role) or "<default>") for role in ROLES
        }


def mlp_q8_policy(
    *, moe: bool = True, requant_scale: Optional[float] = None
) -> PrecisionPolicy:
    """The paper's serving-side split: MLP GEMMs (and, with ``moe=True``, the
    routed expert FFNs plus the shared-expert MLP — the whole ``moe`` role)
    quantize; attention / router / mixers / logits stay full-precision,
    gradients are fp32 by registry rule. ``requant_scale`` (a calibrated
    per-tensor scale) additionally makes the MLP role write its outputs
    through a ``requant_int8`` epilogue for the next quantized consumer."""
    rules: Dict[str, Optional[str]] = {"mlp": "q8"}
    if moe:
        rules["moe"] = "q8"
    requant: Dict[str, float] = (
        {"mlp": float(requant_scale)} if requant_scale is not None else {}
    )
    return PrecisionPolicy(rules=rules, requant=requant, name="mlp-q8")
