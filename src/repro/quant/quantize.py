"""Quantizers: int8 and emulated fp8 (e4m3 / e5m2), per-tensor or per-channel.

The paper's opening trade-off — quantization cuts compute *and data
movement* cost, while accuracy-sensitive work stays in floating point — needs
a software embodiment of "narrow format + scale". This module provides it:

* **Formats** — ``int8`` (symmetric, qmax 127), ``fp8_e4m3`` (max 448) and
  ``fp8_e5m2`` (max 57344). The fp8 formats are *emulated*: values are stored
  in JAX's native ``float8_*`` dtypes (1 byte — the storage/traffic win is
  real) but arithmetic on them happens after widening to fp32, mirroring the
  widening-MAC configurations of the paper's PE (fp8 multiply feeding a wider
  accumulator).
* **Scales** — fp32, per-tensor (scalar) or per-channel (``axis=`` keeps that
  axis; e.g. per-output-channel weights use ``axis=1`` on a ``[K, N]``
  matrix, giving a ``[1, N]`` scale that broadcasts in the dequant).
* **Calibration** — :func:`calibrate_scale` folds an amax estimate over
  sample batches so static (serving-time) quantization can fix its scales
  from representative data instead of per-call dynamics.

The format of a :class:`QuantizedTensor` is carried by its storage dtype
(``q.dtype``), keeping the pytree leaves pure arrays — a QuantizedTensor
jits, scans, and donates like any other cache state.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, NamedTuple, Optional, Tuple, Union

Axes = Union[int, Tuple[int, ...], None]

import jax
import jax.numpy as jnp

__all__ = [
    "QuantFormat",
    "FORMATS",
    "QuantizedTensor",
    "format_of",
    "quantize",
    "quantize_with_scale",
    "dequantize",
    "amax_scale",
    "calibrate_scale",
]

_TINY = 1e-12  # amax floor: all-zero tensors quantize to zeros, not NaNs


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """One storage format: name, storage dtype, and largest representable
    magnitude (the value an amax maps onto)."""

    name: str
    dtype: jnp.dtype
    qmax: float
    integer: bool

    def cast(self, x: jax.Array) -> jax.Array:
        if self.integer:
            return jnp.clip(jnp.round(x), -self.qmax, self.qmax).astype(self.dtype)
        return jnp.clip(x, -self.qmax, self.qmax).astype(self.dtype)


FORMATS = {
    "int8": QuantFormat("int8", jnp.dtype(jnp.int8), 127.0, True),
    "fp8_e4m3": QuantFormat(
        "fp8_e4m3", jnp.dtype(jnp.float8_e4m3fn), 448.0, False
    ),
    "fp8_e5m2": QuantFormat(
        "fp8_e5m2", jnp.dtype(jnp.float8_e5m2), 57344.0, False
    ),
}

_BY_DTYPE = {f.dtype: f for f in FORMATS.values()}


def format_of(fmt_or_dtype: Union[str, jnp.dtype, "QuantFormat"]) -> QuantFormat:
    """Resolve a format name, storage dtype, or QuantFormat to a QuantFormat."""
    if isinstance(fmt_or_dtype, QuantFormat):
        return fmt_or_dtype
    if isinstance(fmt_or_dtype, str) and fmt_or_dtype in FORMATS:
        return FORMATS[fmt_or_dtype]
    f = _BY_DTYPE.get(jnp.dtype(fmt_or_dtype))
    if f is None:
        raise ValueError(
            f"unknown quant format {fmt_or_dtype!r}; known: {sorted(FORMATS)}"
        )
    return f


class QuantizedTensor(NamedTuple):
    """Narrow values + fp32 scale. ``dequant = q.astype(f32) * scale``.

    ``scale`` is a scalar (per-tensor) or keepdims-shaped (per-channel) so it
    broadcasts against ``q`` without bookkeeping. The format is recoverable
    from ``q.dtype`` (see :func:`format_of`), so the pytree holds only arrays.
    """

    q: jax.Array
    scale: jax.Array

    @property
    def fmt(self) -> QuantFormat:
        return format_of(self.q.dtype)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype)

    @property
    def nbytes(self) -> int:
        return (
            self.q.size * jnp.dtype(self.q.dtype).itemsize
            + self.scale.size * jnp.dtype(self.scale.dtype).itemsize
        )


def _keep_axes(ndim: int, axis: Axes) -> Tuple[int, ...]:
    """Normalize ``axis`` (int or tuple of ints to KEEP) to reduce axes."""
    keep = {a % ndim for a in ((axis,) if isinstance(axis, int) else axis)}
    return tuple(i for i in range(ndim) if i not in keep)


def amax_scale(
    x: jax.Array, fmt: Union[str, QuantFormat] = "int8",
    axis: Axes = None,
) -> jax.Array:
    """Symmetric scale mapping the observed amax onto the format's qmax.

    ``axis=None`` gives a per-tensor scalar; an integer axis keeps that axis
    (per-channel), reducing over all others with keepdims so the scale
    broadcasts against ``x``. A tuple keeps several axes — grouped operands
    use ``axis=(0, 1)`` on an ``[G, M, K]`` stack for per-(group, row)
    scales, i.e. per-group quantization that never shares an amax across
    group boundaries.
    """
    f = format_of(fmt)
    xf = jnp.abs(x.astype(jnp.float32))
    if axis is None:
        amax = jnp.max(xf)
    else:
        amax = jnp.max(xf, axis=_keep_axes(x.ndim, axis), keepdims=True)
    return jnp.maximum(amax, _TINY) / f.qmax


def quantize_with_scale(
    x: jax.Array, scale: jax.Array, fmt: Union[str, QuantFormat] = "int8"
) -> QuantizedTensor:
    """Quantize with a fixed (e.g. calibrated) scale; out-of-range clips."""
    f = format_of(fmt)
    q = f.cast(x.astype(jnp.float32) / scale)
    return QuantizedTensor(q=q, scale=jnp.asarray(scale, jnp.float32))


def quantize(
    x: jax.Array,
    fmt: Union[str, QuantFormat] = "int8",
    *,
    axis: Axes = None,
) -> QuantizedTensor:
    """Dynamic symmetric quantization (scale from this tensor's own amax).

    ``axis`` is the axis (or tuple of axes) the scale KEEPS — see
    :func:`amax_scale`."""
    return quantize_with_scale(x, amax_scale(x, fmt, axis=axis), fmt)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return (qt.q.astype(jnp.float32) * qt.scale).astype(dtype)


def calibrate_scale(
    batches: Iterable[jax.Array],
    fmt: Union[str, QuantFormat] = "int8",
    *,
    axis: Axes = None,
    margin: float = 1.0,
) -> jax.Array:
    """Scale from the running amax over sample batches (static quantization).

    ``margin > 1`` leaves headroom for values the calibration set did not
    exhibit (later decode tokens, unseen activations) at the cost of one
    ``log2(margin)`` bit of resolution.
    """
    f = format_of(fmt)
    amax = None
    for x in batches:
        xf = jnp.abs(jnp.asarray(x).astype(jnp.float32))
        if axis is None:
            a = jnp.max(xf)
        else:
            a = jnp.max(xf, axis=_keep_axes(xf.ndim, axis), keepdims=True)
        amax = a if amax is None else jnp.maximum(amax, a)
    if amax is None:
        raise ValueError("calibrate_scale: no batches provided")
    return jnp.maximum(amax * margin, _TINY) / f.qmax
