"""Serving subsystem: static lockstep engine + continuous-batching engine.

* :class:`ServeEngine` — the simple path: one batch enters and exits
  together (lockstep prefill + decode). Also the audio/VLM entry point.
* :class:`ContinuousEngine` — the production path: a slot-pooled KV cache
  (:class:`SlotPool`), a FIFO bucketed-admission :class:`Scheduler`, and one
  fused masked decode step that requests join and leave mid-flight without
  recompiling.
"""
from .cache import PrefixCache, SlotPool, init_slot_caches, scatter_slots
from .continuous import ContinuousEngine, ServingReport
from .engine import ServeEngine, sample_token
from .scheduler import (
    Request,
    RequestState,
    Scheduler,
    bucket_length,
    gen_len_spread,
    poisson_trace,
    shared_prefix_trace,
)

__all__ = [
    "ServeEngine",
    "ContinuousEngine",
    "ServingReport",
    "SlotPool",
    "PrefixCache",
    "init_slot_caches",
    "scatter_slots",
    "Scheduler",
    "Request",
    "RequestState",
    "bucket_length",
    "gen_len_spread",
    "poisson_trace",
    "shared_prefix_trace",
    "sample_token",
]
