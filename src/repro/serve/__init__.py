"""Serving substrate: batched prefill/decode engine."""
from .engine import ServeEngine, sample_token
__all__ = ["ServeEngine", "sample_token"]
