"""Slot-pooled KV cache manager for continuous batching.

The pool owns one set of decode buffers sized ``[n_slots, max_len]``
(``models.api.init_state``) for the whole engine lifetime. Each serving
request leases a *slot* — one batch lane of every cache buffer — for exactly
as long as it is live:

* **join**: a freshly prefilled request's caches (sized to its prompt
  bucket) are scattered into its slot rows with one fused jit'd gather/
  scatter (:func:`scatter_slots`); nothing else in the pool moves.
* **decode**: every slot advances through ``models.api.decode_at`` with its
  own position — per-slot fill counters mean a retiring request never
  touches its neighbours.
* **release**: freeing a slot is pure host bookkeeping (the lane's stale
  K/V is dead weight masked off by the per-slot length mask until the next
  join overwrites it) — zero device work.

This is the serving analogue of the paper's output-stationary accumulator
management: state stays resident where it is used, and only the minimal
panel (one request's rows) streams in or out on a lifecycle event.

**Quantized K/V lanes** (``kv_format="int8"`` / an fp8 format name): the
pool's attention caches become :class:`repro.quant.QuantKVCache` — 1-byte
K/V values with fp32 per-slot, per-head scales. Scales are calibrated at
*join* time from each request's prefilled K/V (inside the jit'd scatter),
fixed for the request's lifetime, and the decode step dequantizes inside the
fused attention. Per-slot cache memory drops ~4x vs fp32 lanes, so the same
HBM budget admits proportionally more concurrent requests. Recurrent states
(mamba conv/ssm, xlstm) stay full-width — they are rewritten wholesale every
step, not appended-and-reread like KV history.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api
from repro.models.attention import KVCache
from repro.quant.kvcache import (
    DEFAULT_KV_MARGIN,
    QuantKVCache,
    quantize_kv_rows,
)

__all__ = [
    "SlotPool",
    "PrefixCache",
    "PrefixNode",
    "init_slot_caches",
    "scatter_slots",
]


def init_slot_caches(
    cfg: ArchConfig, n_slots: int, max_len: int, dtype,
    kv_format: Optional[str] = None,
):
    """Pool-shaped decode caches: per-slot fill counters from step zero.

    Like ``api.init_state`` but (a) every stacked ``KVCache`` carries an
    int32 ``[n_periods, n_slots]`` length vector instead of a scalar, and
    (b) cache-less pattern positions hold the zero-size placeholder array the
    layer-scan threads through — so the pytree structure (and therefore the
    compiled decode step) is identical on step 1 and step 10 000.

    ``kv_format`` (a :data:`repro.quant.FORMATS` name) narrows the attention
    K/V lanes to that storage format with per-slot, per-head scales.
    """
    caches = model_api.init_state(cfg, n_slots, max_len, dtype)
    lengths = jnp.zeros((cfg.n_periods, n_slots), jnp.int32)
    out = []
    for c in caches:
        if c is None:
            out.append(jnp.zeros((cfg.n_periods, 0), jnp.float32))
        elif isinstance(c, KVCache):
            if kv_format is not None:
                q = QuantKVCache.zeros(
                    n_slots, max_len, cfg.n_kv, cfg.head_dim_, fmt=kv_format
                )
                out.append(
                    QuantKVCache(
                        k=jnp.broadcast_to(q.k[None], (cfg.n_periods,) + q.k.shape),
                        v=jnp.broadcast_to(q.v[None], (cfg.n_periods,) + q.v.shape),
                        k_scale=jnp.broadcast_to(
                            q.k_scale[None], (cfg.n_periods,) + q.k_scale.shape
                        ),
                        v_scale=jnp.broadcast_to(
                            q.v_scale[None], (cfg.n_periods,) + q.v_scale.shape
                        ),
                        length=lengths,
                    )
                )
            else:
                out.append(c._replace(length=lengths))
        else:
            out.append(c)
    return tuple(out)


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_slots(pool_caches, prefill_caches, slots: jax.Array, scale_floors=None):
    """Scatter prefilled request state into pool slots. slots: [Bb] int32.

    KV buffers copy only the prompt span ``[:, slots, :Lb]`` (the rest of the
    lane stays dead until the length mask exposes it); recurrent states
    (mamba conv/ssm, xlstm) copy their whole slot row. Prefill batches padded
    up to a compile-friendly row count pass an out-of-range slot index for
    the filler rows — those writes drop.

    ``scale_floors`` (quantized pools only) is a tuple aligned with the cache
    entries: ``None`` or ``(k_floor, v_floor)`` per entry, each
    ``[n_periods, Bb, n_kv]`` — lower bounds on the join-time calibrated
    scales. Rows that attached a quantized cached prefix pass the prefix's
    original scales here (zeros elsewhere), so re-quantizing the dequantized
    prefix span reproduces the stored narrow values bit-for-bit whenever the
    prefix's amax dominates the prompt (see ``quant.kvcache``).
    """
    out = []
    floors = scale_floors or (None,) * len(pool_caches)
    for pc, fc, fl in zip(pool_caches, prefill_caches, floors):
        if pc is None or fc is None:
            out.append(pc)
        elif isinstance(pc, QuantKVCache):
            # Join-time calibration: per-(row, head) scales from the
            # request's own prefilled K/V (amax over the prompt span, with
            # headroom for decode-time values), then quantize and scatter.
            # The scales land in the slot's scale sidecar and stay fixed
            # until the next join overwrites the lane.
            lb = fc.k.shape[2]
            k_q, v_q, k_s, v_s = quantize_kv_rows(
                fc.k, fc.v, pc.n_kv, fmt=pc.k.dtype, margin=DEFAULT_KV_MARGIN,
                k_scale_floor=None if fl is None else fl[0],
                v_scale_floor=None if fl is None else fl[1],
            )
            out.append(
                pc._replace(
                    k=pc.k.at[:, slots, :lb].set(k_q, mode="drop"),
                    v=pc.v.at[:, slots, :lb].set(v_q, mode="drop"),
                    k_scale=pc.k_scale.at[:, slots].set(k_s, mode="drop"),
                    v_scale=pc.v_scale.at[:, slots].set(v_s, mode="drop"),
                )
            )
        elif isinstance(pc, KVCache):
            lb = fc.k.shape[2]
            out.append(
                pc._replace(
                    k=pc.k.at[:, slots, :lb].set(
                        fc.k.astype(pc.k.dtype), mode="drop"
                    ),
                    v=pc.v.at[:, slots, :lb].set(
                        fc.v.astype(pc.v.dtype), mode="drop"
                    ),
                )
            )
        elif isinstance(pc, jax.Array):
            out.append(pc)  # zero-size placeholder for cache-less layers
        else:
            out.append(
                jax.tree.map(
                    lambda p, f: p.at[:, slots].set(
                        f.astype(p.dtype), mode="drop"
                    ),
                    pc,
                    fc,
                )
            )
    return tuple(out)


@dataclasses.dataclass
class SlotPool:
    """Device caches + host-side slot lease bookkeeping."""

    cfg: ArchConfig
    n_slots: int
    max_len: int
    caches: Any
    _free: List[int]
    _owner: Dict[int, Any]  # slot -> request id
    # Peak concurrently-leased slots over the pool's lifetime (leased =
    # owned, whether the lane is already decoding or still mid-chunk-prefill)
    # — the capacity-planning high-watermark `serve.slot_pool_hwm` reports.
    leased_hwm: int = 0

    @classmethod
    def create(
        cls, cfg: ArchConfig, n_slots: int, max_len: int, dtype=jnp.bfloat16,
        kv_format: Optional[str] = None,
    ) -> "SlotPool":
        return cls(
            cfg=cfg,
            n_slots=n_slots,
            max_len=max_len,
            caches=init_slot_caches(cfg, n_slots, max_len, dtype, kv_format),
            _free=list(range(n_slots)),
            _owner={},
        )

    def kv_bytes_per_slot(self) -> float:
        """K/V cache bytes held per slot (values + scale sidecars)."""
        from repro.quant.kvcache import kv_bytes_per_slot

        return kv_bytes_per_slot(self.caches)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.n_slots

    def owner_of(self, slot: int):
        return self._owner.get(slot)

    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def allocate(self, request_ids) -> List[int]:
        """Lease one slot per request id (lowest-numbered slots first)."""
        if len(request_ids) > len(self._free):
            raise RuntimeError(
                f"requested {len(request_ids)} slots, {len(self._free)} free"
            )
        self._free.sort()
        slots = [self._free.pop(0) for _ in request_ids]
        for s, rid in zip(slots, request_ids):
            self._owner[s] = rid
        self.leased_hwm = max(self.leased_hwm, len(self._owner))
        return slots

    def release(self, slot: int) -> bool:
        """Return ``slot`` to the free list. Idempotent: releasing an
        in-range slot that is already free is a no-op returning ``False``
        (a request can retire both at its join tick — one-token prompts —
        and in the same tick's evict sweep); an out-of-range slot id is a
        caller bug and still raises."""
        if not (0 <= slot < self.n_slots):
            raise KeyError(f"slot {slot} out of range [0, {self.n_slots})")
        rid = self._owner.pop(slot, None)
        if rid is None:
            return False
        self._free.append(slot)
        return True

    def join(self, prefill_caches, slots: List[int], scale_floors=None) -> None:
        """Scatter a prefilled bucket into the leased ``slots`` (device op).

        ``prefill_caches`` may hold more rows than ``slots`` (compile-width
        padding); filler rows are routed to slot index ``n_slots`` and drop.
        ``scale_floors`` passes through to :func:`scatter_slots` (quantized
        prefix-scale adoption).
        """
        n_rows = _n_rows(prefill_caches)
        idx = list(slots) + [self.n_slots] * (n_rows - len(slots))
        self.caches = scatter_slots(
            self.caches, prefill_caches, jnp.asarray(idx, jnp.int32),
            scale_floors,
        )


def _n_rows(prefill_caches) -> int:
    for c in prefill_caches:
        if isinstance(c, KVCache):
            return c.k.shape[1]
        if c is not None and not (isinstance(c, jax.Array) and c.size == 0):
            return jax.tree.leaves(c)[0].shape[1]
    raise ValueError("prefill caches contain no per-row state")


# ---------------------------------------------------------------------------
# Radix prefix cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrefixNode:
    """One block of cached prompt K/V: an edge in the radix trie.

    ``payload`` is a tuple aligned with the pool-cache entries: ``None`` for
    non-attention positions, else per-layer-stack K/V for this block's token
    span — full-precision ``(k, v)`` blocks ``[n_periods, bs, fused]``, or
    quantized ``(k_q, v_q, k_scale, v_scale)`` with per-(period, head) fp32
    scales ``[n_periods, n_kv]`` when the trie stores narrow lanes.
    """

    block: tuple  # the token-id block keying this edge
    parent: Optional["PrefixNode"]
    children: Dict[tuple, "PrefixNode"] = dataclasses.field(default_factory=dict)
    payload: tuple = ()
    refcount: int = 0
    last_used: int = 0
    # Memoized ``gather`` result for the root->this-node path. Payloads are
    # immutable (first writer wins) and ancestors outlive this node (eviction
    # only takes childless leaves), so the memo stays valid for the node's
    # whole residency and dies with it on eviction.
    gathered: Optional[tuple] = None


class PrefixCache:
    """Radix-style prompt-prefix cache over token-id blocks.

    Requests sharing a system prompt re-prefill the same K/V on every join —
    the serving-side version of the data-reuse the paper wrings out of the
    MAC array. This trie keys blocks of ``block_size`` token ids; each edge
    holds that block's per-layer K/V slice (quantized to the pool's narrow
    format when ``kv_format`` is set, ~4x cheaper to keep resident). The
    engine matches a new prompt against the trie, attaches the longest cached
    prefix into the request's standalone prefill caches, and chunk-prefills
    only the suffix.

    Residency: matched nodes are ref-counted (``acquire``/``release``) for
    the request's prefill lifetime so eviction can never yank a block that a
    pending chunk pipeline is attached to. Eviction is LRU over refcount-0
    leaves whenever ``cached_tokens`` exceeds ``capacity_tokens``.

    Host-side object; the payloads are device arrays. Pure bookkeeping — no
    jit, nothing here can recompile the decode step.
    """

    def __init__(
        self,
        *,
        block_size: int = 16,
        capacity_tokens: int = 1 << 16,
        kv_format: Optional[str] = None,
        n_kv: Optional[int] = None,
        margin: float = DEFAULT_KV_MARGIN,
    ):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if kv_format is not None and n_kv is None:
            raise ValueError("quantized prefix trie needs n_kv for its scales")
        self.block_size = int(block_size)
        self.capacity_tokens = int(capacity_tokens)
        self.kv_format = kv_format
        self.n_kv = n_kv
        self.margin = margin
        self._root = PrefixNode(block=(), parent=None)
        self._clock = 0
        self._n_nodes = 0
        self.evictions = 0
        self.hits = 0
        self.misses = 0

    # -- introspection ------------------------------------------------------

    @property
    def cached_tokens(self) -> int:
        return self._n_nodes * self.block_size

    @property
    def n_nodes(self) -> int:
        return self._n_nodes

    # -- match / residency --------------------------------------------------

    def match(self, tokens) -> Tuple[List[PrefixNode], int]:
        """Longest cached prefix of ``tokens``, in whole blocks, capped so at
        least one prompt token is left to prefill (the join still needs real
        last-token logits). Returns ``(path nodes, matched token count)``
        and refreshes the LRU clock of every node on the path."""
        toks = [int(t) for t in tokens]
        node, path, matched = self._root, [], 0
        while matched + self.block_size <= len(toks) - 1:
            blk = tuple(toks[matched : matched + self.block_size])
            child = node.children.get(blk)
            if child is None:
                break
            path.append(child)
            matched += self.block_size
            node = child
        self._clock += 1
        for n in path:
            n.last_used = self._clock
        return path, matched

    def acquire(self, nodes: List[PrefixNode]) -> None:
        for n in nodes:
            n.refcount += 1

    def release(self, nodes: List[PrefixNode]) -> None:
        for n in nodes:
            assert n.refcount > 0, "prefix node released more times than acquired"
            n.refcount -= 1

    # -- insert -------------------------------------------------------------

    def insert(self, tokens, plen: int, prefill_caches, row: int) -> int:
        """Insert the full blocks of ``tokens[:plen]`` from one finished
        prefill: ``prefill_caches`` is the request's standalone (always
        full-precision) cache stack, ``row`` its lane. Blocks already present
        keep their original payloads (first writer wins — re-quantizing a
        round-tripped prefix would accumulate drift copies). Returns the
        number of new blocks, then evicts down to capacity."""
        toks = [int(t) for t in tokens[:plen]]
        n_blocks = len(toks) // self.block_size
        if not n_blocks:
            return 0
        quant = self.kv_format is not None
        scales = _span_scales(
            prefill_caches, row, n_blocks * self.block_size,
            fmt=self.kv_format, n_kv=self.n_kv, margin=self.margin,
        ) if quant else None
        node, created = self._root, 0
        self._clock += 1
        for j in range(n_blocks):
            blk = tuple(toks[j * self.block_size : (j + 1) * self.block_size])
            child = node.children.get(blk)
            if child is None:
                payload = _slice_payload(
                    prefill_caches, row,
                    j * self.block_size, (j + 1) * self.block_size,
                    fmt=self.kv_format, span_scales=scales,
                )
                child = PrefixNode(block=blk, parent=node, payload=payload)
                node.children[blk] = child
                self._n_nodes += 1
                created += 1
            child.last_used = self._clock
            node = child
        self._evict_to_capacity()
        return created

    # -- gather -------------------------------------------------------------

    def gather(self, nodes: List[PrefixNode]):
        """Concatenate a matched path into attachable per-entry spans.

        Returns ``(spans, floors)``: ``spans`` aligned with the cache
        entries — ``None`` or full-precision ``(k, v)`` of shape
        ``[n_periods, L, fused]`` (quantized payloads dequantize here; chunk
        prefill always runs full-precision standalone caches) — and
        ``floors`` — ``None`` or per-entry ``(k_scale, v_scale)``
        ``[n_periods, n_kv]`` scale floors (elementwise max over the path's
        block scales) for join-time scale adoption; ``None`` for
        full-precision tries."""
        if not nodes:
            raise ValueError("gather of an empty prefix path")
        if nodes[-1].gathered is not None:
            return nodes[-1].gathered
        n_entries = len(nodes[0].payload)
        spans, floors = [], []
        for e in range(n_entries):
            parts = [n.payload[e] for n in nodes]
            if parts[0] is None:
                spans.append(None)
                floors.append(None)
                continue
            if self.kv_format is None:
                k = jnp.concatenate([p[0] for p in parts], axis=1)
                v = jnp.concatenate([p[1] for p in parts], axis=1)
                spans.append((k, v))
                floors.append(None)
            else:
                from repro.quant.kvcache import _dequant  # noqa: PLC2701

                k = jnp.concatenate(
                    [_dequant(p[0], p[2], jnp.float32) for p in parts], axis=1
                )
                v = jnp.concatenate(
                    [_dequant(p[1], p[3], jnp.float32) for p in parts], axis=1
                )
                k_fl = functools.reduce(jnp.maximum, [p[2] for p in parts])
                v_fl = functools.reduce(jnp.maximum, [p[3] for p in parts])
                spans.append((k, v))
                floors.append((k_fl, v_fl))
        out = tuple(spans), (None if self.kv_format is None else tuple(floors))
        nodes[-1].gathered = out
        return out

    # -- eviction -----------------------------------------------------------

    def _evict_to_capacity(self) -> None:
        while self.cached_tokens > self.capacity_tokens:
            victim = None
            stack = [self._root]
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                if (
                    n is not self._root
                    and not n.children
                    and n.refcount == 0
                    and (victim is None or n.last_used < victim.last_used)
                ):
                    victim = n
            if victim is None:
                return  # everything resident is referenced — over capacity
            del victim.parent.children[victim.block]
            self._n_nodes -= 1
            self.evictions += 1


def _kv_entries(prefill_caches, row: int):
    """Yield ``(index, KVCache)`` for the attention entries of a standalone
    prefill cache stack ``[n_periods, rows, Lb, fused]``."""
    for i, c in enumerate(prefill_caches):
        if isinstance(c, KVCache):
            yield i, c
        elif isinstance(c, QuantKVCache):
            raise TypeError(
                "prefix insertion reads full-precision standalone caches; "
                "quantization happens inside the trie"
            )


def _span_scales(prefill_caches, row: int, span: int, *, fmt, n_kv, margin):
    """Per-entry per-(period, head) scales calibrated over the whole inserted
    span — every block of one insertion shares one scale, so a path inserted
    together dequantizes/re-quantizes against a single floor."""
    from repro.quant.quantize import format_of

    f = format_of(fmt)
    scales = {}
    for i, c in _kv_entries(prefill_caches, row):
        out = []
        for x in (c.k, c.v):
            xh = x[:, row, :span].astype(jnp.float32)
            p, s, fused = xh.shape
            xh = xh.reshape(p, s, n_kv, fused // n_kv)
            amax = jnp.max(jnp.abs(xh), axis=(1, 3))  # [n_periods, n_kv]
            out.append(jnp.maximum(amax * margin, 1e-12) / f.qmax)
        scales[i] = tuple(out)
    return scales


def _slice_payload(prefill_caches, row, lo, hi, *, fmt, span_scales):
    """One block's payload tuple (aligned with the cache entries)."""
    from repro.quant.quantize import format_of

    payload = []
    kv_at = dict(_kv_entries(prefill_caches, row))
    for i, c in enumerate(prefill_caches):
        if i not in kv_at:
            payload.append(None)
            continue
        k = c.k[:, row, lo:hi]
        v = c.v[:, row, lo:hi]
        if fmt is None:
            payload.append((k, v))
        else:
            f = format_of(fmt)
            k_s, v_s = span_scales[i]
            n_kv = k_s.shape[-1]

            def q(x, s):
                p, sp, fused = x.shape
                xh = x.astype(jnp.float32).reshape(p, sp, n_kv, fused // n_kv)
                return f.cast(xh / s[:, None, :, None]).reshape(p, sp, fused)

            payload.append((q(k, k_s), q(v, v_s), k_s, v_s))
    return tuple(payload)
