"""Slot-pooled KV cache manager for continuous batching.

The pool owns one set of decode buffers sized ``[n_slots, max_len]``
(``models.api.init_state``) for the whole engine lifetime. Each serving
request leases a *slot* — one batch lane of every cache buffer — for exactly
as long as it is live:

* **join**: a freshly prefilled request's caches (sized to its prompt
  bucket) are scattered into its slot rows with one fused jit'd gather/
  scatter (:func:`scatter_slots`); nothing else in the pool moves.
* **decode**: every slot advances through ``models.api.decode_at`` with its
  own position — per-slot fill counters mean a retiring request never
  touches its neighbours.
* **release**: freeing a slot is pure host bookkeeping (the lane's stale
  K/V is dead weight masked off by the per-slot length mask until the next
  join overwrites it) — zero device work.

This is the serving analogue of the paper's output-stationary accumulator
management: state stays resident where it is used, and only the minimal
panel (one request's rows) streams in or out on a lifecycle event.

**Quantized K/V lanes** (``kv_format="int8"`` / an fp8 format name): the
pool's attention caches become :class:`repro.quant.QuantKVCache` — 1-byte
K/V values with fp32 per-slot, per-head scales. Scales are calibrated at
*join* time from each request's prefilled K/V (inside the jit'd scatter),
fixed for the request's lifetime, and the decode step dequantizes inside the
fused attention. Per-slot cache memory drops ~4x vs fp32 lanes, so the same
HBM budget admits proportionally more concurrent requests. Recurrent states
(mamba conv/ssm, xlstm) stay full-width — they are rewritten wholesale every
step, not appended-and-reread like KV history.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api
from repro.models.attention import KVCache
from repro.quant.kvcache import (
    DEFAULT_KV_MARGIN,
    QuantKVCache,
    quantize_kv_rows,
)

__all__ = ["SlotPool", "init_slot_caches", "scatter_slots"]


def init_slot_caches(
    cfg: ArchConfig, n_slots: int, max_len: int, dtype,
    kv_format: Optional[str] = None,
):
    """Pool-shaped decode caches: per-slot fill counters from step zero.

    Like ``api.init_state`` but (a) every stacked ``KVCache`` carries an
    int32 ``[n_periods, n_slots]`` length vector instead of a scalar, and
    (b) cache-less pattern positions hold the zero-size placeholder array the
    layer-scan threads through — so the pytree structure (and therefore the
    compiled decode step) is identical on step 1 and step 10 000.

    ``kv_format`` (a :data:`repro.quant.FORMATS` name) narrows the attention
    K/V lanes to that storage format with per-slot, per-head scales.
    """
    caches = model_api.init_state(cfg, n_slots, max_len, dtype)
    lengths = jnp.zeros((cfg.n_periods, n_slots), jnp.int32)
    out = []
    for c in caches:
        if c is None:
            out.append(jnp.zeros((cfg.n_periods, 0), jnp.float32))
        elif isinstance(c, KVCache):
            if kv_format is not None:
                q = QuantKVCache.zeros(
                    n_slots, max_len, cfg.n_kv, cfg.head_dim_, fmt=kv_format
                )
                out.append(
                    QuantKVCache(
                        k=jnp.broadcast_to(q.k[None], (cfg.n_periods,) + q.k.shape),
                        v=jnp.broadcast_to(q.v[None], (cfg.n_periods,) + q.v.shape),
                        k_scale=jnp.broadcast_to(
                            q.k_scale[None], (cfg.n_periods,) + q.k_scale.shape
                        ),
                        v_scale=jnp.broadcast_to(
                            q.v_scale[None], (cfg.n_periods,) + q.v_scale.shape
                        ),
                        length=lengths,
                    )
                )
            else:
                out.append(c._replace(length=lengths))
        else:
            out.append(c)
    return tuple(out)


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_slots(pool_caches, prefill_caches, slots: jax.Array):
    """Scatter prefilled request state into pool slots. slots: [Bb] int32.

    KV buffers copy only the prompt span ``[:, slots, :Lb]`` (the rest of the
    lane stays dead until the length mask exposes it); recurrent states
    (mamba conv/ssm, xlstm) copy their whole slot row. Prefill batches padded
    up to a compile-friendly row count pass an out-of-range slot index for
    the filler rows — those writes drop.
    """
    out = []
    for pc, fc in zip(pool_caches, prefill_caches):
        if pc is None or fc is None:
            out.append(pc)
        elif isinstance(pc, QuantKVCache):
            # Join-time calibration: per-(row, head) scales from the
            # request's own prefilled K/V (amax over the prompt span, with
            # headroom for decode-time values), then quantize and scatter.
            # The scales land in the slot's scale sidecar and stay fixed
            # until the next join overwrites the lane.
            lb = fc.k.shape[2]
            k_q, v_q, k_s, v_s = quantize_kv_rows(
                fc.k, fc.v, pc.n_kv, fmt=pc.k.dtype, margin=DEFAULT_KV_MARGIN
            )
            out.append(
                pc._replace(
                    k=pc.k.at[:, slots, :lb].set(k_q, mode="drop"),
                    v=pc.v.at[:, slots, :lb].set(v_q, mode="drop"),
                    k_scale=pc.k_scale.at[:, slots].set(k_s, mode="drop"),
                    v_scale=pc.v_scale.at[:, slots].set(v_s, mode="drop"),
                )
            )
        elif isinstance(pc, KVCache):
            lb = fc.k.shape[2]
            out.append(
                pc._replace(
                    k=pc.k.at[:, slots, :lb].set(
                        fc.k.astype(pc.k.dtype), mode="drop"
                    ),
                    v=pc.v.at[:, slots, :lb].set(
                        fc.v.astype(pc.v.dtype), mode="drop"
                    ),
                )
            )
        elif isinstance(pc, jax.Array):
            out.append(pc)  # zero-size placeholder for cache-less layers
        else:
            out.append(
                jax.tree.map(
                    lambda p, f: p.at[:, slots].set(
                        f.astype(p.dtype), mode="drop"
                    ),
                    pc,
                    fc,
                )
            )
    return tuple(out)


@dataclasses.dataclass
class SlotPool:
    """Device caches + host-side slot lease bookkeeping."""

    cfg: ArchConfig
    n_slots: int
    max_len: int
    caches: Any
    _free: List[int]
    _owner: Dict[int, Any]  # slot -> request id

    @classmethod
    def create(
        cls, cfg: ArchConfig, n_slots: int, max_len: int, dtype=jnp.bfloat16,
        kv_format: Optional[str] = None,
    ) -> "SlotPool":
        return cls(
            cfg=cfg,
            n_slots=n_slots,
            max_len=max_len,
            caches=init_slot_caches(cfg, n_slots, max_len, dtype, kv_format),
            _free=list(range(n_slots)),
            _owner={},
        )

    def kv_bytes_per_slot(self) -> float:
        """K/V cache bytes held per slot (values + scale sidecars)."""
        from repro.quant.kvcache import kv_bytes_per_slot

        return kv_bytes_per_slot(self.caches)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.n_slots

    def owner_of(self, slot: int):
        return self._owner.get(slot)

    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def allocate(self, request_ids) -> List[int]:
        """Lease one slot per request id (lowest-numbered slots first)."""
        if len(request_ids) > len(self._free):
            raise RuntimeError(
                f"requested {len(request_ids)} slots, {len(self._free)} free"
            )
        self._free.sort()
        slots = [self._free.pop(0) for _ in request_ids]
        for s, rid in zip(slots, request_ids):
            self._owner[s] = rid
        return slots

    def release(self, slot: int) -> None:
        rid = self._owner.pop(slot, None)
        if rid is None:
            raise KeyError(f"slot {slot} is not leased")
        self._free.append(slot)

    def join(self, prefill_caches, slots: List[int]) -> None:
        """Scatter a prefilled bucket into the leased ``slots`` (device op).

        ``prefill_caches`` may hold more rows than ``slots`` (compile-width
        padding); filler rows are routed to slot index ``n_slots`` and drop.
        """
        n_rows = _n_rows(prefill_caches)
        idx = list(slots) + [self.n_slots] * (n_rows - len(slots))
        self.caches = scatter_slots(
            self.caches, prefill_caches, jnp.asarray(idx, jnp.int32)
        )


def _n_rows(prefill_caches) -> int:
    for c in prefill_caches:
        if isinstance(c, KVCache):
            return c.k.shape[1]
        if c is not None and not (isinstance(c, jax.Array) and c.size == 0):
            return jax.tree.leaves(c)[0].shape[1]
    raise ValueError("prefill caches contain no per-row state")
