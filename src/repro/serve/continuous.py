"""Continuous-batching serving engine: keep every decode lane busy.

The static ``ServeEngine`` admits a batch, decodes until the *longest*
request finishes, and only then admits more — decode GEMMs shrink as
requests retire, starving the engine exactly the way low-utilization
baselines starve their MAC arrays in the paper. ``ContinuousEngine``
instead drives **one fused jit decode step over a fixed slot pool with an
active-slot mask**: a finished request frees its slot mid-flight, the next
queued request is prefilled (length-bucketed compiled steps) and scattered
in, and the decode step never recompiles — a masked slot costs one batch
lane, not a new program. Slot occupancy is the serving analogue of the
paper's FPU utilization, and the engine reports it next to tokens/sec.

Step loop (one tick = one fused decode dispatch):

1. **join** — while slots are free and arrived requests queue, prefill one
   prompt-length bucket (``api.prefill_bucketed``), sample each request's
   first token from its last-real-token logits, scatter caches into leased
   slots (`SlotPool.join`), and point the lanes at their positions.
2. **decode** — one jit'd ``decode_at`` + sample over all ``n_slots`` lanes
   (inactive lanes are masked: they hold their token and position).
3. **evict** — stream each active lane's sampled token to its request;
   EOS / max-token requests retire and free their slot for the next tick.

Two compounding prompt-side optimizations (attention-only patterns, both
off by default — ``prefill_chunk`` / ``prefix_cache`` fields or the
``REPRO_PREFILL_CHUNK`` / ``REPRO_PREFIX_CACHE`` env knobs):

* **Chunked prefill** — instead of one monolithic bucket prefill that
  stalls every in-flight decode for the length of the longest prompt, a
  join becomes a *pending pipeline*: its standalone caches advance by one
  fixed power-of-two chunk (``api.prefill_chunk``) per tick, interleaved
  with the pool's decode steps, and the batch joins the pool only when
  every row's prompt is consumed. Chunk width and bucket are compile-time
  shapes; per-row offsets are data — the chunk step compiles once per
  (rows, bucket, width), and the decode step still never recompiles.
* **Prefix cache** — a radix trie over token-id blocks
  (``serve.cache.PrefixCache``) remembers finished prompts' K/V. A new
  request attaches its longest cached prefix (snapped down to a chunk
  boundary — resume offsets stay chunk-aligned) directly into its
  standalone caches and chunk-prefills only the suffix; quantized pools
  re-quantize the attached span under the prefix's original scales
  (scale adoption — see ``quant.kvcache``). Emits
  ``serve.prefix_cache.{hits,misses,evictions,cached_tokens}``.

Every request's lifecycle — arrival, admission (incl. fall-through bucket),
prefix attach, chunk ticks, first token, each ITL, retirement — is stamped
into :mod:`repro.obs.tracing` keyed by ``Request.uid`` (host-side only; the
compiled decode step is bit-identical with tracing on or off). The
``queue``/``prefix_attach``/``chunk_prefill`` phases are contiguous and
share the TTFT stamps, so the exported Perfetto timeline decomposes each
``serve.ttft_seconds`` sample exactly. ``slo_ttft_ms``/``slo_itl_ms`` (or
``REPRO_SLO_TTFT_MS``/``REPRO_SLO_ITL_MS``) turn those stamps into
``ServingReport.goodput``.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.obs import attr as _attr
from repro.obs import tracing as _tracing
from repro.configs.base import ArchConfig
from repro.models import api as model_api

from .cache import PrefixCache, SlotPool
from .engine import sample_token
from .scheduler import Request, Scheduler

__all__ = ["ContinuousEngine", "ServingReport"]

# Chunk-prefill pipelines in flight at once. One is enough to kill
# head-of-line blocking (decode never waits on a monolithic prefill) while
# keeping slot reservations — leased but not yet active — bounded.
_MAX_PENDING = 1


@dataclasses.dataclass
class _PendingJoin:
    """A join mid-chunk: standalone caches filling one chunk per tick."""

    batch: List[Request]
    slots: List[int]
    caches: Any  # standalone full-precision caches [P, rows, lb, ...]
    rows: int
    lb: int
    offsets: np.ndarray  # [len(batch)] next fill position per row
    plens: np.ndarray  # [len(batch)] prompt lengths
    nodes: List[list]  # per-row acquired trie nodes (release at completion)
    floors: Any  # scale_floors for the quantized pool join (or None)
    first_logits: Optional[jax.Array] = None  # [rows, V]; valid where done
    done: Optional[np.ndarray] = None  # [len(batch)] row consumed its prompt

    def __post_init__(self) -> None:
        if self.done is None:
            self.done = np.zeros(len(self.batch), bool)

    @property
    def all_done(self) -> bool:
        return bool(self.done.all())


class _Lifecycle:
    """Per-``serve()`` request-lifecycle bookkeeping.

    Owns the wall stamps the report's product fields (TTFT/ITL and phase
    percentiles, goodput) are computed from, emits the phase histograms,
    and mirrors every lifecycle edge into :mod:`repro.obs.tracing`. The
    phase chain — queue → prefix_attach → chunk_prefill → decode (chunked)
    or queue → prefill → decode (monolithic) — is contiguous and shares
    these exact stamps, so each request's pre-decode phase durations sum
    to its ``serve.ttft_seconds`` sample by construction, which is what
    makes the exported timeline trustworthy as a TTFT decomposition.
    """

    def __init__(self) -> None:
        self.arrive: Dict[int, float] = {}  # rid -> clock-start stamp
        self.admit: Dict[int, float] = {}  # rid -> queue-exit stamp
        self.attach: Dict[int, float] = {}  # rid -> attach-done stamp
        self.last_tok: Dict[int, float] = {}
        self.ttfts: List[float] = []
        self.itls: List[float] = []
        self.queue_s: List[float] = []
        self.attach_s: List[float] = []
        self.chunk_s: List[float] = []
        self.ttft_by_rid: Dict[int, float] = {}
        self.itl_max: Dict[int, float] = {}

    def arrival(self, r: Request, ts: float) -> None:
        """Queue enter: the loop reached the request's arrival tick."""
        self.arrive[r.rid] = ts
        _tracing.begin_request(r.uid, r.rid, ts)

    def admitted(
        self, batch: List[Request], ts: float, bucket, fallthrough: bool,
        phase: str,
    ) -> None:
        """Queue exit: the scheduler popped ``batch`` for one join."""
        for r in batch:
            q = ts - self.arrive.get(r.rid, ts)
            self.admit[r.rid] = ts
            self.queue_s.append(q)
            _obs.histogram("serve.queue_seconds").observe(q)
            _tracing.annotate(r.uid, bucket=bucket, fallthrough=fallthrough)
            _tracing.instant(
                r.uid, "admitted", ts,
                bucket=bucket, fallthrough=fallthrough, queue_s=q,
            )
            _tracing.begin_phase(r.uid, phase, ts)

    def attached(self, batch: List[Request], ts: float) -> None:
        """Chunked path: slots leased + cached prefixes attached; the
        chunk-prefill pipeline owns the request from here to first token."""
        for r in batch:
            a = ts - self.admit.get(r.rid, ts)
            self.attach[r.rid] = ts
            self.attach_s.append(a)
            _obs.histogram("serve.prefill_attach_seconds").observe(a)
            _tracing.begin_phase(r.uid, "chunk_prefill", ts)

    def first_token(
        self, batch: List[Request], sched: Scheduler, eos_id, ts: float,
        chunked: bool,
    ) -> None:
        """First token sampled (from prefill logits, at join): closes the
        TTFT window and the last pre-decode phase with the same stamp."""
        _obs.counter("serve.requests", event="admitted").inc(len(batch))
        for r in batch:
            ttft = ts - self.arrive.get(r.rid, ts)
            self.ttfts.append(ttft)
            self.ttft_by_rid[r.rid] = ttft
            self.last_tok[r.rid] = ts
            _obs.histogram("serve.ttft_seconds").observe(ttft)
            if chunked:
                c = ts - self.attach.get(r.rid, ts)
                self.chunk_s.append(c)
                _obs.histogram("serve.chunk_prefill_seconds").observe(c)
            _tracing.instant(r.uid, "first_token", ts, ttft_s=ttft)
            _tracing.begin_phase(r.uid, "decode", ts)
            st = sched.states[r.rid]
            if st.done:  # one-token request: retires at its own join tick
                _obs.counter("serve.requests", event="retired").inc()
                reason = (
                    "eos"
                    if eos_id is not None and st.tokens
                    and st.tokens[-1] == eos_id
                    else "budget"
                )
                self.retired(r, st, reason, ts)

    def token(self, r: Request, ts: float) -> None:
        prev = self.last_tok.get(r.rid)
        if prev is not None:
            itl = ts - prev
            self.itls.append(itl)
            self.itl_max[r.rid] = max(self.itl_max.get(r.rid, 0.0), itl)
            _obs.histogram("serve.itl_seconds").observe(itl)
            _tracing.instant(r.uid, "token", ts, itl_s=itl)
        self.last_tok[r.rid] = ts

    def retired(self, r: Request, st, reason: str, ts: float) -> None:
        _obs.event(
            "request_retired", uid=r.uid, rid=r.rid, reason=reason,
            tokens=st.n_emitted, slot=st.slot,
        )
        _tracing.end_request(r.uid, reason, ts)

    def goodput(self, requests: List[Request], slo_ttft_s, slo_itl_s):
        """Fraction of requests meeting every configured SLO; None when no
        SLO is set (absence of an objective must not read as 100%)."""
        if (slo_ttft_s is None and slo_itl_s is None) or not requests:
            return None
        good = 0
        for r in requests:
            ok = True
            if slo_ttft_s is not None:
                ttft = self.ttft_by_rid.get(r.rid)
                ok = ok and ttft is not None and ttft <= slo_ttft_s
            if slo_itl_s is not None:
                ok = ok and self.itl_max.get(r.rid, 0.0) <= slo_itl_s
            good += bool(ok)
        return good / len(requests)


@dataclasses.dataclass
class ServingReport:
    """Outcome + the utilization counters the paper's story maps onto."""

    outputs: Dict[int, List[int]]  # rid -> generated tokens
    generated_tokens: int
    decode_steps: int
    prefill_batches: int
    mean_occupancy: float  # mean active-slot fraction per decode step
    wall_time_s: float
    kv_bytes_per_slot: float = 0.0  # K/V pool bytes per slot (+ quant scales)
    # Host-observed latency percentiles (seconds), or ``None`` when the run
    # produced no samples — "no data" must never masquerade as "zero
    # latency" (JSON renders it as null). TTFT = wall clock from the
    # request's arrival tick to its first token (sampled from prefill logits
    # at join, so queueing + prefill dominate); ITL = wall clock between a
    # lane's consecutive tokens. On the deferred-detokenization path (no EOS,
    # no streaming callback) decode dispatches are async, so ITL measures
    # host dispatch cadence, not device step latency — the sync path (EOS or
    # ``on_token``) measures true token-to-token wall time.
    ttft_p50: Optional[float] = None
    ttft_p99: Optional[float] = None
    itl_p50: Optional[float] = None
    itl_p99: Optional[float] = None
    # SLO / phase decomposition. ``goodput`` = fraction of requests whose
    # TTFT (and worst ITL) met every configured objective (engine
    # ``slo_ttft_ms``/``slo_itl_ms`` fields or ``REPRO_SLO_TTFT_MS`` /
    # ``REPRO_SLO_ITL_MS``); None when no SLO is set. ``queue_*`` is the
    # arrival -> admission wait; ``attach_*`` / ``chunk_prefill_*`` are the
    # chunked path's prefix-attach and chunk-prefill phases (None on the
    # monolithic path, whose single pre-decode phase is TTFT - queue).
    # The three phases are contiguous and share the TTFT stamps, so per
    # request they sum exactly to its ``serve.ttft_seconds`` sample.
    # ``slot_hwm`` = peak concurrently-leased slots (capacity headroom).
    goodput: Optional[float] = None
    queue_p50: Optional[float] = None
    queue_p99: Optional[float] = None
    attach_p50: Optional[float] = None
    attach_p99: Optional[float] = None
    chunk_prefill_p50: Optional[float] = None
    chunk_prefill_p99: Optional[float] = None
    slot_hwm: int = 0

    @property
    def tokens_per_sec(self) -> float:
        return self.generated_tokens / self.wall_time_s if self.wall_time_s else 0.0

    @property
    def tokens_per_step(self) -> float:
        """Useful tokens per decode dispatch — the deterministic (wall-clock
        free) throughput proxy; == n_slots * mean occupancy up to the tokens
        sampled directly from prefill logits."""
        return self.generated_tokens / self.decode_steps if self.decode_steps else 0.0


@dataclasses.dataclass
class ContinuousEngine:
    """Continuous-batching engine over ``n_slots`` pooled decode lanes.

    LM families only (dense / moe / hybrid / ssm): requests are token
    prompts. The static ``ServeEngine`` remains the simple lockstep path
    (and the audio/VLM entry point).
    """

    cfg: ArchConfig
    params: Any
    n_slots: int
    max_len: int
    cache_dtype: Any = jnp.bfloat16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    exact_buckets: Optional[bool] = None  # None = auto (exact iff recurrent)
    # Narrow K/V lanes for the slot pool ("int8" / "fp8_e4m3" / "fp8_e5m2"):
    # ~4x less cache memory per slot (vs fp32 lanes), so the same HBM budget
    # admits proportionally more slots. Prefill stays full-precision; the
    # join scatter calibrates per-slot scales and quantizes (see serve.cache).
    kv_format: Optional[str] = None
    # Chunked prefill width (power of two; None = env REPRO_PREFILL_CHUNK,
    # unset = off). Attention-only patterns; see the module docstring.
    prefill_chunk: Optional[int] = None
    # Prefix cache (None = env REPRO_PREFIX_CACHE, unset = off). Enabling it
    # implies chunked prefill (suffix-only prefill needs the chunk entry);
    # the trie persists across serve() calls for the engine's lifetime.
    prefix_cache: Optional[bool] = None
    prefix_block: int = 16  # trie block size, tokens
    prefix_capacity: int = 1 << 16  # trie capacity, tokens
    # TTFT / worst-ITL service-level objectives in milliseconds (None = env
    # REPRO_SLO_TTFT_MS / REPRO_SLO_ITL_MS, unset = no SLO). With at least
    # one set, ServingReport.goodput is the fraction of requests meeting
    # every configured objective.
    slo_ttft_ms: Optional[float] = None
    slo_itl_ms: Optional[float] = None

    def __post_init__(self) -> None:
        cfg = self.cfg
        if cfg.family in ("audio", "vlm"):
            # audio needs encoder frames, vlm per-request image embeddings —
            # neither fits the token-prompt Request; serving them here would
            # silently drop the non-token inputs.
            raise NotImplementedError(
                f"ContinuousEngine serves token-prompt LM families; use "
                f"ServeEngine for {cfg.family}"
            )
        if cfg.moe is not None and not cfg.moe.dropless:
            # Token-choice capacity dropping routes by whole-batch content:
            # one request's load would change another's outputs. Dropless
            # routing is per-token, keeping slots independent.
            warnings.warn(
                "continuous batching with capacity-dropping MoE couples "
                "requests through the router; set moe.dropless for "
                "request-isolated serving",
                RuntimeWarning,
                stacklevel=2,
            )

        # Resolve the SLO knobs (fields beat env).
        if self.slo_ttft_ms is None:
            env = os.environ.get("REPRO_SLO_TTFT_MS", "")
            self.slo_ttft_ms = float(env) if env else None
        if self.slo_itl_ms is None:
            env = os.environ.get("REPRO_SLO_ITL_MS", "")
            self.slo_itl_ms = float(env) if env else None

        # Resolve the prompt-side feature knobs (fields beat env).
        if self.prefill_chunk is None:
            env = os.environ.get("REPRO_PREFILL_CHUNK", "")
            self.prefill_chunk = int(env) if env else None
        if self.prefix_cache is None:
            env = os.environ.get("REPRO_PREFIX_CACHE", "")
            self.prefix_cache = env.lower() not in ("", "0", "false", "no")
        if self.prefix_cache and self.prefill_chunk is None:
            self.prefill_chunk = 32  # suffix prefill rides the chunk entry
        if self.prefill_chunk is not None:
            w = self.prefill_chunk
            if w < 1 or (w & (w - 1)):
                raise ValueError(f"prefill_chunk must be a power of two, got {w}")
            attn_only = all(
                bd.mixer in ("attn", "attn_local", "none") for bd in cfg.pattern
            )
            if not attn_only:
                # Recurrent state can't resume mid-prompt from a scatter;
                # fall back to monolithic bucket prefill rather than fail.
                warnings.warn(
                    "chunked prefill / prefix cache need attention-only "
                    f"patterns; disabled for {cfg.name}",
                    RuntimeWarning,
                    stacklevel=2,
                )
                self.prefill_chunk = None
                self.prefix_cache = False
        self._trie: Optional[PrefixCache] = (
            PrefixCache(
                block_size=self.prefix_block,
                capacity_tokens=self.prefix_capacity,
                kv_format=self.kv_format,
                n_kv=cfg.n_kv,
            )
            if self.prefix_cache
            else None
        )
        self._pending: List[_PendingJoin] = []
        # Prefix-trie residency high-watermark (tokens) — the trie persists
        # across serve() calls, so the peak does too.
        self._prefix_hwm = 0

        @functools.partial(jax.jit, static_argnums=())
        def _prefill(params, tokens, lengths):
            logits, caches = model_api.prefill_bucketed(
                cfg, params, tokens, lengths, self.cache_dtype
            )
            return logits, caches

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _chunk(params, caches, ctoks, offsets, last_idx):
            return model_api.prefill_chunk(
                cfg, params, ctoks, caches, offsets, last_idx
            )

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, caches, tok, pos, active, key):
            logits, caches = model_api.decode_at(cfg, params, tok, caches, pos)
            nxt = sample_token(logits, key, self.temperature)
            # Masked slots cost a lane, not a recompile: they hold token and
            # position so the step's shapes/program never change.
            nxt = jnp.where(active[:, None], nxt, tok)
            pos = pos + active.astype(jnp.int32)
            return nxt, caches, pos

        self._prefill = _prefill
        self._chunk = _chunk
        self._decode = _decode
        # Utilization-attribution state (obs.attr): the GEMM workload of each
        # compiled step, captured once at trace time, then charged with every
        # subsequent dispatch's measured wall time. Keyed per compiled
        # program: one decode step; prefills per (rows, bucket); chunk steps
        # per (rows, bucket, width).
        self._decode_workload = None
        self._prefill_workloads: Dict[tuple, dict] = {}

    # -- introspection -----------------------------------------------------

    def decode_compilations(self) -> Optional[int]:
        """Number of compiled decode programs (None if jax hides the cache)."""
        try:
            return int(self._decode._cache_size())
        except Exception:
            return None

    def prefix_cache_stats(self) -> Optional[Dict[str, int]]:
        """Hit/miss/eviction/residency counters of the prefix trie (None
        when the cache is disabled)."""
        if self._trie is None:
            return None
        return {
            "hits": self._trie.hits,
            "misses": self._trie.misses,
            "evictions": self._trie.evictions,
            "cached_tokens": self._trie.cached_tokens,
        }

    # -- utilization attribution -------------------------------------------

    def _step_workload(self, store_key, fn, args, step_recs, kind: str):
        """Resolve the GEMM workload to charge for one dispatch.

        Records present => this dispatch traced: store its workload, return
        None (the tick's wall bracket includes trace + compile — skip it).
        Records absent and the key unknown (the compile happened while
        metrics were disabled) => re-capture at zero cost via
        ``jax.eval_shape`` so timed dispatches stop silently contributing
        zero attributed GEMM-seconds; each re-capture counts on
        ``gemm.attr_fallback``.
        """
        if step_recs:
            self._prefill_workloads[store_key] = _attr.aggregate(step_recs)
            return None
        wl = self._prefill_workloads.get(store_key)
        if wl is None and _obs.enabled():
            # jax's trace cache is keyed on the function object + avals and
            # is shared with the jit wrapper's original (metrics-off) trace,
            # so eval_shape of either the wrapper or its unjitted body would
            # hit the cache and emit nothing. A fresh lambda is a fresh
            # cache key: the body genuinely re-traces (abstractly — zero
            # FLOPs, no compile) and the registry records re-fire.
            inner = getattr(fn, "__wrapped__", fn)
            with _attr.capture_gemms() as recs:
                jax.eval_shape(lambda *a: inner(*a), *args)
            if recs:
                wl = _attr.aggregate(recs)
                self._prefill_workloads[store_key] = wl
                _obs.counter("gemm.attr_fallback", step=kind).inc()
        return wl

    # -- serving -----------------------------------------------------------

    def serve(
        self,
        requests: List[Request],
        *,
        key: Optional[jax.Array] = None,
        on_token: Optional[Callable[[int, int], None]] = None,
        max_steps: Optional[int] = None,
    ) -> ServingReport:
        """Run ``requests`` to completion; returns outputs + counters.

        ``on_token(rid, token)`` streams every sampled token as soon as the
        host sees it (one fused step behind the device).
        """
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new {r.max_new_tokens} exceeds max_len {self.max_len}"
                )
        key = key if key is not None else jax.random.key(0)
        sched = Scheduler(
            self.cfg,
            eos_id=self.eos_id,
            exact_buckets=self.exact_buckets,
            max_bucket=self.max_len,
        )
        for r in requests:
            sched.submit(r)
        pool = SlotPool.create(
            self.cfg, self.n_slots, self.max_len, self.cache_dtype,
            kv_format=self.kv_format,
        )
        self._last_kv_bytes_per_slot = pool.kv_bytes_per_slot()
        # Abandoned pipelines die with their serve() call (slot leases are
        # per-pool); the prefix trie deliberately survives — warmup runs
        # populate it for the timed runs that follow.
        self._pending = []

        b = self.n_slots
        tok = jnp.zeros((b, 1), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        active = [False] * b  # host truth; device mask derived on change
        active_dev = jnp.asarray(active)

        # Without EOS eviction or a streaming callback, retirement depends
        # only on token *counts* — so the loop never reads token values and
        # decode dispatches pipeline freely; values are fetched once at the
        # end (deferred detokenization). With EOS/streaming, every step
        # syncs on the sampled tokens.
        sync = on_token is not None or self.eos_id is not None
        pending = []  # (device tokens [*, 1], [(row, rid), ...]) per step

        # Lifecycle wall stamps (always kept — the report's percentile fields
        # are product, not telemetry; only the obs emission is gated). A
        # request's clock starts when the loop reaches its arrival tick.
        wall = time.perf_counter
        by_arrival = sorted(requests, key=lambda r: r.arrival)
        n_arrival_stamped = 0
        lc = _Lifecycle()

        step = 0
        decode_steps = 0
        prefill_batches = 0
        generated = 0
        occupancy_acc = 0.0
        limit = max_steps if max_steps is not None else (
            sum(r.arrival + r.max_new_tokens for r in requests) + 10 * self.max_len
            # chunked joins spend up to ceil(plen/W) extra ticks per request
            + (sum(len(r.prompt) for r in requests) if self.prefill_chunk else 0)
        )

        while not (sched.drained and pool.n_active == 0):
            if step > limit:
                raise RuntimeError(f"serving did not drain within {limit} steps")
            while (
                n_arrival_stamped < len(by_arrival)
                and by_arrival[n_arrival_stamped].arrival <= step
            ):
                lc.arrival(by_arrival[n_arrival_stamped], wall())
                n_arrival_stamped += 1

            # -- join: refill free slots from the queue ---------------------
            joined = False
            chunked = self.prefill_chunk is not None
            while pool.n_free:
                admissible = None
                if chunked and len(self._pending) >= _MAX_PENDING:
                    # Pipeline full: only prompts whose remaining prefill
                    # fits one chunk can still join (they complete inline,
                    # no pipeline slot). Everything else waits — and the
                    # scheduler's deepest-admissible-bucket fallback keeps
                    # short arrivals flowing past the blocked head.
                    admissible = (
                        lambda r: self._suffix_len(r) <= self.prefill_chunk
                    )
                batch = sched.next_batch(
                    pool.n_free, now=step, admissible=admissible
                )
                if not batch:
                    break
                adm = sched.last_admission or {}
                if self.temperature > 0:
                    key, sub = jax.random.split(key)
                else:
                    sub = key  # greedy: sampling ignores the key
                if chunked:
                    lc.admitted(
                        batch, wall(), adm.get("bucket"),
                        bool(adm.get("fallthrough")), phase="prefix_attach",
                    )
                    pj = self._begin_join(sched, pool, batch, step)
                    lc.attached(batch, wall())
                    if len(self._pending) < _MAX_PENDING:
                        self._pending.append(pj)  # advances below, this tick
                    else:
                        # All-fast batch (admissible guaranteed it): one
                        # chunk finishes the whole prompt set — join now.
                        # (Loop, not a single advance: a trie eviction racing
                        # the admissibility check can lengthen a suffix.)
                        while not pj.all_done:
                            self._advance_chunk(pj)
                        tok, pos, n_gen = self._complete_join(
                            pj, sched, pool, tok, pos, active, sub, step,
                            on_token, sync, pending,
                        )
                        prefill_batches += 1
                        generated += n_gen
                        joined = True
                        self._stamp_join(pj.batch, sched, wall, lc)
                else:
                    lc.admitted(
                        batch, wall(), adm.get("bucket"),
                        bool(adm.get("fallthrough")), phase="prefill",
                    )
                    tok, pos, active, n_gen = self._join(
                        sched, pool, batch, tok, pos, active, sub, step,
                        on_token, sync, pending,
                    )
                    prefill_batches += 1
                    generated += n_gen  # one token per request, prefill logits
                    joined = True
                    # First token exists now (sampled from prefill logits):
                    # the join stamp closes each request's TTFT window.
                    self._stamp_join(batch, sched, wall, lc)

            # -- advance the pending chunk pipeline by one chunk ------------
            if self._pending:
                pj = self._pending[0]
                self._advance_chunk(pj)
                if pj.all_done:
                    if self.temperature > 0:
                        key, sub = jax.random.split(key)
                    else:
                        sub = key
                    tok, pos, n_gen = self._complete_join(
                        pj, sched, pool, tok, pos, active, sub, step,
                        on_token, sync, pending,
                    )
                    prefill_batches += 1
                    generated += n_gen
                    joined = True
                    self._stamp_join(pj.batch, sched, wall, lc)
                    self._pending.pop(0)
            if joined:
                active_dev = jnp.asarray(active)

            if not any(active):
                if sched.drained and not self._pending:
                    break
                step += 1  # idle tick: next arrival / next pending chunk
                continue

            # -- decode: one fused masked step over the whole pool ----------
            t_step = wall()
            n_live = sum(active)
            if self.temperature > 0:
                key, sub = jax.random.split(key)
            else:
                sub = key
            with _attr.capture_gemms() as step_recs:
                tok, pool.caches, pos = self._decode(
                    self.params, pool.caches, tok, pos, active_dev, sub
                )
            decode_wl = self._step_workload(
                ("decode",), self._decode,
                (self.params, pool.caches, tok, pos, active_dev, sub),
                step_recs, "decode",
            )
            decode_steps += 1
            occupancy_acc += n_live / self.n_slots
            step += 1

            # -- evict: stream tokens, retire finished requests -------------
            # Guard against already-retired lanes: a slot released earlier in
            # this tick (one-token request at join) must not be swept again —
            # the owner check plus the release return value make the sweep a
            # no-op for such lanes instead of freeing a re-leased slot twice.
            live = [
                s for s in pool.active_slots()
                if active[s] and pool.owner_of(s) is not None
            ]
            live_rids = [pool.owner_of(s) for s in live]
            n_retired = 0
            retired_now: List[tuple] = []  # (Request, reason)
            changed = False
            if sync:
                emitted = np.asarray(tok[:, 0])
                for slot, rid in zip(live, live_rids):
                    t = int(emitted[slot])
                    if on_token is not None:
                        on_token(rid, t)
                    generated += 1
                    if sched.record_token(rid, t, now=step):
                        reason = (
                            "eos"
                            if self.eos_id is not None and t == self.eos_id
                            else "budget"
                        )
                        retired_now.append((sched.states[rid].request, reason))
                        if pool.release(slot):
                            n_retired += 1
                        active[slot] = False
                        changed = True
            else:
                pending.append((tok, list(zip(live, live_rids))))
                for slot, rid in zip(live, live_rids):
                    generated += 1
                    if sched.record_emitted(rid, now=step):
                        retired_now.append(
                            (sched.states[rid].request, "budget")
                        )
                        if pool.release(slot):
                            n_retired += 1
                        active[slot] = False
                        changed = True
            if changed:
                active_dev = jnp.asarray(active)

            # Per-tick telemetry: step wall time, each live lane's
            # inter-token gap, queue/occupancy gauges. Retirement stamps
            # come after the token stamps so a request's last ITL instant
            # lands inside its span.
            now = wall()
            _obs.histogram("serve.step_seconds").observe(now - t_step)
            if decode_wl:
                # Same host-wall caveat as ITL: on the deferred path this is
                # dispatch cadence, on the sync path token-to-token time.
                _attr.observe_step(decode_wl, now - t_step)
            for rid in live_rids:
                lc.token(sched.states[rid].request, now)
            for r, reason in retired_now:
                lc.retired(r, sched.states[r.rid], reason, now)
            _obs.counter("serve.tokens").inc(len(live_rids))
            if n_retired:
                _obs.counter("serve.requests", event="retired").inc(n_retired)
            _obs.gauge("serve.queue_depth").set(sched.n_arrived(step))
            _obs.gauge("serve.occupancy").set(n_live / self.n_slots)
            _obs.gauge("serve.slot_pool_hwm").set(pool.leased_hwm)

        # Deferred fetch: one host sync for the whole run.
        for arr, pairs in pending:
            vals = np.asarray(arr[:, 0])
            for row, rid in pairs:
                sched.states[rid].tokens.append(int(vals[row]))
        jax.block_until_ready(tok)
        outputs = {rid: st.tokens for rid, st in sched.states.items()}
        _obs.gauge("serve.slot_pool_hwm").set(pool.leased_hwm)
        goodput = lc.goodput(
            requests,
            None if self.slo_ttft_ms is None else self.slo_ttft_ms / 1e3,
            None if self.slo_itl_ms is None else self.slo_itl_ms / 1e3,
        )
        report = ServingReport(
            outputs=outputs,
            generated_tokens=generated,
            decode_steps=decode_steps,
            prefill_batches=prefill_batches,
            mean_occupancy=(occupancy_acc / decode_steps) if decode_steps else 0.0,
            wall_time_s=0.0,  # stamped by timed_serve
            kv_bytes_per_slot=self._last_kv_bytes_per_slot,
            ttft_p50=_obs.percentile(lc.ttfts, 50),
            ttft_p99=_obs.percentile(lc.ttfts, 99),
            itl_p50=_obs.percentile(lc.itls, 50),
            itl_p99=_obs.percentile(lc.itls, 99),
            goodput=goodput,
            queue_p50=_obs.percentile(lc.queue_s, 50),
            queue_p99=_obs.percentile(lc.queue_s, 99),
            attach_p50=_obs.percentile(lc.attach_s, 50),
            attach_p99=_obs.percentile(lc.attach_s, 99),
            chunk_prefill_p50=_obs.percentile(lc.chunk_s, 50),
            chunk_prefill_p99=_obs.percentile(lc.chunk_s, 99),
            slot_hwm=pool.leased_hwm,
        )
        _obs.event(
            "serving_report",
            requests=len(requests),
            generated_tokens=report.generated_tokens,
            decode_steps=report.decode_steps,
            mean_occupancy=report.mean_occupancy,
            ttft_p50=report.ttft_p50,
            ttft_p99=report.ttft_p99,
            itl_p50=report.itl_p50,
            itl_p99=report.itl_p99,
            goodput=report.goodput,
            queue_p50=report.queue_p50,
            queue_p99=report.queue_p99,
            slot_hwm=report.slot_hwm,
        )
        return report

    def timed_serve(self, requests: List[Request], **kw) -> ServingReport:
        t0 = time.perf_counter()
        report = self.serve(requests, **kw)
        report.wall_time_s = time.perf_counter() - t0
        return report

    # -- internals ---------------------------------------------------------

    def _stamp_join(self, batch, sched, wall, lc: _Lifecycle) -> None:
        """Close each admitted request's TTFT window (its first token was
        just sampled) and emit the admission counters. One shared ``now``
        per batch simultaneously closes the last pre-decode phase and
        timestamps the first token — the reason the exported phase chain
        sums exactly to the TTFT sample."""
        lc.first_token(
            batch, sched, self.eos_id, wall(),
            chunked=self.prefill_chunk is not None,
        )

    def _attach_len(self, matched: int, plen: int) -> int:
        """Usable prefix span: snap the trie match down to a chunk boundary
        (resume offsets stay chunk-aligned — one partial chunk per prompt,
        at the tail) and always leave >= 1 token to prefill."""
        w = self.prefill_chunk
        attach = (min(matched, plen - 1) // w) * w
        return max(attach, 0)

    def _suffix_len(self, r: Request) -> int:
        """Prompt tokens left to prefill after a (hypothetical) prefix
        attach — the admissibility measure for a full chunk pipeline."""
        if self._trie is None:
            return len(r.prompt)
        _, matched = self._trie.match(r.prompt)
        return len(r.prompt) - self._attach_len(matched, len(r.prompt))

    def _begin_join(
        self, sched: Scheduler, pool: SlotPool, batch: List[Request], step: int
    ) -> _PendingJoin:
        """Lease slots, build standalone caches, attach cached prefixes.

        The returned pipeline advances one chunk per engine tick; the batch
        joins the pool (and its lanes activate) only at completion.
        """
        lb = sched.bucket(max(len(r.prompt) for r in batch))
        rows = 1
        while rows < len(batch):
            rows *= 2
        plens = np.array([len(r.prompt) for r in batch], np.int64)
        caches = model_api.init_state(
            self.cfg, rows, lb, self.cache_dtype
        )
        offsets = np.zeros(len(batch), np.int64)
        nodes: List[list] = [[] for _ in batch]
        floors = None
        if self._trie is not None:
            floors_np = None
            for i, r in enumerate(batch):
                path, matched = self._trie.match(r.prompt)
                attach = self._attach_len(matched, int(plens[i]))
                if attach <= 0:
                    self._trie.misses += 1
                    _obs.counter("serve.prefix_cache.misses").inc()
                    _tracing.instant(
                        r.uid, "prefix_miss", time.perf_counter(),
                        matched=int(matched),
                    )
                    continue
                self._trie.hits += 1
                _obs.counter("serve.prefix_cache.hits").inc()
                # Keep only the nodes the attach actually covers resident.
                n_nodes = -(-attach // self._trie.block_size)  # ceil
                nodes[i] = path[:n_nodes]
                self._trie.acquire(nodes[i])
                spans, fls = self._trie.gather(nodes[i])
                caches = _attach_prefix(caches, spans, i, attach)
                _tracing.instant(
                    r.uid, "prefix_attach", time.perf_counter(),
                    tokens=int(attach), matched=int(matched),
                    spans=int(n_nodes),
                )
                _tracing.annotate(
                    r.uid, prefix_tokens=int(attach), prefix_spans=int(n_nodes)
                )
                if fls is not None:
                    if floors_np is None:
                        floors_np = _zero_floors(rows, fls)
                    for e, f in enumerate(fls):
                        if f is not None:
                            floors_np[e][0][:, i] = np.asarray(f[0])
                            floors_np[e][1][:, i] = np.asarray(f[1])
                offsets[i] = attach
            if floors_np is not None:
                floors = tuple(
                    None if f is None else (jnp.asarray(f[0]), jnp.asarray(f[1]))
                    for f in floors_np
                )
        slots = pool.allocate([r.rid for r in batch])
        sched.admit(batch, slots, now=step)
        for r, s in zip(batch, slots):
            _tracing.set_slot(r.uid, s)
        return _PendingJoin(
            batch=batch, slots=slots, caches=caches, rows=rows, lb=lb,
            offsets=offsets, plens=plens, nodes=nodes, floors=floors,
        )

    def _advance_chunk(self, pj: _PendingJoin) -> None:
        """Advance every unfinished row of ``pj`` by one prompt chunk."""
        w = self.prefill_chunk
        ctoks = np.zeros((pj.rows, w), np.int32)
        # Sentinel offset = bucket length: every K/V write of that row drops
        # and its (garbage) logits row is never selected.
        offs = np.full((pj.rows,), pj.lb, np.int32)
        last_idx = np.zeros((pj.rows,), np.int32)
        fin = np.zeros((pj.rows,), bool)
        advanced = []  # (uid, off, end): trace slices stamped post-dispatch
        for i, r in enumerate(pj.batch):
            if pj.done[i]:
                continue
            off = int(pj.offsets[i])
            end = min(off + w, int(pj.plens[i]))
            offs[i] = off
            ctoks[i, : end - off] = np.asarray(r.prompt[off:end], np.int32)
            if end >= pj.plens[i]:
                fin[i] = True
                last_idx[i] = int(pj.plens[i]) - 1 - off
            pj.offsets[i] = end
            advanced.append((r.uid, off, end))
        args = (
            self.params, pj.caches, jnp.asarray(ctoks), jnp.asarray(offs),
            jnp.asarray(last_idx),
        )
        t_ck = time.perf_counter()
        with _attr.capture_gemms() as ck_recs:
            logits, pj.caches = self._chunk(*args)
        t_done = time.perf_counter()
        wl = self._step_workload(
            (pj.rows, pj.lb, w), self._chunk,
            (self.params, pj.caches) + args[2:], ck_recs, "chunk",
        )
        if wl:
            _attr.observe_step(wl, t_done - t_ck)
        # One nested slice per row advanced this tick (host dispatch
        # bracket — the chunk step itself is async like every dispatch).
        for uid, off, end in advanced:
            _tracing.slice_event(uid, "chunk", t_ck, t_done, offset=off, end=end)
        fin_dev = jnp.asarray(fin)
        pj.first_logits = (
            logits if pj.first_logits is None
            else jnp.where(fin_dev[:, None], logits, pj.first_logits)
        )
        pj.done |= fin[: len(pj.batch)]

    def _complete_join(
        self, pj: _PendingJoin, sched: Scheduler, pool: SlotPool,
        tok, pos, active, key, step, on_token, sync, pending,
    ):
        """All prompts consumed: join the pool, seed lanes, sample first
        tokens, insert the finished prompts into the prefix trie."""
        first = sample_token(pj.first_logits, key, self.temperature)
        pool.join(pj.caches, pj.slots, pj.floors)
        slot_idx = jnp.asarray(pj.slots, jnp.int32)
        tok = tok.at[slot_idx].set(first[: len(pj.batch)])
        pos = pos.at[slot_idx].set(
            jnp.asarray(pj.plens[: len(pj.batch)], jnp.int32)
        )
        n_gen = len(pj.batch)
        if sync:
            first_host = np.asarray(first[:, 0])
            for i, r in enumerate(pj.batch):
                t = int(first_host[i])
                if on_token is not None:
                    on_token(r.rid, t)
                if sched.record_token(r.rid, t, now=step):
                    pool.release(pj.slots[i])  # one-token request
                else:
                    active[pj.slots[i]] = True
        else:
            pending.append((first, [(i, r.rid) for i, r in enumerate(pj.batch)]))
            for i, r in enumerate(pj.batch):
                if sched.record_emitted(r.rid, now=step):
                    pool.release(pj.slots[i])
                else:
                    active[pj.slots[i]] = True
        if self._trie is not None:
            ev0 = self._trie.evictions
            for i, r in enumerate(pj.batch):
                self._trie.insert(r.prompt, int(pj.plens[i]), pj.caches, i)
                if pj.nodes[i]:
                    self._trie.release(pj.nodes[i])
            if self._trie.evictions > ev0:
                _obs.counter("serve.prefix_cache.evictions").inc(
                    self._trie.evictions - ev0
                )
            _obs.gauge("serve.prefix_cache.cached_tokens").set(
                self._trie.cached_tokens
            )
            self._prefix_hwm = max(self._prefix_hwm, self._trie.cached_tokens)
            _obs.gauge("serve.prefix_cache.hwm_tokens").set(self._prefix_hwm)
        return tok, pos, n_gen

    def _join(
        self,
        sched: Scheduler,
        pool: SlotPool,
        batch: List[Request],
        tok: jax.Array,
        pos: jax.Array,
        active: List[bool],
        key: jax.Array,
        step: int,
        on_token,
        sync: bool,
        pending,
    ):
        """Prefill one bucket, scatter it into leased slots, seed the lanes."""
        lb = sched.bucket(max(len(r.prompt) for r in batch))
        # Round the row count up to a power of two so prefill compiles stay
        # bounded per bucket (filler rows duplicate row 0 and scatter-drop).
        rows = 1
        while rows < len(batch):
            rows *= 2
        tokens = np.zeros((rows, lb), np.int32)
        lengths = np.ones((rows,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, : len(r.prompt)] = np.asarray(r.prompt, np.int32)
            lengths[i] = len(r.prompt)
        if rows > len(batch):
            tokens[len(batch):] = tokens[0]
            lengths[len(batch):] = lengths[0]

        args = (self.params, jnp.asarray(tokens), jnp.asarray(lengths))
        t_pf = time.perf_counter()
        with _attr.capture_gemms() as pf_recs:
            logits, caches = self._prefill(*args)
        wl = self._step_workload((rows, lb), self._prefill, args, pf_recs, "prefill")
        if wl:
            _attr.observe_step(wl, time.perf_counter() - t_pf)
        first = sample_token(logits, key, self.temperature)

        slots = pool.allocate([r.rid for r in batch])
        sched.admit(batch, slots, now=step)
        for r, s in zip(batch, slots):
            _tracing.set_slot(r.uid, s)
        pool.join(caches, slots)

        slot_idx = jnp.asarray(slots, jnp.int32)
        tok = tok.at[slot_idx].set(first[: len(batch)])
        pos = pos.at[slot_idx].set(jnp.asarray(lengths[: len(batch)]))
        n_gen = len(batch)
        if sync:
            first_host = np.asarray(first[:, 0])
            for i, r in enumerate(batch):
                t = int(first_host[i])
                if on_token is not None:
                    on_token(r.rid, t)
                if sched.record_token(r.rid, t, now=step):
                    pool.release(slots[i])  # one-token request: retire at join
                else:
                    active[slots[i]] = True
        else:
            pending.append((first, [(i, r.rid) for i, r in enumerate(batch)]))
            for i, r in enumerate(batch):
                if sched.record_emitted(r.rid, now=step):
                    pool.release(slots[i])
                else:
                    active[slots[i]] = True
        return tok, pos, active, n_gen


@functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(3,))
def _attach_prefix_jit(caches, spans, row, attach: int):
    out = []
    for c, sp in zip(caches, spans):
        if sp is None or not hasattr(c, "k"):
            out.append(c)
            continue
        k, v = sp
        out.append(
            c._replace(
                k=jax.lax.dynamic_update_slice(
                    c.k,
                    k[:, :attach].astype(c.k.dtype)[:, None],
                    (0, row, 0, 0),
                ),
                v=jax.lax.dynamic_update_slice(
                    c.v,
                    v[:, :attach].astype(c.v.dtype)[:, None],
                    (0, row, 0, 0),
                ),
            )
        )
    return tuple(out)


def _attach_prefix(caches, spans, row: int, attach: int):
    """Write a gathered prefix span into one row of standalone prefill
    caches: positions ``[0:attach]`` of every attention entry. The span may
    run past ``attach`` (the trie matched beyond the chunk-aligned snap) —
    the excess is simply not attached.

    Donated jit: the standalone stack is freshly initialized and threaded
    through repeated attaches, so XLA updates it in place instead of copying
    the whole pool-sized buffer per row. ``row`` is traced (one program
    serves every lane); compile shapes key on the span/attach bucket, like
    the chunk-prefill programs — the decode step is untouched."""
    return _attach_prefix_jit(caches, spans, jnp.int32(row), int(attach))


def _zero_floors(rows: int, fls):
    """Host-side zero scale floors, per entry ``[n_periods, rows, n_kv]`` —
    rows that attach a quantized prefix overwrite their lane."""
    out = []
    for f in fls:
        if f is None:
            out.append(None)
        else:
            p, n_kv = np.asarray(f[0]).shape
            out.append(
                (
                    np.zeros((p, rows, n_kv), np.float32),
                    np.zeros((p, rows, n_kv), np.float32),
                )
            )
    return out
