"""Continuous-batching serving engine: keep every decode lane busy.

The static ``ServeEngine`` admits a batch, decodes until the *longest*
request finishes, and only then admits more — decode GEMMs shrink as
requests retire, starving the engine exactly the way low-utilization
baselines starve their MAC arrays in the paper. ``ContinuousEngine``
instead drives **one fused jit decode step over a fixed slot pool with an
active-slot mask**: a finished request frees its slot mid-flight, the next
queued request is prefilled (length-bucketed compiled steps) and scattered
in, and the decode step never recompiles — a masked slot costs one batch
lane, not a new program. Slot occupancy is the serving analogue of the
paper's FPU utilization, and the engine reports it next to tokens/sec.

Step loop (one tick = one fused decode dispatch):

1. **join** — while slots are free and arrived requests queue, prefill one
   prompt-length bucket (``api.prefill_bucketed``), sample each request's
   first token from its last-real-token logits, scatter caches into leased
   slots (`SlotPool.join`), and point the lanes at their positions.
2. **decode** — one jit'd ``decode_at`` + sample over all ``n_slots`` lanes
   (inactive lanes are masked: they hold their token and position).
3. **evict** — stream each active lane's sampled token to its request;
   EOS / max-token requests retire and free their slot for the next tick.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.obs import attr as _attr
from repro.configs.base import ArchConfig
from repro.models import api as model_api

from .cache import SlotPool
from .engine import sample_token
from .scheduler import Request, Scheduler

__all__ = ["ContinuousEngine", "ServingReport"]


@dataclasses.dataclass
class ServingReport:
    """Outcome + the utilization counters the paper's story maps onto."""

    outputs: Dict[int, List[int]]  # rid -> generated tokens
    generated_tokens: int
    decode_steps: int
    prefill_batches: int
    mean_occupancy: float  # mean active-slot fraction per decode step
    wall_time_s: float
    kv_bytes_per_slot: float = 0.0  # K/V pool bytes per slot (+ quant scales)
    # Host-observed latency percentiles (seconds), or ``None`` when the run
    # produced no samples — "no data" must never masquerade as "zero
    # latency" (JSON renders it as null). TTFT = wall clock from the
    # request's arrival tick to its first token (sampled from prefill logits
    # at join, so queueing + prefill dominate); ITL = wall clock between a
    # lane's consecutive tokens. On the deferred-detokenization path (no EOS,
    # no streaming callback) decode dispatches are async, so ITL measures
    # host dispatch cadence, not device step latency — the sync path (EOS or
    # ``on_token``) measures true token-to-token wall time.
    ttft_p50: Optional[float] = None
    ttft_p99: Optional[float] = None
    itl_p50: Optional[float] = None
    itl_p99: Optional[float] = None

    @property
    def tokens_per_sec(self) -> float:
        return self.generated_tokens / self.wall_time_s if self.wall_time_s else 0.0

    @property
    def tokens_per_step(self) -> float:
        """Useful tokens per decode dispatch — the deterministic (wall-clock
        free) throughput proxy; == n_slots * mean occupancy up to the tokens
        sampled directly from prefill logits."""
        return self.generated_tokens / self.decode_steps if self.decode_steps else 0.0


@dataclasses.dataclass
class ContinuousEngine:
    """Continuous-batching engine over ``n_slots`` pooled decode lanes.

    LM families only (dense / moe / hybrid / ssm): requests are token
    prompts. The static ``ServeEngine`` remains the simple lockstep path
    (and the audio/VLM entry point).
    """

    cfg: ArchConfig
    params: Any
    n_slots: int
    max_len: int
    cache_dtype: Any = jnp.bfloat16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    exact_buckets: Optional[bool] = None  # None = auto (exact iff recurrent)
    # Narrow K/V lanes for the slot pool ("int8" / "fp8_e4m3" / "fp8_e5m2"):
    # ~4x less cache memory per slot (vs fp32 lanes), so the same HBM budget
    # admits proportionally more slots. Prefill stays full-precision; the
    # join scatter calibrates per-slot scales and quantizes (see serve.cache).
    kv_format: Optional[str] = None

    def __post_init__(self) -> None:
        cfg = self.cfg
        if cfg.family in ("audio", "vlm"):
            # audio needs encoder frames, vlm per-request image embeddings —
            # neither fits the token-prompt Request; serving them here would
            # silently drop the non-token inputs.
            raise NotImplementedError(
                f"ContinuousEngine serves token-prompt LM families; use "
                f"ServeEngine for {cfg.family}"
            )
        if cfg.moe is not None and not cfg.moe.dropless:
            # Token-choice capacity dropping routes by whole-batch content:
            # one request's load would change another's outputs. Dropless
            # routing is per-token, keeping slots independent.
            warnings.warn(
                "continuous batching with capacity-dropping MoE couples "
                "requests through the router; set moe.dropless for "
                "request-isolated serving",
                RuntimeWarning,
                stacklevel=2,
            )

        @functools.partial(jax.jit, static_argnums=())
        def _prefill(params, tokens, lengths):
            logits, caches = model_api.prefill_bucketed(
                cfg, params, tokens, lengths, self.cache_dtype
            )
            return logits, caches

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, caches, tok, pos, active, key):
            logits, caches = model_api.decode_at(cfg, params, tok, caches, pos)
            nxt = sample_token(logits, key, self.temperature)
            # Masked slots cost a lane, not a recompile: they hold token and
            # position so the step's shapes/program never change.
            nxt = jnp.where(active[:, None], nxt, tok)
            pos = pos + active.astype(jnp.int32)
            return nxt, caches, pos

        self._prefill = _prefill
        self._decode = _decode
        # Utilization-attribution state (obs.attr): the GEMM workload of each
        # compiled step, captured once at trace time, then charged with every
        # subsequent dispatch's measured wall time. Keyed per compiled
        # program: one decode step; prefills per (rows, bucket).
        self._decode_workload = None
        self._prefill_workloads: Dict[tuple, dict] = {}

    # -- introspection -----------------------------------------------------

    def decode_compilations(self) -> Optional[int]:
        """Number of compiled decode programs (None if jax hides the cache)."""
        try:
            return int(self._decode._cache_size())
        except Exception:
            return None

    # -- serving -----------------------------------------------------------

    def serve(
        self,
        requests: List[Request],
        *,
        key: Optional[jax.Array] = None,
        on_token: Optional[Callable[[int, int], None]] = None,
        max_steps: Optional[int] = None,
    ) -> ServingReport:
        """Run ``requests`` to completion; returns outputs + counters.

        ``on_token(rid, token)`` streams every sampled token as soon as the
        host sees it (one fused step behind the device).
        """
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new {r.max_new_tokens} exceeds max_len {self.max_len}"
                )
        key = key if key is not None else jax.random.key(0)
        sched = Scheduler(
            self.cfg,
            eos_id=self.eos_id,
            exact_buckets=self.exact_buckets,
            max_bucket=self.max_len,
        )
        for r in requests:
            sched.submit(r)
        pool = SlotPool.create(
            self.cfg, self.n_slots, self.max_len, self.cache_dtype,
            kv_format=self.kv_format,
        )
        self._last_kv_bytes_per_slot = pool.kv_bytes_per_slot()

        b = self.n_slots
        tok = jnp.zeros((b, 1), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        active = [False] * b  # host truth; device mask derived on change
        active_dev = jnp.asarray(active)

        # Without EOS eviction or a streaming callback, retirement depends
        # only on token *counts* — so the loop never reads token values and
        # decode dispatches pipeline freely; values are fetched once at the
        # end (deferred detokenization). With EOS/streaming, every step
        # syncs on the sampled tokens.
        sync = on_token is not None or self.eos_id is not None
        pending = []  # (device tokens [*, 1], [(row, rid), ...]) per step

        # Lifecycle wall stamps (always kept — the report's percentile fields
        # are product, not telemetry; only the obs emission is gated). A
        # request's clock starts when the loop reaches its arrival tick.
        wall = time.perf_counter
        by_arrival = sorted(requests, key=lambda r: r.arrival)
        n_arrival_stamped = 0
        arrive_wall: Dict[int, float] = {}
        last_tok_wall: Dict[int, float] = {}
        ttfts: List[float] = []
        itls: List[float] = []

        step = 0
        decode_steps = 0
        prefill_batches = 0
        generated = 0
        occupancy_acc = 0.0
        limit = max_steps if max_steps is not None else (
            sum(r.arrival + r.max_new_tokens for r in requests) + 10 * self.max_len
        )

        while not (sched.drained and pool.n_active == 0):
            if step > limit:
                raise RuntimeError(f"serving did not drain within {limit} steps")
            while (
                n_arrival_stamped < len(by_arrival)
                and by_arrival[n_arrival_stamped].arrival <= step
            ):
                arrive_wall[by_arrival[n_arrival_stamped].rid] = wall()
                n_arrival_stamped += 1

            # -- join: refill free slots from the queue ---------------------
            joined = False
            while pool.n_free:
                batch = sched.next_batch(pool.n_free, now=step)
                if not batch:
                    break
                if self.temperature > 0:
                    key, sub = jax.random.split(key)
                else:
                    sub = key  # greedy: sampling ignores the key
                tok, pos, active, n_gen = self._join(
                    sched, pool, batch, tok, pos, active, sub, step, on_token,
                    sync, pending,
                )
                prefill_batches += 1
                generated += n_gen  # one token per request from prefill logits
                joined = True
                # First token exists now (sampled from prefill logits): the
                # join stamp closes each admitted request's TTFT window.
                now = wall()
                _obs.counter("serve.requests", event="admitted").inc(len(batch))
                for r in batch:
                    ttft = now - arrive_wall.get(r.rid, now)
                    ttfts.append(ttft)
                    last_tok_wall[r.rid] = now
                    _obs.histogram("serve.ttft_seconds").observe(ttft)
                    if sched.states[r.rid].done:  # one-token request
                        _obs.counter("serve.requests", event="retired").inc()
            if joined:
                active_dev = jnp.asarray(active)

            if not any(active):
                if sched.drained:
                    break
                step += 1  # idle tick: wait for the next arrival
                continue

            # -- decode: one fused masked step over the whole pool ----------
            t_step = wall()
            n_live = sum(active)
            if self.temperature > 0:
                key, sub = jax.random.split(key)
            else:
                sub = key
            with _attr.capture_gemms() as step_recs:
                tok, pool.caches, pos = self._decode(
                    self.params, pool.caches, tok, pos, active_dev, sub
                )
            if step_recs:
                # This dispatch traced (records only appear at trace time):
                # remember the step's GEMM workload, but skip attributing
                # this tick — its wall bracket includes trace + compile.
                self._decode_workload = _attr.aggregate(step_recs)
            decode_steps += 1
            occupancy_acc += n_live / self.n_slots
            step += 1

            # -- evict: stream tokens, retire finished requests -------------
            live = [s for s in pool.active_slots() if active[s]]
            live_rids = [pool.owner_of(s) for s in live]
            n_retired = 0
            changed = False
            if sync:
                emitted = np.asarray(tok[:, 0])
                for slot, rid in zip(live, live_rids):
                    t = int(emitted[slot])
                    if on_token is not None:
                        on_token(rid, t)
                    generated += 1
                    if sched.record_token(rid, t, now=step):
                        pool.release(slot)
                        active[slot] = False
                        changed = True
                        n_retired += 1
            else:
                pending.append((tok, list(zip(live, live_rids))))
                for slot, rid in zip(live, live_rids):
                    generated += 1
                    if sched.record_emitted(rid, now=step):
                        pool.release(slot)
                        active[slot] = False
                        changed = True
                        n_retired += 1
            if changed:
                active_dev = jnp.asarray(active)

            # Per-tick telemetry: step wall time, each live lane's
            # inter-token gap, queue/occupancy gauges.
            now = wall()
            _obs.histogram("serve.step_seconds").observe(now - t_step)
            if not step_recs and self._decode_workload:
                # Same host-wall caveat as ITL: on the deferred path this is
                # dispatch cadence, on the sync path token-to-token time.
                _attr.observe_step(self._decode_workload, now - t_step)
            for rid in live_rids:
                prev = last_tok_wall.get(rid)
                if prev is not None:
                    itl = now - prev
                    itls.append(itl)
                    _obs.histogram("serve.itl_seconds").observe(itl)
                last_tok_wall[rid] = now
            _obs.counter("serve.tokens").inc(len(live_rids))
            if n_retired:
                _obs.counter("serve.requests", event="retired").inc(n_retired)
            _obs.gauge("serve.queue_depth").set(sched.n_arrived(step))
            _obs.gauge("serve.occupancy").set(n_live / self.n_slots)

        # Deferred fetch: one host sync for the whole run.
        for arr, pairs in pending:
            vals = np.asarray(arr[:, 0])
            for row, rid in pairs:
                sched.states[rid].tokens.append(int(vals[row]))
        jax.block_until_ready(tok)
        outputs = {rid: st.tokens for rid, st in sched.states.items()}
        report = ServingReport(
            outputs=outputs,
            generated_tokens=generated,
            decode_steps=decode_steps,
            prefill_batches=prefill_batches,
            mean_occupancy=(occupancy_acc / decode_steps) if decode_steps else 0.0,
            wall_time_s=0.0,  # stamped by timed_serve
            kv_bytes_per_slot=self._last_kv_bytes_per_slot,
            ttft_p50=_obs.percentile(ttfts, 50),
            ttft_p99=_obs.percentile(ttfts, 99),
            itl_p50=_obs.percentile(itls, 50),
            itl_p99=_obs.percentile(itls, 99),
        )
        _obs.event(
            "serving_report",
            requests=len(requests),
            generated_tokens=report.generated_tokens,
            decode_steps=report.decode_steps,
            mean_occupancy=report.mean_occupancy,
            ttft_p50=report.ttft_p50,
            ttft_p99=report.ttft_p99,
            itl_p50=report.itl_p50,
            itl_p99=report.itl_p99,
        )
        return report

    def timed_serve(self, requests: List[Request], **kw) -> ServingReport:
        t0 = time.perf_counter()
        report = self.serve(requests, **kw)
        report.wall_time_s = time.perf_counter() - t0
        return report

    # -- internals ---------------------------------------------------------

    def _join(
        self,
        sched: Scheduler,
        pool: SlotPool,
        batch: List[Request],
        tok: jax.Array,
        pos: jax.Array,
        active: List[bool],
        key: jax.Array,
        step: int,
        on_token,
        sync: bool,
        pending,
    ):
        """Prefill one bucket, scatter it into leased slots, seed the lanes."""
        lb = sched.bucket(max(len(r.prompt) for r in batch))
        # Round the row count up to a power of two so prefill compiles stay
        # bounded per bucket (filler rows duplicate row 0 and scatter-drop).
        rows = 1
        while rows < len(batch):
            rows *= 2
        tokens = np.zeros((rows, lb), np.int32)
        lengths = np.ones((rows,), np.int32)
        for i, r in enumerate(batch):
            tokens[i, : len(r.prompt)] = np.asarray(r.prompt, np.int32)
            lengths[i] = len(r.prompt)
        if rows > len(batch):
            tokens[len(batch):] = tokens[0]
            lengths[len(batch):] = lengths[0]

        t_pf = time.perf_counter()
        with _attr.capture_gemms() as pf_recs:
            logits, caches = self._prefill(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths)
            )
        if pf_recs:
            self._prefill_workloads[(rows, lb)] = _attr.aggregate(pf_recs)
        else:
            wl = self._prefill_workloads.get((rows, lb))
            if wl:
                _attr.observe_step(wl, time.perf_counter() - t_pf)
        first = sample_token(logits, key, self.temperature)

        slots = pool.allocate([r.rid for r in batch])
        sched.admit(batch, slots, now=step)
        pool.join(caches, slots)

        slot_idx = jnp.asarray(slots, jnp.int32)
        tok = tok.at[slot_idx].set(first[: len(batch)])
        pos = pos.at[slot_idx].set(jnp.asarray(lengths[: len(batch)]))
        n_gen = len(batch)
        if sync:
            first_host = np.asarray(first[:, 0])
            for i, r in enumerate(batch):
                t = int(first_host[i])
                if on_token is not None:
                    on_token(r.rid, t)
                if sched.record_token(r.rid, t, now=step):
                    pool.release(slots[i])  # one-token request: retire at join
                else:
                    active[slots[i]] = True
        else:
            pending.append((first, [(i, r.rid) for i, r in enumerate(batch)]))
            for i, r in enumerate(batch):
                if sched.record_emitted(r.rid, now=step):
                    pool.release(slots[i])
                else:
                    active[slots[i]] = True
        return tok, pos, active, n_gen
