"""Batched serving: prefill + greedy/temperature decode over the KV caches.

``ServeEngine`` compiles one prefill step and one decode step per
(batch, prompt_len, max_len) bucket and runs requests through them. The
decode step is a single fused jit (cache update + attention + sampling), so
steady-state serving is one dispatch per token — the structure the decode_32k
/ long_500k dry-run cells lower at production shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api

__all__ = ["ServeEngine", "sample_token"]


def sample_token(logits: jax.Array, key, temperature: float = 0.0) -> jax.Array:
    """logits [B, V] -> [B, 1] token (greedy at temperature 0)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1)[
        :, None
    ].astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    cfg: ArchConfig
    params: Any
    max_len: int
    cache_dtype: Any = jnp.bfloat16
    temperature: float = 0.0

    def __post_init__(self):
        cfg = self.cfg

        @jax.jit
        def _prefill(params, batch):
            return model_api.prefill(
                cfg, params, batch, self.max_len, self.cache_dtype
            )

        @functools.partial(jax.jit, donate_argnums=(2,))
        def _decode(params, token, caches, pos, key):
            logits, caches = model_api.decode(cfg, params, token, caches, pos)
            nxt = sample_token(logits, key, self.temperature)
            return nxt, caches

        self._prefill = _prefill
        self._decode = _decode

    def generate(
        self,
        batch: Dict[str, jax.Array],
        n_tokens: int,
        *,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Prefill the prompt batch and decode ``n_tokens`` greedily.

        Returns generated tokens [B, n_tokens].
        """
        key = key if key is not None else jax.random.key(0)
        sampling = self.temperature > 0
        logits, caches = self._prefill(self.params, batch)
        # Split before the first sample: consuming `key` directly and then
        # re-splitting it for step 0 correlates the first two sampled tokens
        # at temperature > 0. (Greedy decoding ignores the key entirely.)
        if sampling:
            key, sub = jax.random.split(key)
        else:
            sub = key
        tok = sample_token(logits, sub, self.temperature)
        pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        out = [tok]
        for i in range(n_tokens - 1):
            if sampling:
                key, sub = jax.random.split(key)
            tok, caches = self._decode(self.params, tok, caches, pos + i, sub)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
