"""Request queue + admission policy for the continuous-batching engine.

FIFO with bucketed prompt lengths: the queue is strictly arrival-ordered;
when decode slots free up, admission takes the oldest *arrived* request,
derives its prompt-length bucket, and greedily collects further arrived
requests of the same bucket (in FIFO order) up to the free-slot count — so
one compiled prefill step serves the whole join and the decode pool refills
in a single scatter. Requests of other buckets keep their queue position.

Buckets are powers of two by default (one compiled prefill per bucket,
right-padding handled by ``models.api.prefill_bucketed``). Families with
recurrent mixers (mamba / xlstm) get *exact-length* buckets: padding would
flow through the recurrent state, so those prompts only share a prefill with
equal-length peers.

Eviction policy lives here too (:meth:`Scheduler.should_finish`): a request
retires on EOS or on reaching ``max_new_tokens``, freeing its slot for the
next join without touching any other lane.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.configs.base import ArchConfig

__all__ = [
    "Request",
    "RequestState",
    "Scheduler",
    "bucket_length",
    "gen_len_spread",
    "poisson_trace",
    "shared_prefix_trace",
]


# Process-wide monotonic uid source: `rid` is the caller's name for a
# request (benchmarks reuse the same rids across warmup/timed replays), so
# per-request telemetry keys on `uid` instead — unique across every Request
# ever constructed in this process.
_UIDS = itertools.count(1)


@dataclasses.dataclass
class Request:
    """One serving request. ``arrival`` is in scheduler clock ticks (one tick
    per engine decode step), so traces replay deterministically. ``uid`` is
    assigned monotonically at construction and is the stable key every
    per-request trace/log record carries (see :mod:`repro.obs.tracing`)."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: int = 0
    uid: int = dataclasses.field(default_factory=lambda: next(_UIDS))

    def __post_init__(self) -> None:
        if not len(self.prompt):
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")


@dataclasses.dataclass
class RequestState:
    """Per-request tracking while (and after) a request holds a slot.

    ``n_emitted`` counts sampled tokens; ``tokens`` holds their values. The
    engine's pipelined path defers fetching values to the end of the run, so
    ``n_emitted`` can run ahead of ``len(tokens)`` mid-flight.
    """

    request: Request
    slot: int
    joined_at: int  # engine step of the join
    tokens: List[int] = dataclasses.field(default_factory=list)
    n_emitted: int = 0
    finished_at: Optional[int] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def uid(self) -> int:
        return self.request.uid


def bucket_length(
    n: int, *, exact: bool = False, minimum: int = 8,
    maximum: Optional[int] = None,
) -> int:
    """Prompt-length -> bucket: next power of two, floored at ``minimum`` and
    clamped to ``maximum`` (the pool's max_len — a bucket longer than the KV
    buffers could never scatter in)."""
    if exact:
        return n
    b = minimum
    while b < n:
        b *= 2
    if maximum is not None:
        b = min(b, maximum)
    return max(b, n)


def _has_recurrent(cfg: ArchConfig) -> bool:
    return any(bd.mixer in ("mamba", "mlstm", "slstm") for bd in cfg.pattern)


class Scheduler:
    """FIFO queue with bucketed-prompt admission and EOS/max-token eviction."""

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        eos_id: Optional[int] = None,
        exact_buckets: Optional[bool] = None,
        min_bucket: int = 8,
        max_bucket: Optional[int] = None,
    ) -> None:
        self.cfg = cfg
        self.eos_id = eos_id
        # Padding flows through recurrent state, so mamba/xlstm families
        # only batch prompts of identical length into one prefill.
        self.exact_buckets = (
            _has_recurrent(cfg) if exact_buckets is None else exact_buckets
        )
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self._queue: Deque[Request] = deque()
        self.states: Dict[int, RequestState] = {}  # rid -> state
        # Admission side-channel for the engine's tracer: set by every
        # next_batch() that returns a batch — {"bucket": join bucket,
        # "fallthrough": head was blocked and admission fell through to a
        # deeper bucket}; None when the last call returned [].
        self.last_admission: Optional[Dict[str, object]] = None

    # -- queue -------------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.rid in self.states or any(
            r.rid == request.rid for r in self._queue
        ):
            raise ValueError(f"duplicate request id {request.rid}")
        self._queue.append(request)

    def __len__(self) -> int:
        return len(self._queue)

    def n_arrived(self, now: int) -> int:
        return sum(1 for r in self._queue if r.arrival <= now)

    @property
    def drained(self) -> bool:
        """True when the queue is empty and every admitted request finished."""
        return not self._queue and all(s.done for s in self.states.values())

    def bucket(self, prompt_len: int) -> int:
        return bucket_length(
            prompt_len, exact=self.exact_buckets, minimum=self.min_bucket,
            maximum=self.max_bucket,
        )

    # -- admission ---------------------------------------------------------

    def next_batch(
        self, max_n: int, now: int, admissible=None
    ) -> List[Request]:
        """Pop up to ``max_n`` arrived requests sharing one bucket.

        The head-of-line request keeps strict FIFO priority whenever it is
        admissible: the join bucket is then the head's, and same-bucket
        arrivals ride along. But admitting *only* from the literal head
        starved whole buckets: with the head un-admittable (e.g. its prompt
        needs a pipeline stage that is full), arrived requests in every
        other bucket waited behind it while slots sat free. With the head
        blocked, admission now falls through to the **deepest non-empty
        admissible bucket** (longest prompts first — they have the most
        remaining work to pipeline); skipped requests keep their queue
        position.

        ``admissible`` is an optional ``Request -> bool`` predicate supplied
        by the engine (e.g. "this prompt's remaining prefill fits the chunk
        pipeline right now"). Returns [] when nothing admissible has arrived
        or no slot is free.
        """
        self.last_admission = None
        if max_n <= 0:
            return []
        ok = admissible if admissible is not None else (lambda r: True)
        head = next((r for r in self._queue if r.arrival <= now), None)
        if head is None:
            return []
        fallthrough = not ok(head)
        if not fallthrough:
            want = self.bucket(len(head.prompt))
        else:
            candidates = [
                r for r in self._queue if r.arrival <= now and ok(r)
            ]
            if not candidates:
                return []
            want = max(self.bucket(len(r.prompt)) for r in candidates)
        batch: List[Request] = []
        for r in list(self._queue):
            if len(batch) >= max_n:
                break
            if (
                r.arrival <= now
                and self.bucket(len(r.prompt)) == want
                and ok(r)
            ):
                batch.append(r)
                self._queue.remove(r)
        if batch:
            self.last_admission = {"bucket": want, "fallthrough": fallthrough}
        return batch

    def admit(self, requests: List[Request], slots: List[int], now: int) -> None:
        for r, s in zip(requests, slots):
            self.states[r.rid] = RequestState(request=r, slot=s, joined_at=now)

    # -- eviction ----------------------------------------------------------

    def record_token(self, rid: int, token: int, now: int) -> bool:
        """Append a sampled token; returns True when the request retires."""
        st = self.states[rid]
        st.tokens.append(token)
        st.n_emitted += 1
        if self.should_finish(st, token):
            st.finished_at = now
            return True
        return False

    def record_emitted(self, rid: int, now: int) -> bool:
        """Count an emitted token whose value is fetched later (pipelined
        path, only valid without EOS eviction); True when the request
        retires on its max-token budget."""
        assert self.eos_id is None
        st = self.states[rid]
        st.n_emitted += 1
        if st.n_emitted >= st.request.max_new_tokens:
            st.finished_at = now
            return True
        return False

    def should_finish(self, st: RequestState, token: int) -> bool:
        if self.eos_id is not None and token == self.eos_id:
            return True
        return st.n_emitted >= st.request.max_new_tokens


def gen_len_spread(max_gen: int):
    """Small spread of generation budgets for demo traces, all <= max_gen
    (so ``prompt + budget <= prompt + max_gen`` sizing always holds)."""
    return tuple(sorted({max(1, max_gen // 4), max(1, max_gen // 2), max_gen}))


def poisson_trace(
    n_requests: int,
    *,
    seed: int = 0,
    vocab: int = 256,
    prompt_lens: Sequence[int] = (6, 12, 17, 24, 32),
    gen_lens: Sequence[int] = (4, 8, 12, 24, 48),
    mean_interarrival: float = 0.0,
) -> List[Request]:
    """Deterministic mixed-length request trace with Poisson-ish arrivals.

    Arrival gaps are exponential with mean ``mean_interarrival`` (in decode
    steps; 0 = a burst that saturates the pool immediately). Prompt tokens,
    lengths, and generation budgets are drawn from a seeded generator so the
    same trace drives the static and continuous engines in the benchmark.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        if mean_interarrival > 0:
            t += rng.exponential(mean_interarrival)
        plen = int(rng.choice(prompt_lens))
        out.append(
            Request(
                rid=rid,
                prompt=[int(x) for x in rng.integers(0, vocab, plen)],
                max_new_tokens=int(rng.choice(gen_lens)),
                arrival=int(t),
            )
        )
    return out


def shared_prefix_trace(
    n_requests: int,
    *,
    seed: int = 0,
    vocab: int = 256,
    prefix_len: int = 96,
    tail_lens: Sequence[int] = (8, 12, 16),
    gen_lens: Sequence[int] = (4, 8, 12),
    mean_interarrival: float = 0.0,
) -> List[Request]:
    """Poisson-ish trace where every prompt opens with one shared system
    prompt of ``prefix_len`` tokens followed by a unique per-request tail —
    the chat-serving shape the prefix cache exists for. Request 0 pays the
    cold prefill; once its blocks are inserted, every later join resumes
    from the cached prefix and chunk-prefills only its tail."""
    import numpy as np

    rng = np.random.default_rng(seed)
    prefix = [int(x) for x in rng.integers(0, vocab, prefix_len)]
    t = 0.0
    out = []
    for rid in range(n_requests):
        if mean_interarrival > 0:
            t += rng.exponential(mean_interarrival)
        tail_len = int(rng.choice(tail_lens))
        tail = [int(x) for x in rng.integers(0, vocab, tail_len)]
        out.append(
            Request(
                rid=rid,
                prompt=prefix + tail,
                max_new_tokens=int(rng.choice(gen_lens)),
                arrival=int(t),
            )
        )
    return out
