"""Training substrate: fault-tolerant loop + step factory."""
from .loop import TrainLoopConfig, TrainResult, make_train_step, train
__all__ = ["TrainLoopConfig", "TrainResult", "make_train_step", "train"]
