"""Fault-tolerant training loop.

Responsibilities:

* **step function factory** — builds the jitted train step for an arch config
  (loss -> grad -> AdamW), with gradient accumulation (``cfg.grad_accum``
  microbatches via ``lax.scan``; grok-1 needs 8x to fit activations) and
  optional donation of params/opt state.
* **checkpoint/restart** — auto-resumes from the newest complete checkpoint;
  `AsyncCheckpointer` writes every ``ckpt_every`` steps off-thread. Because
  the data pipeline is step-indexed and deterministic, a restart replays the
  exact token stream (verified in tests by killing mid-run).
* **straggler watchdog** — flags steps slower than ``watchdog_factor`` x the
  running median (on a real fleet this triggers hot-spare swap; here it logs
  and counts, and tests inject a synthetic stall).
* **elastic re-scale** — a checkpoint written on one mesh restores onto
  another (host-side full arrays; see checkpoint.restore).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.configs.base import ArchConfig
from repro.core import roofline as _roofline
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig, OptState, apply_updates, init_opt_state
from repro.checkpoint import checkpoint as ckpt

__all__ = ["TrainLoopConfig", "make_train_step", "train", "TrainResult"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    watchdog_factor: float = 3.0
    fail_at_step: Optional[int] = None  # fault-injection hook (tests)


@dataclasses.dataclass
class TrainResult:
    losses: list
    resumed_from: Optional[int]
    straggler_steps: int
    final_step: int


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    donate: bool = True,
    jit: bool = True,
    policy=None,
) -> Callable:
    """Returns jit'd ``(params, opt_state, batch) -> (params, opt_state, metrics)``.

    With ``cfg.grad_accum > 1`` the global batch's leading dim is split into
    microbatches scanned sequentially, accumulating fp32 grads — the
    activation-memory lever that fits grok-1's 1M-token steps.

    ``policy`` (a backend name or :class:`repro.quant.PrecisionPolicy`)
    selects per-role forward matmul precision. The fp32 master path is
    untouched by any policy: gradients route through each backend's
    registered full-precision grad backend, accumulation stays fp32, and the
    optimizer moments/updates never see a quantized value.
    """

    def loss(params, batch):
        return model_api.loss_fn(cfg, params, batch, backend=policy)

    def step(params, opt_state, batch):
        n_micro = cfg.grad_accum
        if n_micro <= 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )

            def accum(carry, mb):
                tot, g = carry
                li, gi = jax.value_and_grad(loss)(params, mb)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g, gi
                )
                return (tot + li, g), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (tot, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), g0), micro)
            l = tot / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics["loss"] = l
        return params, opt_state, metrics

    if not jit:
        return step
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class _Watchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.times: list = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = (
            len(self.times) >= 5
            and dt > self.factor * statistics.median(self.times)
        )
        self.times.append(dt)
        if len(self.times) > 50:
            self.times.pop(0)
        if slow:
            self.flagged += 1
        return slow


def train(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    loop: TrainLoopConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    init_key: Optional[jax.Array] = None,
    params: Any = None,
    log: Callable[[str], None] = print,
    policy=None,
) -> TrainResult:
    """Run (or resume) training. ``batch_fn(step)`` must be deterministic."""
    if params is None:
        if init_key is None:
            init_key = jax.random.key(0)
        params = model_api.init_params(cfg, init_key)
    opt_state = init_opt_state(
        params, dataclasses.replace(opt_cfg, moment_dtype=cfg.moment_dtype)
    )
    opt_cfg = dataclasses.replace(opt_cfg, moment_dtype=cfg.moment_dtype)

    start = 0
    resumed_from = None
    writer = None
    if loop.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(loop.ckpt_dir)
        last = ckpt.latest_step(loop.ckpt_dir)
        if last is not None:
            state = ckpt.restore(
                loop.ckpt_dir, last, like={"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            start = last
            resumed_from = last
            log(f"[train] resumed from step {last}")

    step_fn = make_train_step(cfg, opt_cfg, policy=policy)
    wd = _Watchdog(loop.watchdog_factor)
    losses = []
    # Per-step telemetry baseline: parameter count for the 6*N*D train-FLOP
    # estimate (core.roofline.model_flops), so each step event carries
    # achieved GFLOP/s and its fraction of the reference roofline.
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    try:
        for step in range(start, loop.total_steps):
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            if _obs.enabled():
                tok_arr = batch.get("tokens", next(iter(batch.values())))
                tokens = int(tok_arr.size)
                flops = _roofline.model_flops(n_params, tokens, kind="train")
                tok_s = tokens / dt if dt else 0.0
                gflops = flops / dt / 1e9 if dt else 0.0
                _obs.histogram("train.step_seconds").observe(dt)
                _obs.gauge("train.tokens_per_sec").set(tok_s)
                _obs.event(
                    "train_step",
                    step=step,
                    loss=loss,
                    wall_s=dt,
                    tokens=tokens,
                    tokens_per_sec=tok_s,
                    gflops_per_sec=gflops,
                    roofline_frac=flops / dt / _roofline.TPU_V5E.peak_flops
                    if dt else 0.0,
                )
            if wd.observe(dt):
                log(f"[train] straggler: step {step} took {dt:.3f}s")
                _obs.counter("train.stragglers").inc()
                _obs.event("straggler", step=step, wall_s=dt)
            if loop.log_every and step % loop.log_every == 0:
                log(
                    f"[train] step {step} loss {loss:.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                    f"{dt*1e3:.0f}ms"
                )
            if writer and (step + 1) % loop.ckpt_every == 0:
                writer.save(step + 1, {"params": params, "opt": opt_state})
        if writer:
            writer.save(loop.total_steps, {"params": params, "opt": opt_state})
            writer.wait()
    finally:
        # A failing step must not also lose the checkpoint already in flight:
        # join the async writer so every save issued before the failure is
        # committed (the graceful-shutdown analogue of a SIGTERM flush; a hard
        # kill still loses at most one interval, as documented in checkpoint).
        if writer:
            try:
                writer.wait()
            except Exception as flush_err:  # don't mask the original failure
                log(f"[train] checkpoint flush failed: {flush_err}")
    return TrainResult(
        losses=losses,
        resumed_from=resumed_from,
        straggler_steps=wd.flagged,
        final_step=loop.total_steps,
    )
