"""Autotuning subsystem: measured tile selection for the O-POPE backends.

The paper's utilization story depends on the right tile shapes; the repo's
heuristic (``kernels.opope_gemm.default_block_shape``) is one guess per
shape. This package replaces guessing with measurement, in three parts:

* :mod:`~repro.tune.search` — candidate ``(bm, bn, bk)`` generation pruned
  by the analytic cost model behind ``core.tiling.choose_tile``, then timed
  on-device (compile + warmup + steady state) through the kernels'
  ``block_*=`` parameters;
* :mod:`~repro.tune.table` — the persistent JSON tuning table (keyed by
  backend, shape family, (M, K, N, G), dtype, device kind) that
  ``kernels.ops._tile_for`` consults before the heuristic, with env override
  ``REPRO_TUNE_TABLE`` and hard-constraint validation at lookup;
* :mod:`~repro.tune.capture` — workload harvesting: one ``jax.eval_shape``
  of a ``configs/`` model under ``ops.capture_shapes`` yields its entire
  GEMM shape set, which the ``repro-tune`` CLI (``repro.launch.tune``)
  tunes offline.

``ops.tile_source(backend, m, k, n)`` reports whether a given shape resolves
``"tuned"`` or ``"heuristic"``. The tuner also measures a fused-vs-post-hoc
epilogue probe at each winning tile (``search.probe_epilogue_fusion``) and
records the verdict in ``TuneEntry.fuse_epilogue``; ``ops.fusion_source``
reports whether a shape's fusion decision is ``"tuned"`` or ``"default"``.
"""

from .capture import capture_gemm_shapes, harvest_model_shapes
from .search import (
    PROBE_EPILOGUE,
    TUNABLE_BACKENDS,
    CandidateResult,
    EpilogueProbe,
    candidate_blocks,
    median_time_us,
    probe_epilogue_fusion,
    tune_shape,
    tune_workload,
)
from .table import (
    DEFAULT_TABLE_PATH,
    ENV_VAR,
    GemmShape,
    SCHEMA_VERSION,
    TableFormatError,
    TuneEntry,
    TuneKey,
    TuningTable,
    active_table_path,
    device_kind,
    load_active_table,
)

__all__ = [
    "PROBE_EPILOGUE",
    "TUNABLE_BACKENDS",
    "CandidateResult",
    "EpilogueProbe",
    "candidate_blocks",
    "median_time_us",
    "probe_epilogue_fusion",
    "tune_shape",
    "tune_workload",
    "capture_gemm_shapes",
    "harvest_model_shapes",
    "DEFAULT_TABLE_PATH",
    "ENV_VAR",
    "GemmShape",
    "SCHEMA_VERSION",
    "TableFormatError",
    "TuneEntry",
    "TuneKey",
    "TuningTable",
    "active_table_path",
    "device_kind",
    "load_active_table",
]
