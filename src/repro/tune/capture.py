"""Workload harvesting: which GEMM shapes does a model actually run?

Tuning a serving deployment offline needs the exact (M, K, N, G, dtype)
set its layers push through the registry. Rather than hand-listing them,
``repro.kernels.ops`` grows a shape-capture mode (``ops.capture_shapes``):
every ``matmul`` / ``grouped_matmul`` records its flattened shape at *trace*
time, so one ``jax.eval_shape`` of a model's loss (or prefill) under capture
yields the complete GEMM workload of a ``configs/`` architecture with zero
FLOPs and zero parameter allocation — grok-314b harvests in milliseconds.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Union

from .table import GemmShape

__all__ = ["capture_gemm_shapes", "harvest_model_shapes"]


class capture_gemm_shapes:
    """Context manager yielding the deduped list of :class:`GemmShape` routed
    through the registry inside the block (first-seen order)."""

    def __enter__(self) -> List[GemmShape]:
        from repro.kernels import ops

        self._cm = ops.capture_shapes()
        self._raw = self._cm.__enter__()
        self._out: List[GemmShape] = []
        return self._out

    def __exit__(self, *exc) -> bool:
        self._cm.__exit__(*exc)
        seen = set()
        for family, m, k, n, g, dtype in self._raw:
            shape = GemmShape(family=family, m=m, k=k, n=n, g=g, dtype=dtype)
            if shape not in seen:
                seen.add(shape)
                self._out.append(shape)
        return False


def harvest_model_shapes(
    arch: Union[str, object],
    *,
    batch: int = 1,
    seq: int = 128,
    backend: Optional[str] = None,
) -> List[GemmShape]:
    """Every distinct GEMM shape one training step of ``arch`` runs.

    ``arch`` is a ``configs/`` name or an ``ArchConfig``. ``backend`` is
    threaded through so a :class:`~repro.quant.policy.PrecisionPolicy` (or an
    explicit q8 backend) captures the quantized routing it would really use.
    Abstract evaluation only — no parameters are materialized.
    """
    import jax

    from repro.models import api as model_api

    if isinstance(arch, str):
        from repro.configs import get_config

        cfg = get_config(arch)
    else:
        cfg = arch

    params = jax.eval_shape(
        functools.partial(model_api.init_params, cfg), jax.random.key(0)
    )
    specs = model_api.input_specs(cfg, batch=batch, seq=seq, kind="train")
    with capture_gemm_shapes() as shapes:
        jax.eval_shape(
            functools.partial(model_api.loss_fn, cfg, backend=backend),
            params, specs,
        )
    return shapes
