"""The auto-retune seam: what the registry's drift detectors trigger.

Two sibling hooks in ``kernels.ops`` route here by default:

* ``on_miss_streak`` — a long-lived process keeps resolving tile shapes the
  memo (and, usually, the tuning table) has never seen: the signature of a
  workload the last ``repro-tune`` run did not cover (``reason:
  "miss_streak"``).
* ``on_util_gap`` — a shape the table *does* cover keeps scoring a live
  roofline fraction (``repro.obs.attr``) well below its own best: the
  signature of a tuned entry gone stale (``reason: "util_gap"``).

The default hook deliberately does **not** retune: an in-process search
would steal device time from the serving loop it is trying to help. It
records the candidate — a ``tune.retune_candidates`` counter labelled by
shape family, backend and reason, plus a ``retune_candidate`` event
carrying the full shape key — so an operator (or a future background
tuner, ROADMAP item 4) can run ``repro-tune`` offline against exactly the
shapes that need it.

Processes that *want* an active policy register their own callback::

    from repro.kernels import ops
    ops.on_miss_streak(lambda key, streak: my_queue.put(key), threshold=16)
    ops.on_util_gap(lambda key, streak, frac: my_queue.put(key),
                    threshold=0.5, streak=8)
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro import obs as _obs

__all__ = ["retune_candidate"]

# (backend, shape_family, m, k, n, groups, itemsize) — ops.TileKey.
Key = Tuple[Optional[str], str, int, int, int, int, int]


def retune_candidate(key: Key, streak: int, *,
                     reason: str = "miss_streak") -> None:
    """Record one retune candidate (never retunes implicitly)."""
    if not _obs.enabled():
        return
    backend, family, m, k, n, groups, itemsize = key
    _obs.counter(
        "tune.retune_candidates",
        backend=str(backend),
        family=family,
        reason=reason,
    ).inc()
    _obs.event(
        "retune_candidate",
        backend=str(backend),
        family=family,
        m=m,
        k=k,
        n=n,
        groups=groups,
        itemsize=itemsize,
        streak=streak,
        reason=reason,
    )
