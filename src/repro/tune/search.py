"""Empirical tile search: analytic pruning, then on-device timing.

The search space for one workload cell is every legal ``(bm, bn, bk)`` block
shape of the O-POPE kernels (alignment + VMEM-budget constraints from
``kernels.opope_gemm.validate_block_shape``). Exhaustively timing it on
device is wasteful — OpenGeMM (arXiv:2411.09543) and the Versal GEMM DSE
(arXiv:2511.06907) both prune with a performance model first — so candidates
are ranked by the analytic cluster model behind ``core.tiling.choose_tile``
(:func:`repro.core.tiling.rank_plans`: double-buffered compute/DMA overlap
per tile) and only the modeled top-K are measured: compile + warmup, then
steady-state timing, winner persisted to the :class:`~repro.tune.table.TuningTable`.

The backend's own heuristic tile is **always** in the measured set, so a
tuned entry is never slower than the heuristic under the same measurement
protocol — the tuner can only confirm or improve the default.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import OPOPE_16x16_FP16
from repro.core.tiling import rank_plans
from repro.kernels import ops
from repro.kernels.opope_gemm import opope_gemm, validate_block_shape
from repro.kernels.opope_grouped import opope_gemm_grouped

from .table import GemmShape, TuneEntry, TuneKey, TuningTable, device_kind

__all__ = [
    "TUNABLE_BACKENDS",
    "PROBE_EPILOGUE",
    "CandidateResult",
    "EpilogueProbe",
    "candidate_blocks",
    "median_time_us",
    "probe_epilogue_fusion",
    "tune_shape",
    "tune_workload",
]

# backend name -> interpret mode, for the backends whose kernel entry points
# the tuner knows how to drive with explicit block_*= overrides. Tunability
# itself and the numerics family come from the ops registry (tile_fn /
# family_of) — this map only exists because a registered backend fn hides
# its block parameters, so timing a *specific* candidate needs the
# underlying kernel entry point, which the registry doesn't expose. A new
# backend with a tile_fn must add its kernel dispatch here (and to
# _make_runner) to be CLI-tunable; tune_shape says so in its error.
TUNABLE_BACKENDS: Dict[str, bool] = {
    "pallas": False,
    "pallas_interpret": True,
    "pallas_q8": False,
    "pallas_q8_interpret": True,
}

_BM_CHOICES = (8, 16, 32, 64, 128, 256)
_BN_CHOICES = (128, 256, 512)
_BK_CHOICES = (128, 256, 512)


def _rup(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def _clamp_block(
    m: int, k: int, n: int, bm: int, bn: int, bk: int, m_align: int
) -> Tuple[int, int, int]:
    """Apply the kernels' own clamping so candidates that the kernel would
    collapse to the same effective blocks dedupe before timing."""
    bm = _rup(min(bm, _rup(m, m_align)), m_align)
    bn = min(bn, _rup(n, 128))
    bk = min(bk, _rup(k, 128))
    return bm, bn, bk


def candidate_blocks(
    m: int, k: int, n: int, *, itemsize: int = 4, m_align: int = 8
) -> List[Tuple[int, int, int]]:
    """Every legal deduped (bm, bn, bk) candidate for this GEMM shape."""
    out: List[Tuple[int, int, int]] = []
    seen = set()
    for bm in _BM_CHOICES:
        if bm % m_align:
            continue
        for bn in _BN_CHOICES:
            for bk in _BK_CHOICES:
                cand = _clamp_block(m, k, n, bm, bn, bk, m_align)
                if cand in seen:
                    continue
                seen.add(cand)
                if validate_block_shape(
                    *cand, elem_bytes=itemsize, m_align=m_align
                ):
                    out.append(cand)
    return out


@dataclasses.dataclass(frozen=True)
class CandidateResult:
    block: Tuple[int, int, int]
    us: float
    gflops: float
    modeled_cycles: Optional[int]
    is_heuristic: bool


# The epilogue pipeline the fusion probe times: one streamed row operand plus
# one activation — the canonical MLP-hidden writeback (bias + silu), i.e. the
# exact shape of traffic the fused lane exists to absorb.
PROBE_EPILOGUE: Tuple[str, ...] = ("bias", "silu")


@dataclasses.dataclass(frozen=True)
class EpilogueProbe:
    """Fused-vs-post-hoc measurement of :data:`PROBE_EPILOGUE` at one tile.

    ``fused_us`` times the kernel with the pipeline fused into the
    accumulator writeback; ``posthoc_us`` times the same kernel followed by
    one XLA elementwise pass over the full output. Ties go to fused — at
    equal wall time the fused form still saves the extra HBM round-trip.
    """

    block: Tuple[int, int, int]
    steps: Tuple[str, ...]
    fused_us: float
    posthoc_us: float

    @property
    def fuse(self) -> bool:
        return self.fused_us <= self.posthoc_us

    @property
    def decided_us(self) -> float:
        return min(self.fused_us, self.posthoc_us)


def median_time_us(run: Callable[[], object], *, iters: int, warmup: int) -> float:
    """Median steady-state wall time of ``run`` in microseconds.

    The first (warmup) calls absorb compilation; ``block_until_ready`` on
    the result bounds each sample (async dispatch otherwise times nothing).
    Shared with ``benchmarks/kernel_bench.py`` so heuristic, tuned and
    untiled rows all use one measurement protocol.
    """
    import jax

    for _ in range(max(1, warmup)):
        jax.tree.leaves(run())[0].block_until_ready()
    samples = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.tree.leaves(run())[0].block_until_ready()
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return max(samples[len(samples) // 2], 1e-3)


def _make_runner(
    backend: str, shape: GemmShape, seed: int = 0
) -> Callable[[Tuple[int, int, int]], Callable[[], object]]:
    """Build ``blocks -> (zero-arg timed call)`` for one workload cell.

    Operand generation (and, for the q8 backends, quantization) happens once
    here, outside every timed region: the tile choice affects the GEMM
    schedule only, and the measurement must see exactly that.
    """
    import jax.numpy as jnp

    interpret = TUNABLE_BACKENDS[backend]
    family = ops.family_of(backend)
    rng = np.random.default_rng(seed)
    g = shape.g if shape.family == "grouped" else 0
    lead = (g,) if g else ()
    a = rng.standard_normal(lead + (shape.m, shape.k)).astype(np.float32)
    b = rng.standard_normal(lead + (shape.k, shape.n)).astype(np.float32)

    if family == "q8":
        from repro.quant.quantize import quantize

        if g:
            aq = quantize(jnp.asarray(a), "int8", axis=(0, 1))
            bq = quantize(jnp.asarray(b), "int8", axis=(0, 2))
            from repro.quant.pallas_q8 import opope_gemm_q8_grouped as kern
        else:
            aq = quantize(jnp.asarray(a), "int8", axis=0)
            bq = quantize(jnp.asarray(b), "int8", axis=1)
            from repro.quant.pallas_q8 import opope_gemm_q8 as kern

        def runner(blocks):
            bm, bn, bk = blocks
            return lambda: kern(
                aq.q, aq.scale, bq.q, bq.scale,
                block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
            )

        return runner

    dtype = jnp.dtype(shape.dtype)
    aj = jnp.asarray(a, dtype)
    bj = jnp.asarray(b, dtype)
    kern = opope_gemm_grouped if g else opope_gemm

    def runner(blocks):
        bm, bn, bk = blocks
        return lambda: kern(
            aj, bj, block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
        )

    return runner


def _make_epilogue_runners(
    backend: str, shape: GemmShape, blocks: Tuple[int, int, int], seed: int = 0
) -> Tuple[Callable[[], object], Callable[[], object]]:
    """``(fused, post-hoc)`` zero-arg timed calls for :data:`PROBE_EPILOGUE`.

    Both variants are jitted with the operands as call arguments (closed-over
    constants would invite constant folding of the whole measurement) and
    compute the identical fp32 result, so the timing difference is purely
    writeback-fused versus one extra elementwise pass over the output.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import epilogue as _epi

    interpret = TUNABLE_BACKENDS[backend]
    family = ops.family_of(backend)
    bm, bn, bk = blocks
    rng = np.random.default_rng(seed)
    g = shape.g if shape.family == "grouped" else 0
    lead = (g,) if g else ()
    a = rng.standard_normal(lead + (shape.m, shape.k)).astype(np.float32)
    b = rng.standard_normal(lead + (shape.k, shape.n)).astype(np.float32)
    bias = jnp.asarray(
        rng.standard_normal((g, shape.n) if g else (shape.n,)), jnp.float32
    )
    kw = dict(
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=jnp.float32, interpret=interpret,
    )

    if family == "q8":
        from repro.quant.quantize import quantize

        if g:
            from repro.quant.pallas_q8 import opope_gemm_q8_grouped as kern

            aq = quantize(jnp.asarray(a), "int8", axis=(0, 1))
            bq = quantize(jnp.asarray(b), "int8", axis=(0, 2))
        else:
            from repro.quant.pallas_q8 import opope_gemm_q8 as kern

            aq = quantize(jnp.asarray(a), "int8", axis=0)
            bq = quantize(jnp.asarray(b), "int8", axis=1)
        gemm_args = (aq.q, aq.scale, bq.q, bq.scale)
    else:
        dtype = jnp.dtype(shape.dtype)
        kern = opope_gemm_grouped if g else opope_gemm
        gemm_args = (jnp.asarray(a, dtype), jnp.asarray(b, dtype))
    n_args = len(gemm_args)

    @jax.jit
    def fused_fn(*xs):
        return kern(
            *xs[:n_args], epilogue=PROBE_EPILOGUE,
            epilogue_operands=xs[n_args:], **kw,
        )

    @jax.jit
    def posthoc_fn(*xs):
        acc = kern(*xs[:n_args], **kw)
        canon = _epi.canonicalize_operands(
            PROBE_EPILOGUE, xs[n_args:], n=shape.n, m=shape.m, groups=g
        )
        return _epi.apply_epilogue(acc, PROBE_EPILOGUE, canon)

    args = gemm_args + (bias,)
    return (lambda: fused_fn(*args)), (lambda: posthoc_fn(*args))


def probe_epilogue_fusion(
    backend: str,
    shape: GemmShape,
    blocks: Tuple[int, int, int],
    *,
    iters: int = 3,
    warmup: int = 1,
    seed: int = 0,
) -> EpilogueProbe:
    """Time :data:`PROBE_EPILOGUE` fused at the writeback vs post-hoc for one
    workload cell at one tile; the verdict feeds ``TuneEntry.fuse_epilogue``
    (and from there ``ops._fusion_for`` on every later run)."""
    if backend not in TUNABLE_BACKENDS:
        raise ValueError(
            f"backend {backend!r} is not tunable; tunable: "
            f"{sorted(TUNABLE_BACKENDS)}"
        )
    if not ops.epilogue_capable(backend):
        raise ValueError(f"backend {backend!r} has no fused-epilogue lane")
    fused, posthoc = _make_epilogue_runners(backend, shape, blocks, seed=seed)
    return EpilogueProbe(
        block=tuple(blocks),
        steps=PROBE_EPILOGUE,
        fused_us=median_time_us(fused, iters=iters, warmup=warmup),
        posthoc_us=median_time_us(posthoc, iters=iters, warmup=warmup),
    )


def tune_shape(
    backend: str,
    shape: GemmShape,
    *,
    top_k: int = 4,
    iters: int = 3,
    warmup: int = 1,
    seed: int = 0,
    probe_epilogue: bool = True,
) -> Tuple[TuneEntry, List[CandidateResult]]:
    """Tune one workload cell on one backend; returns the winning entry plus
    every measured candidate (the heuristic tile is always among them).

    With ``probe_epilogue`` (the default), epilogue-capable backends get one
    extra fused-vs-post-hoc timing at the winning tile and the entry records
    the verdict in ``fuse_epilogue``; pass ``False`` to run the probe
    yourself (``probe_epilogue_fusion``) when you also want its raw timings.
    """
    if backend not in TUNABLE_BACKENDS:
        if backend in ops.tunable_backends():
            raise ValueError(
                f"backend {backend!r} registers a tile_fn but the tuner has "
                f"no kernel dispatch for it — add it to "
                f"search.TUNABLE_BACKENDS/_make_runner to make it tunable"
            )
        raise ValueError(
            f"backend {backend!r} has no tile knob to tune; tunable: "
            f"{sorted(TUNABLE_BACKENDS)}"
        )
    family = ops.family_of(backend)
    itemsize = 1 if family == "q8" else int(np.dtype(shape.dtype).itemsize)
    m_align = 32 if family == "q8" else 8
    heuristic = _clamp_block(
        shape.m, shape.k, shape.n,
        *ops.heuristic_tile(backend, shape.m, shape.k, shape.n,
                            dtype=shape.dtype),
        m_align,
    )

    cands = candidate_blocks(
        shape.m, shape.k, shape.n, itemsize=itemsize, m_align=m_align
    )
    if heuristic not in cands:
        cands.append(heuristic)
    # Analytic pruning: score every candidate with the cluster cost model
    # ((tm, tk, tn) order there), keep the modeled top-K — plus the heuristic,
    # which is measured unconditionally as the baseline.
    effective_m = shape.m * (shape.g if shape.family == "grouped" else 1)
    scored = rank_plans(
        OPOPE_16x16_FP16, effective_m, shape.k, shape.n,
        [(bm, bk, bn) for bm, bn, bk in cands],
        elem_bytes=itemsize, top_k=len(cands),
    )
    modeled = {(tm, tn, tk): cyc for (tm, tk, tn), cyc in scored}
    keep = [(tm, tn, tk) for (tm, tk, tn), _ in scored[: max(1, top_k)]]
    if heuristic not in keep:
        keep.append(heuristic)

    runner = _make_runner(backend, shape, seed=seed)
    flops = 2.0 * shape.m * shape.k * shape.n * max(1, shape.g)
    results: List[CandidateResult] = []
    for blocks in keep:
        us = median_time_us(runner(blocks), iters=iters, warmup=warmup)
        results.append(CandidateResult(
            block=blocks, us=us, gflops=flops / us / 1e3,
            modeled_cycles=modeled.get(blocks),
            is_heuristic=blocks == heuristic,
        ))
    best = min(results, key=lambda r: r.us)
    fuse: Optional[bool] = None
    if probe_epilogue and ops.epilogue_capable(backend):
        fuse = probe_epilogue_fusion(
            backend, shape, best.block, iters=iters, warmup=warmup, seed=seed
        ).fuse
    entry = TuneEntry(
        key=TuneKey(
            backend=backend, shape_family=shape.family,
            m=shape.m, k=shape.k, n=shape.n, g=shape.g,
            dtype="int8" if family == "q8" else shape.dtype,
            device_kind=device_kind(),
        ),
        block=best.block, us=best.us, gflops=best.gflops,
        modeled_cycles=best.modeled_cycles,
        fuse_epilogue=fuse,
    )
    return entry, results


def tune_workload(
    shapes: Sequence[GemmShape],
    *,
    backends: Iterable[str],
    table: Optional[TuningTable] = None,
    top_k: int = 4,
    iters: int = 3,
    warmup: int = 1,
    log: Optional[Callable[[str], None]] = None,
) -> TuningTable:
    """Tune every (shape x backend) cell into ``table`` (new one if None)."""
    table = table if table is not None else TuningTable()
    for backend in backends:
        for shape in shapes:
            entry, results = tune_shape(
                backend, shape, top_k=top_k, iters=iters, warmup=warmup
            )
            table.put(entry)
            if log is not None:
                heur = next(r for r in results if r.is_heuristic)
                gain = heur.us / entry.us if entry.us else 1.0
                log(
                    f"{backend:>20s} {shape.family:>7s} "
                    f"g={shape.g:<3d} {shape.m}x{shape.k}x{shape.n} "
                    f"{shape.dtype}: best {entry.block} {entry.us:.1f}us "
                    f"({entry.gflops:.2f} GFLOP/s), heuristic {heur.block} "
                    f"{heur.us:.1f}us -> {gain:.2f}x, "
                    f"{len(results)} candidates timed, "
                    f"fuse_epilogue={entry.fuse_epilogue}"
                )
    return table
