"""Persistent tuning tables: measured tile winners, keyed per workload cell.

A tuning table is one JSON file mapping

    (backend, shape-family, M, K, N, G, dtype, device-kind)  ->  (bm, bn, bk)

plus the measurement that justified the choice (steady-state microseconds,
GFLOP/s, the analytic model's cycle estimate). ``repro.kernels.ops`` consults
the *active* table — ``$REPRO_TUNE_TABLE`` if set, else the committed
in-package default — before falling back to the block-shape heuristics, so a
table written once by the ``repro-tune`` CLI keeps paying on every later run
on the same device kind.

Robustness contract (asserted in ``tests/test_tune.py``): a missing,
corrupt, or stale-schema table file must never break a GEMM — the loader
degrades to "no table" with a single warning and every tile resolution falls
back to the heuristic. Entries are additionally validated against the
kernel's hard constraints at lookup time (``ops._tuned_tile``): the table is
a cache of *suggestions*, never a trusted input.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_TUNE_TABLE"
# The committed default table (CI measures a tiny CPU shape set into it; a
# TPU deployment commits its own). Entries only apply on a matching device
# kind, so a cpu-tuned default is inert on TPU and vice versa.
DEFAULT_TABLE_PATH = os.path.join(
    os.path.dirname(__file__), "tables", "default.json"
)

__all__ = [
    "SCHEMA_VERSION",
    "ENV_VAR",
    "DEFAULT_TABLE_PATH",
    "GemmShape",
    "TuneKey",
    "TuneEntry",
    "TuningTable",
    "TableFormatError",
    "active_table_path",
    "load_active_table",
    "device_kind",
]


class GemmShape(NamedTuple):
    """One workload cell: the unit the tuner measures and the table keys on.

    ``family`` is ``"dense"`` ([M,K] @ [K,N]) or ``"grouped"`` ([G,M,K] @
    [G,K,N] with (m, k, n) the per-group shape, ``g`` the group count —
    0 for dense).
    """

    family: str
    m: int
    k: int
    n: int
    g: int = 0
    dtype: str = "float32"


def device_kind() -> str:
    """Normalized device kind of the default JAX device ("cpu",
    "tpu-v5-lite-podslice", ...): the table's hardware discriminator."""
    try:
        import jax

        kind = jax.devices()[0].device_kind
    except Exception:
        return "unknown"
    return str(kind).strip().lower().replace(" ", "-")


def _dtype_itemsize(name: str) -> int:
    from repro.core.roofline import dtype_width

    return dtype_width(name)


@dataclasses.dataclass(frozen=True)
class TuneKey:
    backend: str
    shape_family: str  # "dense" | "grouped"
    m: int
    k: int
    n: int
    g: int  # group count, 0 for dense
    dtype: str  # operand dtype name as quantized/streamed by the backend
    device_kind: str

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "TuneKey":
        return cls(
            backend=str(d["backend"]),
            shape_family=str(d["shape_family"]),
            m=int(d["m"]), k=int(d["k"]), n=int(d["n"]), g=int(d["g"]),
            dtype=str(d["dtype"]),
            device_kind=str(d["device_kind"]),
        )


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    key: TuneKey
    block: Tuple[int, int, int]  # (bm, bn, bk) as the kernels take them
    us: float  # steady-state time of the winner
    gflops: float
    modeled_cycles: Optional[int] = None  # analytic pruner's estimate
    source: str = "measured"
    # Measured fused-vs-post-hoc epilogue verdict for this cell: True =
    # fusing the epilogue into the writeback was at least as fast, False =
    # the post-hoc pass won (operand streaming perturbed the pipelining),
    # None = never measured — ops falls back to fuse-by-default. Optional
    # JSON field: tables written before this field existed load unchanged
    # (from_json reads known keys only), so the schema version stays 1.
    fuse_epilogue: Optional[bool] = None

    def to_json(self) -> Dict[str, object]:
        d = self.key.to_json()
        d.update(
            block=list(self.block), us=self.us, gflops=self.gflops,
            modeled_cycles=self.modeled_cycles, source=self.source,
        )
        if self.fuse_epilogue is not None:
            d["fuse_epilogue"] = self.fuse_epilogue
        return d

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "TuneEntry":
        block = d["block"]
        if not (isinstance(block, (list, tuple)) and len(block) == 3):
            raise TableFormatError(f"bad block {block!r}")
        fuse = d.get("fuse_epilogue")
        return cls(
            key=TuneKey.from_json(d),
            block=(int(block[0]), int(block[1]), int(block[2])),
            us=float(d.get("us", 0.0)),
            gflops=float(d.get("gflops", 0.0)),
            modeled_cycles=(
                int(d["modeled_cycles"])
                if d.get("modeled_cycles") is not None else None
            ),
            source=str(d.get("source", "measured")),
            fuse_epilogue=None if fuse is None else bool(fuse),
        )


class TableFormatError(ValueError):
    """The file exists but is not a valid tuning table (corrupt JSON, wrong
    schema version, malformed entry). The loader treats it as 'no table'."""


class TuningTable:
    """In-memory tuning table with JSON round-trip and itemsize-keyed lookup.

    Lookup is by element *width*, not dtype name: tile selection cares about
    bytes moved per element (exactly like the heuristics, which key on
    ``itemsize``), so an entry tuned at float32 serves int32 and an entry
    tuned at bfloat16 serves float16. Entries for other device kinds are
    carried through load/save untouched but never served.
    """

    def __init__(self, entries: Iterable[TuneEntry] = ()):
        self._entries: Dict[TuneKey, TuneEntry] = {}
        self._index: Dict[Tuple, Tuple[int, int, int]] = {}
        self._fusion_index: Dict[Tuple, bool] = {}
        for e in entries:
            self.put(e)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> List[TuneEntry]:
        return list(self._entries.values())

    @staticmethod
    def _index_key(
        backend: str, shape_family: str, m: int, k: int, n: int, g: int,
        itemsize: int, device: str,
    ) -> Tuple:
        return (backend, shape_family, m, k, n, g, itemsize, device)

    def put(self, entry: TuneEntry) -> None:
        self._entries[entry.key] = entry
        try:
            itemsize = _dtype_itemsize(entry.key.dtype)
        except Exception:
            return  # unknown dtype name: keep the entry, never serve it
        ikey = self._index_key(
            entry.key.backend, entry.key.shape_family,
            entry.key.m, entry.key.k, entry.key.n, entry.key.g,
            itemsize, entry.key.device_kind,
        )
        self._index[ikey] = entry.block
        if entry.fuse_epilogue is not None:
            self._fusion_index[ikey] = entry.fuse_epilogue
        else:
            # A re-tuned entry without a fusion verdict supersedes any stale
            # verdict the replaced entry carried.
            self._fusion_index.pop(ikey, None)

    def get(self, key: TuneKey) -> Optional[TuneEntry]:
        return self._entries.get(key)

    def lookup(
        self,
        *,
        backend: str,
        shape_family: str,
        m: int,
        k: int,
        n: int,
        g: int = 0,
        itemsize: int,
        device: Optional[str] = None,
    ) -> Optional[Tuple[int, int, int]]:
        """The tuned (bm, bn, bk) for this cell on this device, or None."""
        return self._index.get(self._index_key(
            backend, shape_family, m, k, n, g, itemsize,
            device if device is not None else device_kind(),
        ))

    def lookup_fusion(
        self,
        *,
        backend: str,
        shape_family: str,
        m: int,
        k: int,
        n: int,
        g: int = 0,
        itemsize: int,
        device: Optional[str] = None,
    ) -> Optional[bool]:
        """The measured fused-vs-post-hoc epilogue verdict for this cell on
        this device, or None when the tuner never measured one (ops then
        fuses by default on capable backends)."""
        return self._fusion_index.get(self._index_key(
            backend, shape_family, m, k, n, g, itemsize,
            device if device is not None else device_kind(),
        ))

    def merge(self, other: "TuningTable") -> None:
        """Adopt ``other``'s entries (other wins on key conflicts)."""
        for e in other.entries:
            self.put(e)

    # -- persistence --------------------------------------------------------

    def to_json(self) -> Dict[str, object]:
        return {
            "schema": SCHEMA_VERSION,
            "entries": [e.to_json() for e in self._entries.values()],
        }

    @classmethod
    def from_json(cls, doc: object) -> "TuningTable":
        if not isinstance(doc, dict):
            raise TableFormatError(f"table root is {type(doc).__name__}, not object")
        if doc.get("schema") != SCHEMA_VERSION:
            raise TableFormatError(
                f"table schema {doc.get('schema')!r} != supported {SCHEMA_VERSION}"
            )
        raw = doc.get("entries")
        if not isinstance(raw, list):
            raise TableFormatError("table has no entries list")
        try:
            return cls(TuneEntry.from_json(d) for d in raw)
        except (KeyError, TypeError, ValueError) as e:
            raise TableFormatError(f"malformed table entry: {e}") from e

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        """Load a table; raises FileNotFoundError / TableFormatError.

        (Use :func:`load_active_table` for the never-raises behaviour the
        GEMM hot path needs.)
        """
        with open(path) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as e:
                raise TableFormatError(f"corrupt table JSON: {e}") from e
        return cls.from_json(doc)

    def save(self, path: str) -> None:
        """Atomic write (tmp file + rename): a reader — another serving
        process mid-resolution — never observes a half-written table."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
                f.write("\n")
            os.chmod(tmp, 0o644)  # mkstemp's 0600 is wrong for a shared table
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def active_table_path() -> str:
    """Where the active table lives: ``$REPRO_TUNE_TABLE`` overrides the
    committed in-package default."""
    return os.environ.get(ENV_VAR) or DEFAULT_TABLE_PATH


def load_active_table() -> Optional[TuningTable]:
    """The table ``ops._tile_for`` consults; never raises.

    Missing file -> None silently (most processes have no table). A file
    that exists but cannot be parsed (corrupt JSON, wrong schema, malformed
    entries) -> None with one RuntimeWarning naming the path: GEMMs keep
    running on heuristics, exactly as if there were no table.
    """
    path = active_table_path()
    if not os.path.exists(path):
        return None
    try:
        return TuningTable.load(path)
    except (TableFormatError, OSError) as e:
        warnings.warn(
            f"ignoring unusable tuning table {path!r}: {e}",
            RuntimeWarning,
            stacklevel=2,
        )
        return None
