"""Graceful degradation when the ``test`` extra isn't installed.

``pip install -e .[test]`` brings in hypothesis; containers without it must
still *collect* every test module (the seed failed collection outright).
Importing ``given/settings/st`` from here gives property tests a no-op
strategy surface and turns each ``@given`` function into a skipped test,
while every non-property test in the same module keeps running.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""

import pytest

_SKIP_REASON = "hypothesis not installed (pip install -e .[test])"


class _Strategy:
    """Inert stand-in: any attribute access or call yields another strategy."""

    def __init__(self, label: str):
        self._label = label

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name: str):
        return _Strategy(f"{self._label}.{name}")

    def __repr__(self):
        return f"<stub strategy {self._label}>"


st = _Strategy("st")


def settings(*args, **kwargs):
    def decorate(fn):
        return fn

    return decorate


def given(*args, **kwargs):
    def decorate(fn):
        return pytest.mark.skip(reason=_SKIP_REASON)(fn)

    return decorate
