import os
import sys

# Tests run single-device (the dry-run forces 512 devices only inside its own
# process). Keep XLA from grabbing every core for compilation determinism.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
