import os
import sys

# Tests run single-device (the dry-run forces 512 devices only inside its own
# process). Keep XLA from grabbing every core for compilation determinism.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_BENCHMARKS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")


def import_quant_bench():
    """Import benchmarks/quant_bench.py (a plain dir, not a package): shared
    by the tests that reuse its trained-model / greedy-decode helpers."""
    sys.path.insert(0, _BENCHMARKS_DIR)
    try:
        import quant_bench
    finally:
        sys.path.pop(0)
    return quant_bench


import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_telemetry():
    """No cross-test telemetry bleed: every test starts with an empty metrics
    registry and zeroed tile-lookup counters (the tile *memo* itself is NOT
    dropped — warm tiles across tests are fine and fast; tests that need a
    cold memo call ops.clear_tile_cache() themselves)."""
    from repro import obs
    from repro.kernels import ops
    from repro.obs import audit
    from repro.obs import http as obs_http
    from repro.obs import tracing as obs_tracing

    obs.reset()
    obs.clear_events()
    obs_tracing.reset()  # request-lifecycle trace buffer
    ops.reset_tile_cache_stats()
    yield
    obs.reset()
    obs.clear_events()
    obs_tracing.reset()
    obs_tracing.set_enabled(None)  # back to env-driven tracing toggle
    obs_http.shutdown()  # a test that started the scrape server won't leak it
    ops.reset_tile_cache_stats()  # also drops util-gap streaks/bests
    ops.on_miss_streak(None)  # restore the default retune-candidate hook
    ops.on_util_gap(None)  # restore the default util-gap hook
    audit.set_audit_every(None)  # back to env-driven sampling (off in tests)
