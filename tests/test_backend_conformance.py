"""Registry-wide backend conformance suite.

One parametrized contract, asserted for *every* backend in the registry
(quant plugins included; unavailable backends skip instead of failing):

* **fp32 accumulation vs ref.py** — output matches the
  :mod:`repro.kernels.ref` oracle; full-precision backends to reassociation
  noise, q8 backends to the per-row/column scale bound.
* **single final cast** — requesting a narrow ``out_dtype`` equals computing
  the fp32 result and casting once (bitwise: same accumulator, one cast).
* **bias-in-backend** — the [N] bias row rides the accumulator
  preload/writeback, equal to a post-GEMM add in fp32.
* **custom_vjp gradients** — backward matches the XLA reference gradients;
  exactly for backends with a full-precision ``grad_backend``, to tolerance
  for backends that run their own backward GEMMs.
* **fallback-chain degradation** — an unavailable backend degrades with the
  RuntimeWarning and lands inside its own numerics family; every declared
  chain terminates at a family-preserving member.
* **grouped member** — ``grouped_matmul`` on the same name equals stacked
  per-group ``matmul`` calls (same family, same contract), gradients
  included.

New backends inherit the whole suite by registration: the parametrization
iterates the registry at collection time.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import reference_grouped_matmul, reference_matmul

# Quant backends register through the lazy plugin import; force it so the
# parametrization below sees the whole registry.
ops._load_plugin_backends()
ALL_BACKENDS = sorted(ops.registered_backends())
GROUPED_BACKENDS = sorted(ops.grouped_backends())


def _available_or_skip(name: str) -> None:
    if not ops._probe_ok(ops._REGISTRY[name]):
        pytest.skip(f"backend {name!r} unavailable on this platform")


def _operands(m=48, k=96, n=72, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return a, b


def _grouped_operands(g=3, m=24, k=64, n=40, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    return a, b


def _tolerance(name: str, want) -> float:
    """Contract tolerance: reassociation noise for fp, scale bound for q8."""
    if ops.family_of(name) == "q8":
        # |C_err| <~ K * (amax_a*sb/2 + sa/2*amax_b); 3% of the output's max
        # magnitude is the same conservative envelope test_quant asserts.
        return 0.03 * float(jnp.max(jnp.abs(want)))
    return 1e-4 * float(jnp.max(jnp.abs(want))) + 1e-5


# ---------------------------------------------------------------------------
# the shared numerics contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_matches_reference_contract(name):
    _available_or_skip(name)
    a, b = _operands()
    want = reference_matmul(a, b)
    got = ops.matmul(a, b, backend=name)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert float(jnp.max(jnp.abs(got - want))) <= _tolerance(name, want)


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_single_final_cast(name):
    # Narrow output == fp32 output cast once: accumulation never happens in
    # the narrow dtype, and no backend casts twice.
    _available_or_skip(name)
    a, b = _operands(seed=1)
    wide = ops.matmul(a, b, backend=name, out_dtype=jnp.float32)
    narrow = ops.matmul(a, b, backend=name, out_dtype=jnp.bfloat16)
    assert narrow.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(narrow), np.asarray(wide.astype(jnp.bfloat16))
    )


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_bias_rides_the_backend(name):
    _available_or_skip(name)
    a, b = _operands(seed=2)
    bias = jnp.asarray(
        np.random.default_rng(3).standard_normal(b.shape[1]), jnp.float32
    )
    no_bias = ops.matmul(a, b, backend=name)
    with_bias = ops.matmul(a, b, bias, backend=name)
    np.testing.assert_allclose(
        np.asarray(with_bias), np.asarray(no_bias + bias[None, :]),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_custom_vjp_gradients(name):
    _available_or_skip(name)
    a, b = _operands(m=24, k=48, n=32, seed=4)

    # sum() makes the cotangent all-ones: the backward GEMMs see the same
    # cotangent on every backend, so the comparison isolates the backward
    # path itself (quantized forwards run it on their fp32 grad backend).
    da, db = jax.grad(
        lambda a, b: ops.matmul(a, b, backend=name).sum(), argnums=(0, 1)
    )(a, b)
    da_ref, db_ref = jax.grad(
        lambda a, b: reference_matmul(a, b).sum(), argnums=(0, 1)
    )(a, b)
    # fp32-accumulated backward on every backend: reassociation noise only.
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_grad_backend_is_full_precision_for_q8(name):
    if ops.family_of(name) != "q8":
        pytest.skip("fp backend: backward runs on itself by design")
    gb = ops.grad_backend_of(name)
    assert ops.family_of(gb) == "fp", (
        f"{name} backpropagates through {gb} ({ops.family_of(gb)}): gradients "
        f"must stay full-precision by registry rule"
    )


# ---------------------------------------------------------------------------
# fallback chains
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_fallback_chain_declared_and_family_preserving(name):
    chain = ops.fallback_chain_of(name)
    assert chain, f"{name} declares no fallback chain"
    registered = [fb for fb in chain if fb in ops.registered_backends()]
    assert registered, f"{name} fallback chain {chain} has no registered member"
    terminal = registered[-1]
    assert ops.family_of(terminal) == ops.family_of(name), (
        f"{name} ({ops.family_of(name)}) degrades to terminal {terminal} "
        f"({ops.family_of(terminal)}): degradation must preserve the "
        f"numerics family"
    )


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_degradation_warns_and_preserves_family(name, monkeypatch):
    b = ops._REGISTRY[name]
    monkeypatch.setitem(
        ops._REGISTRY, name, dataclasses.replace(b, available=lambda: False)
    )
    try:
        with pytest.warns(RuntimeWarning, match="degrading to"):
            resolved = ops.resolve_backend(name)
    except RuntimeError:
        pytest.skip("no member of the chain is available on this platform")
    assert resolved != name
    assert ops.family_of(resolved) == ops.family_of(name)


# ---------------------------------------------------------------------------
# the grouped member of each family
# ---------------------------------------------------------------------------


def test_every_backend_declares_a_grouped_member():
    # The acceptance bar for the grouped family: no registered backend is
    # missing its grouped implementation (third-party registrations may omit
    # one — then THIS assert tells their CI, not a silent xla fallback).
    assert set(GROUPED_BACKENDS) == set(ALL_BACKENDS)


@pytest.mark.parametrize("name", GROUPED_BACKENDS)
def test_grouped_equals_stacked_matmul(name):
    _available_or_skip(name)
    a, b = _grouped_operands()
    got = ops.grouped_matmul(a, b, backend=name)
    want = jnp.stack(
        [ops.matmul(a[i], b[i], backend=name) for i in range(a.shape[0])]
    )
    tol = 1e-5 if ops.family_of(name) == "fp" else 1e-4 * float(
        jnp.max(jnp.abs(want))
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=max(tol, 1e-5)
    )


@pytest.mark.parametrize("name", GROUPED_BACKENDS)
def test_grouped_bias_rides_the_backend(name):
    _available_or_skip(name)
    a, b = _grouped_operands(seed=5)
    bias = jnp.asarray(
        np.random.default_rng(6).standard_normal((a.shape[0], b.shape[2])),
        jnp.float32,
    )
    no_bias = ops.grouped_matmul(a, b, backend=name)
    with_bias = ops.grouped_matmul(a, b, bias, backend=name)
    np.testing.assert_allclose(
        np.asarray(with_bias), np.asarray(no_bias + bias[:, None, :]),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("name", GROUPED_BACKENDS)
def test_grouped_gradients_match_reference(name):
    _available_or_skip(name)
    a, b = _grouped_operands(g=2, m=16, k=32, n=24, seed=7)
    da = jax.grad(lambda a: ops.grouped_matmul(a, b, backend=name).sum())(a)
    da_ref = jax.grad(lambda a: reference_grouped_matmul(a, b).sum())(a)
    if ops.grad_backend_of(name) != name:
        # grad-backend indirection: exactly the reference backward
        np.testing.assert_allclose(
            np.asarray(da), np.asarray(da_ref), rtol=1e-6, atol=1e-6
        )
    else:
        np.testing.assert_allclose(
            np.asarray(da), np.asarray(da_ref), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# the epilogue contract (backend x epilogue)
# ---------------------------------------------------------------------------

# Each case: (id, spec builder, independent reference fn) — the reference is
# hand-written jnp (NOT repro.kernels.epilogue), so these assert the lane's
# numerics against an implementation that shares no code with it.


def _epilogue_cases(m, n, seed=11):
    rng = np.random.default_rng(seed)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    resid = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    gate = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    row = jnp.asarray(rng.standard_normal(n), jnp.float32)
    return {
        "gelu": ("gelu", lambda acc: jax.nn.gelu(acc)),
        "silu": ("silu", lambda acc: jax.nn.silu(acc)),
        "swish": ("swish", lambda acc: jax.nn.silu(acc)),
        "relu": ("relu", lambda acc: jnp.maximum(acc, 0.0)),
        "bias": ([("bias", bias)], lambda acc: acc + bias[None, :]),
        "residual": ([("residual", resid)], lambda acc: acc + resid),
        "scale": ([("scale", row)], lambda acc: acc * row[None, :]),
        "silu-mul": (
            ["silu", ("mul", gate)], lambda acc: jax.nn.silu(acc) * gate
        ),
        "bias-gelu": (
            [("bias", bias), "gelu"],
            lambda acc: jax.nn.gelu(acc + bias[None, :]),
        ),
    }


EPILOGUE_IDS = sorted(_epilogue_cases(1, 1))


@pytest.mark.parametrize("ep", EPILOGUE_IDS)
@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_epilogue_matches_reference(name, ep):
    _available_or_skip(name)
    a, b = _operands(seed=10)
    spec, ref_fn = _epilogue_cases(a.shape[0], b.shape[1])[ep]
    acc = ops.matmul(a, b, backend=name, out_dtype=jnp.float32)
    want = ref_fn(acc)  # this backend's accumulator + independent post-ops
    got = ops.matmul(a, b, backend=name, epilogue=spec)
    assert got.shape == want.shape
    # The pipeline runs on the same accumulator in fp32 either way; only
    # op-level rounding differs between fused/post-hoc and the jnp reference.
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("ep", EPILOGUE_IDS)
@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_epilogue_single_final_cast(name, ep):
    # The epilogue runs on the fp32 accumulator BEFORE the single final
    # cast: narrow output == fp32 output cast once, for every pipeline.
    _available_or_skip(name)
    a, b = _operands(seed=12)
    spec, _ = _epilogue_cases(a.shape[0], b.shape[1])[ep]
    wide = ops.matmul(a, b, backend=name, epilogue=spec, out_dtype=jnp.float32)
    narrow = ops.matmul(a, b, backend=name, epilogue=spec, out_dtype=jnp.bfloat16)
    assert narrow.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(narrow), np.asarray(wide.astype(jnp.bfloat16))
    )


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_epilogue_vjp_matches_unfused(name):
    # Gradients through the fused lane == gradients through the unfused
    # full-precision composition (incl. the epilogue operand cotangents).
    # Full-precision is the right reference for every family: the fused
    # backward rematerializes the accumulator on the backend's *grad*
    # backend, which the registry pins to fp for q8 members — so even a
    # quantized forward differentiates the fp composition.
    _available_or_skip(name)
    a, b = _operands(m=24, k=48, n=32, seed=13)
    gate = jnp.asarray(
        np.random.default_rng(14).standard_normal((24, 32)), jnp.float32
    )

    def fused(a, b, g):
        return ops.matmul(a, b, backend=name, epilogue=["silu", ("mul", g)]).sum()

    def unfused(a, b, g):
        return (jax.nn.silu(reference_matmul(a, b)) * g).sum()

    got = jax.grad(fused, argnums=(0, 1, 2))(a, b, gate)
    want = jax.grad(unfused, argnums=(0, 1, 2))(a, b, gate)
    for gi, wi in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(gi), np.asarray(wi), rtol=1e-4, atol=1e-4
        )


@pytest.mark.parametrize("name", GROUPED_BACKENDS)
def test_grouped_epilogue_matches_stacked(name):
    _available_or_skip(name)
    a, b = _grouped_operands(seed=15)
    g_, m, n = a.shape[0], a.shape[1], b.shape[2]
    rng = np.random.default_rng(16)
    gate = jnp.asarray(rng.standard_normal((g_, m, n)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((g_, n)), jnp.float32)
    got = ops.grouped_matmul(
        a, b, backend=name, epilogue=[("bias", bias), "silu", ("mul", gate)]
    )
    want = jnp.stack(
        [
            ops.matmul(
                a[i], b[i], backend=name,
                epilogue=[("bias", bias[i]), "silu", ("mul", gate[i])],
            )
            for i in range(g_)
        ]
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_degradation_preserves_epilogue(name, monkeypatch):
    # Regression: a request that degrades along the fallback chain must apply
    # the epilogue exactly once on whatever backend serves it — never dropped
    # (fused-capable member gone) and never doubled (post-hoc on top of
    # fused). Equality with the terminal backend's own fused/post-hoc result
    # rules out both failure modes.
    b = ops._REGISTRY[name]
    monkeypatch.setitem(
        ops._REGISTRY, name, dataclasses.replace(b, available=lambda: False)
    )
    a_, b_ = _operands(seed=17)
    resid = jnp.asarray(
        np.random.default_rng(18).standard_normal((a_.shape[0], b_.shape[1])),
        jnp.float32,
    )
    try:
        with pytest.warns(RuntimeWarning, match="degrading to"):
            got = ops.matmul(
                a_, b_, backend=name, epilogue=["gelu", ("residual", resid)]
            )
            resolved = ops.resolve_backend(name)
    except RuntimeError:
        pytest.skip("no member of the chain is available on this platform")
    want = jax.nn.gelu(
        ops.matmul(a_, b_, backend=resolved, out_dtype=jnp.float32)
    ) + resid
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_resolution_never_crosses_family_boundaries():
    # A q8 backend registered WITHOUT a quantized fallback chain inherits the
    # default (fp) chain — the family guard must raise rather than silently
    # hand the request to a full-precision engine.
    ops.register_backend(
        "_test_q8_no_chain", ops._xla_fn, available=False, family="q8"
    )
    try:
        with pytest.raises(RuntimeError, match="no available matmul backend"):
            ops.resolve_backend("_test_q8_no_chain")
    finally:
        ops._REGISTRY.pop("_test_q8_no_chain", None)


def test_grouped_resolution_never_crosses_family_boundaries():
    # Same guard on the grouped resolver: a q8 backend missing its grouped
    # member must not degrade through the default chain onto fp grouped GEMMs.
    ops.register_backend("_test_q8_no_grouped", ops._xla_fn, family="q8")
    try:
        with pytest.raises(RuntimeError, match="no available grouped"):
            ops.resolve_grouped_backend("_test_q8_no_grouped")
    finally:
        ops._REGISTRY.pop("_test_q8_no_grouped", None)


def test_grouped_resolution_degrades_with_warning(monkeypatch):
    # A backend whose grouped member is missing degrades along its chain with
    # the degradation warning (registered here, never shipped: built-ins all
    # have grouped members — see test_every_backend_declares_a_grouped_member).
    ops.register_backend("_test_no_grouped", ops._xla_fn, fallback=("xla",))
    try:
        with pytest.warns(RuntimeWarning, match="grouped GEMM member"):
            assert ops.resolve_grouped_backend("_test_no_grouped") == "xla"
    finally:
        ops._REGISTRY.pop("_test_no_grouped", None)


def test_grouped_only_failure_keeps_the_2d_member():
    # Per-member availability: a grouped-only lowering failure degrades
    # grouped_matmul along the chain but never demotes the backend's 2-D
    # matmul member (a fleet of dense models must not lose their compiled
    # kernels because the MoE grid regressed).
    ops.register_backend(
        "_test_grouped_broken", ops._xla_fn, fallback=("xla",),
        grouped=ops._xla_grouped_fn, grouped_available=False,
    )
    try:
        assert ops.resolve_backend("_test_grouped_broken") == "_test_grouped_broken"
        with pytest.warns(RuntimeWarning, match="grouped GEMM member"):
            assert ops.resolve_grouped_backend("_test_grouped_broken") == "xla"
    finally:
        ops._REGISTRY.pop("_test_grouped_broken", None)
