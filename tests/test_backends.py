"""Backend registry degradation chain: explicit-request fallback
``pallas -> pallas_interpret -> xla`` with the RuntimeWarning contract, plus
``set_default_backend("auto")`` round-trips. Probes are monkeypatched so the
chain is exercised deterministically regardless of the host platform.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import reference_matmul


def _force_unavailable(monkeypatch, *names):
    for name in names:
        b = ops._REGISTRY[name]
        monkeypatch.setitem(
            ops._REGISTRY, name, dataclasses.replace(b, available=lambda: False)
        )


def _force_available(monkeypatch, name):
    b = ops._REGISTRY[name]
    monkeypatch.setitem(
        ops._REGISTRY, name, dataclasses.replace(b, available=lambda: True)
    )


def test_explicit_pallas_degrades_to_interpreter(monkeypatch):
    _force_unavailable(monkeypatch, "pallas")
    with pytest.warns(RuntimeWarning, match="degrading to 'pallas_interpret'"):
        assert ops.resolve_backend("pallas") == "pallas_interpret"


def test_explicit_request_degrades_past_interpreter_to_xla(monkeypatch):
    _force_unavailable(monkeypatch, "pallas", "pallas_interpret")
    with pytest.warns(RuntimeWarning, match="degrading to 'xla'"):
        assert ops.resolve_backend("pallas") == "xla"
    # a degraded interpreter request also lands on xla
    with pytest.warns(RuntimeWarning, match="degrading to 'xla'"):
        assert ops.resolve_backend("pallas_interpret") == "xla"


def test_degraded_matmul_still_resolves_and_computes(monkeypatch):
    _force_unavailable(monkeypatch, "pallas", "pallas_interpret")
    a = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    b = jnp.asarray(np.ones((4, 2), np.float32))
    with pytest.warns(RuntimeWarning):
        got = ops.matmul(a, b, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(reference_matmul(a, b)))


def test_no_available_backend_raises(monkeypatch):
    _force_unavailable(monkeypatch, "pallas", "pallas_interpret", "xla")
    with pytest.raises(RuntimeError, match="no available matmul backend"):
        ops.resolve_backend("pallas")


def test_probe_exceptions_count_as_unavailable(monkeypatch):
    def boom():
        raise OSError("probe exploded")

    b = ops._REGISTRY["pallas"]
    monkeypatch.setitem(
        ops._REGISTRY, "pallas", dataclasses.replace(b, available=boom)
    )
    with pytest.warns(RuntimeWarning):
        assert ops.resolve_backend("pallas") == "pallas_interpret"
    assert "pallas" not in ops.available_backends()


def test_auto_follows_reregistered_probe(monkeypatch):
    # "auto" consults the registry probe, so a re-registered pallas backend
    # brings its own availability rule.
    _force_available(monkeypatch, "pallas")
    assert ops.resolve_backend("auto") == "pallas"
    _force_unavailable(monkeypatch, "pallas")
    assert ops.resolve_backend("auto") == "xla"


def test_set_default_backend_auto_roundtrip():
    assert ops.default_backend() in ops.registered_backends()
    try:
        ops.set_default_backend("xla")
        assert ops.default_backend() == "xla"
        assert ops.resolve_backend(None) == "xla"
        ops.set_default_backend("auto")
        # auto resolves to a real backend on every platform
        assert ops.default_backend() in ("pallas", "xla")
    finally:
        ops.set_default_backend("auto")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown matmul backend"):
        ops.resolve_backend("tpu_v7")
    with pytest.raises(ValueError, match="unknown matmul backend"):
        ops.set_default_backend("tpu_v7")


def test_register_backend_requires_callable():
    with pytest.raises(TypeError):
        ops.register_backend("broken", fn=None)
