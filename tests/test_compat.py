"""Each compat shim exercised against the installed JAX (whatever it is)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.kernels import ops


class TestVersion:
    def test_parses_installed_version(self):
        v = compat.jax_version()
        assert len(v) >= 2 and all(isinstance(p, int) for p in v)
        assert v >= (0, 4)


class TestCompilerParams:
    def test_object_constructs_with_dimension_semantics(self):
        params = compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        )
        cls = type(params)
        assert cls.__name__ in ("CompilerParams", "TPUCompilerParams")
        assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")

    def test_kernel_using_shim_runs(self):
        from repro.kernels.opope_gemm import opope_gemm

        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        out = opope_gemm(a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 16.0)


class TestMesh:
    def test_axis_types_tuple_or_none(self):
        types = compat.get_mesh_axis_types(3, "auto")
        if hasattr(jax.sharding, "AxisType"):
            assert types is not None and len(types) == 3
        else:
            assert types is None

    def test_make_mesh_single_device(self):
        mesh = compat.make_mesh((1,), ("data",), axis_types="auto")
        assert mesh.axis_names == ("data",)
        assert compat.mesh_axis_sizes(mesh) == {"data": 1}

    def test_set_mesh_installs_ambient_mesh(self):
        mesh = compat.make_mesh((1,), ("data",))
        with compat.set_mesh(mesh):
            ambient = compat.current_abstract_mesh()
            assert ambient is not None
            assert tuple(ambient.axis_names) == ("data",)
            assert compat.mesh_axis_sizes(ambient)["data"] == 1

    def test_no_mesh_means_none_or_empty(self):
        ambient = compat.current_abstract_mesh()
        assert not (getattr(ambient, "axis_names", ()) or ())

    def test_constrain_under_ambient_mesh(self):
        from repro.distributed.hints import constrain

        mesh = compat.make_mesh((1,), ("model",))
        with compat.set_mesh(mesh):
            y = jax.jit(lambda x: constrain(x, None, "model"))(
                jnp.ones((4, 8), jnp.float32)
            )
        np.testing.assert_allclose(np.asarray(y), 1.0)

    def test_constrain_no_mesh_is_noop(self):
        from repro.distributed.hints import constrain

        x = jnp.ones((4, 8), jnp.float32)
        np.testing.assert_allclose(np.asarray(constrain(x, "model", None)), 1.0)


class TestCostAnalysis:
    def _compiled(self):
        return (
            jax.jit(lambda x: jnp.tanh(x @ x))
            .lower(jax.ShapeDtypeStruct((16, 16), jnp.float32))
            .compile()
        )

    def test_dict_from_compiled(self):
        ca = compat.normalize_cost_analysis(self._compiled())
        assert isinstance(ca, dict)
        assert ca.get("flops", 0) > 0

    def test_dict_from_raw_result(self):
        raw = self._compiled().cost_analysis()
        assert compat.normalize_cost_analysis(raw)["flops"] > 0

    def test_list_dict_and_none_forms(self):
        assert compat.normalize_cost_analysis([{"flops": 3.0}]) == {"flops": 3.0}
        assert compat.normalize_cost_analysis({"flops": 3.0}) == {"flops": 3.0}
        assert compat.normalize_cost_analysis(None) == {}
        assert compat.normalize_cost_analysis([]) == {}

    def test_memory_analysis_has_peak(self):
        ma = compat.normalize_memory_analysis(self._compiled())
        for key in (
            "argument_bytes", "output_bytes", "temp_bytes", "alias_bytes",
            "peak_bytes",
        ):
            assert key in ma and ma[key] >= 0
        assert ma["argument_bytes"] > 0


class TestBackendRegistry:
    def test_builtins_registered(self):
        names = ops.registered_backends()
        for name in ("pallas", "pallas_interpret", "xla"):
            assert name in names

    def test_auto_resolves_to_available_backend(self):
        resolved = ops.resolve_backend("auto")
        assert resolved in ops.available_backends()

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            ops.resolve_backend("not-a-backend")
        with pytest.raises(ValueError):
            ops.set_default_backend("not-a-backend")

    def test_unavailable_backend_degrades_not_raises(self):
        ops.register_backend(
            "always_broken", lambda a, b, c, dt: a, available=False
        )
        try:
            with pytest.warns(RuntimeWarning):
                resolved = ops.resolve_backend("always_broken")
            assert resolved in ("pallas_interpret", "xla")
        finally:
            ops._REGISTRY.pop("always_broken")

    def test_registered_backend_is_callable_through_matmul(self):
        calls = []

        def doubling(a, b, c, out_dtype):
            calls.append(a.shape)
            return (2.0 * (a @ b)).astype(out_dtype)

        ops.register_backend("doubling", doubling)
        try:
            a = jnp.ones((4, 8), jnp.float32)
            b = jnp.ones((8, 4), jnp.float32)
            out = ops.matmul(a, b, backend="doubling")
            np.testing.assert_allclose(np.asarray(out), 16.0)
            assert calls
        finally:
            ops._REGISTRY.pop("doubling")

    def test_tile_cache_keys_on_shape_and_dtype(self):
        ops._tile_for.cache_clear()
        t1 = ops._tile_for(256, 512, 256, 2)
        t2 = ops._tile_for(256, 512, 256, 2)
        t3 = ops._tile_for(256, 512, 256, 4)
        assert t1 == t2
        assert isinstance(t3, tuple) and len(t3) == 3
        info = ops._tile_for.cache_info()
        assert info.hits >= 1 and info.misses == 2

    def test_matmul_default_backend_matches_reference(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        got = ops.matmul(a, b)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=1e-5, atol=1e-5
        )
