"""The 10 configs must match the assignment table exactly."""

import pytest

from repro.configs import ARCHS, applicable_shapes
from repro.models import api

# (layers, d_model, heads, kv, d_ff, vocab) straight from the assignment.
ASSIGNED = {
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
    "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "xlstm-125m": (12, 768, 4, 4, 3072, 50304),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
}

MOE = {
    "grok-1-314b": (8, 2),
    "deepseek-moe-16b": (64, 6),
    "jamba-v0.1-52b": (16, 2),
}


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_config_matches_assignment(name):
    cfg = ARCHS[name]
    layers, d, h, kv, ff, v = ASSIGNED[name]
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv == kv
    assert cfg.d_ff == ff
    assert cfg.vocab == v
    if name in MOE:
        e, k = MOE[name]
        assert cfg.moe.n_experts == e and cfg.moe.top_k == k
    else:
        assert cfg.moe is None or name in MOE


def test_long_500k_applicability():
    runs_long = {n for n, c in ARCHS.items() if c.supports_long}
    assert runs_long == {"jamba-v0.1-52b", "xlstm-125m"}
    for name, cfg in ARCHS.items():
        names = [s.name for s in applicable_shapes(cfg)]
        assert ("long_500k" in names) == (name in runs_long)


@pytest.mark.parametrize(
    "name,target_b,tol",
    [
        ("grok-1-314b", 314e9, 0.03),
        ("jamba-v0.1-52b", 52e9, 0.03),
        ("deepseek-moe-16b", 16.4e9, 0.05),
        ("qwen2.5-32b", 32.5e9, 0.03),
        ("gemma2-9b", 9.2e9, 0.05),
        ("llava-next-mistral-7b", 7.2e9, 0.05),
    ],
)
def test_param_counts_match_published(name, target_b, tol):
    n = api.param_count(ARCHS[name])
    assert abs(n - target_b) / target_b < tol, n / 1e9
