"""Subprocess dry-run smoke: the multi-pod path end-to-end on 8 fake devices.

The real 512-device matrix runs via ``python -m repro.launch.dryrun`` (see
experiments/dryrun); here a (2,2,2) pod mesh proves the same code path —
XLA_FLAGS forcing, mesh construction, input_specs, sharding rules, lower,
compile, census — inside the test suite without touching this process's
device count.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import input_specs, run_cell

cfg = get_config("chatglm3-6b").reduced()
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
recs = []
for shape in (ShapeConfig("t", 64, 4, "train"),
              ShapeConfig("p", 64, 4, "prefill"),
              ShapeConfig("d", 64, 4, "decode")):
    rec = run_cell(cfg, shape, mesh, mesh_name="test")
    recs.append({"kind": shape.kind, "status": rec["status"],
                 "flops": rec["cost"]["flops_per_device"],
                 "fits": rec["memory"]["fits_16gb"],
                 "coll": rec["roofline"]["collective_bytes_per_device"]})
print("RESULT " + json.dumps(recs))
"""


@pytest.mark.slow
def test_dryrun_pod_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    recs = json.loads(line[len("RESULT "):])
    assert len(recs) == 3
    for r in recs:
        assert r["status"] == "ok"
        assert r["flops"] > 0
        assert r["fits"]
    # a pod mesh must actually communicate
    assert any(r["coll"] > 0 for r in recs)
