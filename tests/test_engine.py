"""O-POPE engine cycle model: paper-claim validation + property tests."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the `test` extra
    from _hypothesis_fallback import given, settings, st

from repro.core.engine import (
    EngineConfig,
    simulate_gemm,
    simulate_gemm_cycle_accurate,
)
from repro.core.dataflows import ACCELERATORS
from repro.core.sota import (
    PUBLISHED_TABLE2,
    buffer_share,
    fig5_geomean_scaling,
    table2_model,
)
from repro.core.tiling import ClusterConfig, choose_tile, tiled_gemm_cycles


class TestPaperClaims:
    def test_headline_9997_utilization(self):
        """§III-C: 64x256x128 on a 4x4 mesh reaches 99.97% FPU utilization."""
        r = simulate_gemm(EngineConfig(p=4), 64, 256, 128)
        assert round(100 * r.utilization, 2) == 99.97

    def test_peak_gflops_match_table2(self):
        """Table II: peak GFLOPS per accelerator (2 * 256 MACs * f_max)."""
        for name, (gflops, _, _) in PUBLISHED_TABLE2.items():
            got = ACCELERATORS[name].peak_gflops
            assert abs(got - gflops) / gflops < 0.01, (name, got)

    def test_table2_density_and_efficiency(self):
        t = table2_model()
        # O-POPE's analytical area/power land within 2% of published.
        assert abs(t["o-pope"]["gflops_per_mm2"] - 2336) / 2336 < 0.02
        assert abs(t["o-pope"]["tflops_per_w"] - 3.18) / 3.18 < 0.02
        # Ordering claims: O-POPE best on all three metrics.
        for metric in ("gflops", "gflops_per_mm2", "tflops_per_w"):
            vals = {
                n: v[metric] for n, v in t.items() if v[metric] == v[metric]
            }
            assert max(vals, key=vals.get) == "o-pope", metric

    def test_fig5_area_scaling_band(self):
        """Fig 5a: geomean area ratio per 4x MACs in [3.27, 3.79] for the
        evaluated FP16 config (other MAC kinds within a small tolerance)."""
        assert 3.27 <= fig5_geomean_scaling("fp16") <= 3.79
        for kind in ("fp8_to_fp16", "fp32", "fp16_to_fp32+fp32"):
            assert 3.2 <= fig5_geomean_scaling(kind) <= 3.95

    def test_fig5_buffer_share(self):
        """Fig 5b: input-buffer share decreases with size; < 2% at 32x32."""
        shares = [buffer_share(EngineConfig(p=p)) for p in (4, 8, 16, 32)]
        assert all(a > b for a, b in zip(shares, shares[1:]))
        assert shares[-1] < 0.02

    def test_fig6_small_k_hurts(self):
        """§III-C: K < 2p cannot hide the C-tile swap."""
        cfg = EngineConfig(p=8)
        u = [simulate_gemm(cfg, 32, k, 32).utilization for k in (4, 8, 16, 32, 256)]
        assert all(a < b for a, b in zip(u, u[1:]))
        assert u[0] < 0.5 < u[-1]

    def test_fig6_alignment_matters(self):
        """M, N multiples of 2p reach higher utilization."""
        cfg = EngineConfig(p=8)
        aligned = simulate_gemm(cfg, 64, 256, 128).utilization
        ragged = simulate_gemm(cfg, 65, 256, 129).utilization
        assert aligned > ragged

    def test_fig6_smaller_mesh_higher_util(self):
        """Smaller engines amortize overheads better on ragged workloads."""
        us = [
            simulate_gemm(EngineConfig(p=p), 196, 256, 1536).utilization
            for p in (4, 8, 16, 32)
        ]
        assert all(a > b for a, b in zip(us, us[1:]))

    def test_fig7_runtime_ordering_and_band(self):
        """Fig 7: O-POPE fastest on every Table I layer. Raw-engine speedup
        stays near the paper's <=1.86x band (the published figure is for the
        DMA-tiled cluster integration; raw engine ratios run slightly higher
        on small-K layers)."""
        workloads = [
            (196, 256, 1536), (196, 768, 256), (768, 196, 196),
            (197, 768, 768), (784, 512, 256), (2048, 768, 64),
            (2048, 128, 2048),
        ]
        for m, k, n in workloads:
            times = {a: ACCELERATORS[a].runtime_us(m, k, n) for a in ACCELERATORS}
            assert min(times, key=times.get) == "o-pope", (m, k, n)
            speedup = max(times.values()) / times["o-pope"]
            assert speedup <= 2.1, (m, k, n, speedup)


class TestCycleModelProperties:
    @settings(max_examples=150, deadline=None)
    @given(
        p=st.sampled_from([2, 4, 8]),
        m=st.integers(1, 80),
        k=st.integers(1, 80),
        n=st.integers(1, 80),
    )
    def test_closed_form_equals_cycle_accurate(self, p, m, k, n):
        cfg = EngineConfig(p=p)
        a = simulate_gemm(cfg, m, k, n)
        b = simulate_gemm_cycle_accurate(cfg, m, k, n)
        assert a.total_cycles == b.total_cycles

    @settings(max_examples=100, deadline=None)
    @given(
        p=st.sampled_from([2, 4, 8, 16]),
        m=st.integers(1, 512),
        k=st.integers(1, 512),
        n=st.integers(1, 512),
    )
    def test_utilization_bounds(self, p, m, k, n):
        r = simulate_gemm(EngineConfig(p=p), m, k, n)
        assert 0.0 < r.utilization <= 1.0
        assert r.total_cycles >= math.ceil(r.ideal_cycles)

    @settings(max_examples=60, deadline=None)
    @given(p=st.sampled_from([4, 8]), m=st.integers(1, 128), n=st.integers(1, 128))
    def test_monotone_in_k(self, p, m, n):
        cfg = EngineConfig(p=p)
        u1 = simulate_gemm(cfg, m, 2 * cfg.p, n).utilization
        u2 = simulate_gemm(cfg, m, 8 * cfg.p, n).utilization
        assert u2 >= u1

    @settings(max_examples=60, deadline=None)
    @given(
        p=st.sampled_from([4, 8, 16]),
        mt=st.integers(1, 6),
        kt=st.integers(1, 6),
        nt=st.integers(1, 6),
    )
    def test_aligned_large_k_near_ideal(self, p, mt, kt, nt):
        """Aligned M,N and K >= 2p -> utilization within overheads of ideal."""
        cfg = EngineConfig(p=p)
        m, n = mt * cfg.tile_m, nt * cfg.tile_n
        k = 2 * cfg.p * kt
        r = simulate_gemm(cfg, m, k, n)
        overhead = cfg.cfg_cycles + 6 * cfg.p
        assert r.total_cycles <= r.ideal_cycles + overhead + r.n_tiles


class TestTiling:
    def test_paper_tile_fits_64kb(self):
        plan = choose_tile(EngineConfig(p=16), 2048, 1024, 2048)
        assert plan.total_bytes <= 64 * 1024
        assert plan.tm % 32 == 0 and plan.tn % 32 == 0
        assert plan.tk >= 32

    @settings(max_examples=50, deadline=None)
    @given(
        m=st.integers(64, 4096),
        k=st.integers(64, 4096),
        n=st.integers(64, 4096),
    )
    def test_tiled_utilization_reasonable(self, m, k, n):
        res = tiled_gemm_cycles(EngineConfig(p=16), m, k, n)
        assert 0 < res["utilization"] <= 1.0
        assert res["bound"] in ("compute", "dma")

    def test_double_buffering_helps(self):
        eng = EngineConfig(p=16)
        on = tiled_gemm_cycles(eng, 2048, 1024, 2048,
                               cluster=ClusterConfig(double_buffer=True))
        off = tiled_gemm_cycles(eng, 2048, 1024, 2048,
                                cluster=ClusterConfig(double_buffer=False))
        assert on["total_cycles"] < off["total_cycles"]
