"""Fused-epilogue lane: registry, requant, model wiring, and the HLO census.

Four layers of coverage (the backend x epilogue numerics contract itself
lives in test_backend_conformance.py):

* registry semantics — spec normalization, operand canonicalization,
  unknown-name/missing-operand errors, the ACT2FN naming authority;
* the requant_int8 lane — exact int8-grid outputs, STE gradients, and the
  pre-quantized chain into the next q8 GEMM (no dequant round trip);
* model wiring — mlp_apply / _expert_ffn / attention residual produce the
  same numbers as the pre-refactor unfused compositions;
* the decode-step HLO census — zero standalone elementwise passes over
  GEMM-sized tensors on the hot path (the PR's acceptance metric), with a
  positive control proving the census catches missed fusions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import epilogue as epi
from repro.kernels import ops

ops._load_plugin_backends()


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_normalize_spec_forms():
    r = jnp.ones((4, 4))
    assert epi.normalize_epilogue(None) == ((), ())
    assert epi.normalize_epilogue("silu") == (("silu",), ())
    steps, ops_ = epi.normalize_epilogue(("residual", r))
    assert steps == ("residual",) and len(ops_) == 1
    # A 2-tuple whose second element is itself a step is a SEQUENCE, not a
    # single step with an operand — the ambiguity the parser must get right.
    steps, ops_ = epi.normalize_epilogue(("silu", ("mul", r)))
    assert steps == ("silu", "mul") and len(ops_) == 1
    steps, ops_ = epi.normalize_epilogue([("bias", r[0]), "gelu"])
    assert steps == ("bias", "gelu") and len(ops_) == 1


def test_unknown_and_malformed_specs_raise():
    r = jnp.ones((4, 4))
    with pytest.raises(ValueError, match="unknown epilogue op"):
        epi.normalize_epilogue("gelluu")
    with pytest.raises(ValueError):
        epi.normalize_epilogue([("residual",)])  # missing operand
    with pytest.raises(ValueError):
        epi.normalize_epilogue([("silu", r)])  # operand for a no-operand op


def test_operand_shape_validation():
    with pytest.raises(ValueError):
        epi.canonicalize_operands(("bias",), (jnp.ones(7),), n=8, m=4)
    with pytest.raises(ValueError):
        epi.canonicalize_operands(("residual",), (jnp.ones((3, 8)),), n=8, m=4)


def test_act2fn_is_the_single_naming_authority():
    from repro.models import layers

    assert layers.ACT2FN is epi.ACTIVATIONS
    assert set(layers.ACT2FN) >= {"gelu", "silu", "swish", "relu"}
    with pytest.raises(ValueError, match="unknown activation"):
        layers.activation_fn("gelUU")
    # swish is HF's name for silu — same callable semantics.
    x = jnp.linspace(-3, 3, 32)
    np.testing.assert_array_equal(
        np.asarray(layers.ACT2FN["swish"](x)), np.asarray(layers.ACT2FN["silu"](x))
    )


def test_epilogue_capable_reflects_registration():
    assert ops.epilogue_capable("pallas_interpret")
    assert not ops.epilogue_capable("xla")
    with pytest.raises(ValueError, match="unknown"):
        ops.epilogue_capable("no_such_backend")


def test_linear_threads_epilogue():
    rng = _rng(1)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(12), jnp.float32)
    got = ops.linear(x, w, b, backend="xla", epilogue=["gelu"])
    want = jax.nn.gelu(ops.matmul(x, w, backend="xla", out_dtype=jnp.float32) + b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# the requant_int8 lane
# ---------------------------------------------------------------------------


def test_requant_output_is_exactly_on_the_int8_grid():
    rng = _rng(2)
    a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
    scale = jnp.float32(0.35)
    q = ops.matmul(
        a, b, backend="xla", epilogue=[("requant_int8", scale)],
        out_dtype=jnp.int8,
    )
    assert q.dtype == jnp.int8
    acc = ops.matmul(a, b, backend="xla", out_dtype=jnp.float32)
    want = np.clip(np.round(np.asarray(acc) / 0.35), -127, 127).astype(np.int8)
    np.testing.assert_array_equal(np.asarray(q), want)


def test_requant_ste_gradients():
    rng = _rng(3)
    a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
    scale = jnp.float32(0.5)

    def f(a):
        return ops.matmul(
            a, b, backend="xla", epilogue=[("requant_int8", scale)]
        ).sum()

    da = jax.grad(f)(a)
    # STE: d(clip(round(acc/s)))/d(acc) ~= 1/s inside the clip range.
    acc = np.asarray(ops.matmul(a, b, backend="xla", out_dtype=jnp.float32))
    inside = (np.abs(acc / 0.5) <= 127).astype(np.float32)
    da_ref = (inside / 0.5) @ np.asarray(b).T
    np.testing.assert_allclose(np.asarray(da), da_ref, rtol=1e-4, atol=1e-4)


def test_prequantized_chain_skips_the_round_trip():
    # Layer N writes int8 via the requant epilogue; layer N+1's q8 GEMM
    # consumes it directly (duck-typed .q/.scale) — and the result matches
    # dequantize-then-quantize to fp32 rounding, since the values are
    # IDENTICAL int8 grids either way.
    rng = _rng(4)
    a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((24, 20)), jnp.float32)
    scale = jnp.float32(0.35)
    q = ops.matmul(
        a, w1, backend="xla_q8", epilogue=[("requant_int8", scale)],
        out_dtype=jnp.int8,
    )

    class Carrier:
        def __init__(self, q, scale):
            self.q, self.scale = q, scale

    got = ops.matmul(Carrier(q, scale), w2, backend="xla_q8")
    assert got.dtype == jnp.float32
    # Reference: dequantize explicitly, then run the same q8 GEMM on it.
    # That path RE-quantizes h dynamically (per-row amax grid != the requant
    # grid), so the two agree to the q8 quantization envelope, not to fp
    # rounding — the point of the lane is skipping exactly that second
    # quantization pass.
    h = q.astype(jnp.float32) * scale
    want = ops.matmul(h, w2, backend="xla_q8")
    err = float(jnp.max(jnp.abs(got - want)))
    assert err <= 0.03 * float(jnp.max(jnp.abs(want))), err
    # And both stay within the quantization envelope of the fp composition.
    fp = ops.matmul(h, w2, backend="xla")
    assert float(jnp.max(jnp.abs(got - fp))) <= 0.03 * float(
        jnp.max(jnp.abs(fp))
    )


def test_prequantized_rejects_fp_backends():
    class Carrier:
        def __init__(self, q, scale):
            self.q, self.scale = q, scale

    q = jnp.zeros((4, 8), jnp.int8)
    with pytest.raises(ValueError, match="q8-family"):
        ops.matmul(Carrier(q, jnp.float32(0.1)), jnp.zeros((8, 4)), backend="xla")


def test_policy_requant_roles_validated():
    from repro.quant.policy import PrecisionPolicy, mlp_q8_policy

    with pytest.raises(ValueError, match="requant roles"):
        PrecisionPolicy(requant={"nonsense": 0.1})
    pol = mlp_q8_policy(moe=False, requant_scale=0.25)
    assert pol.requant_for("mlp") == 0.25
    assert pol.requant_for("attn_out") is None


# ---------------------------------------------------------------------------
# model wiring == unfused compositions
# ---------------------------------------------------------------------------


def test_mlp_apply_matches_unfused_composition():
    from repro.models.layers import Initializer, mlp_init, mlp_apply

    key = jax.random.key(0)
    p = mlp_init(key, 32, 64, Initializer(dtype=jnp.float32))
    x = jnp.asarray(_rng(5).standard_normal((2, 8, 32)), jnp.float32)
    res = jnp.asarray(_rng(6).standard_normal((2, 8, 32)), jnp.float32)
    want = (
        jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    ) @ p["w_down"] + res
    got = mlp_apply(p, x, backend="xla", residual=res)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_mlp_apply_requant_lane_feeds_down_gemm_prequantized(monkeypatch):
    # With a policy that declares a requant scale for the role, the hidden
    # activation must reach the down GEMM as a pre-quantized carrier (.q
    # int8), not as a float tensor — asserted by intercepting the matmul.
    from repro.models import layers
    from repro.quant.policy import mlp_q8_policy

    key = jax.random.key(1)
    p = layers.mlp_init(key, 32, 64, layers.Initializer(dtype=jnp.float32))
    x = jnp.asarray(_rng(7).standard_normal((4, 32)), jnp.float32) * 0.5
    pol = mlp_q8_policy(moe=False, requant_scale=0.02)

    seen = []
    orig = ops.matmul

    def spy(a, b, *args, **kwargs):
        seen.append(a)
        return orig(a, b, *args, **kwargs)

    monkeypatch.setattr(layers.ops, "matmul", spy)
    out = layers.mlp_apply(p, x, backend=pol)
    assert out.dtype == x.dtype
    down_in = seen[-1]
    assert hasattr(down_in, "q") and down_in.q.dtype == jnp.int8
    # And the numbers stay within the quantization envelope of the fp path.
    want = (
        jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    ) @ p["w_down"]
    err = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
    assert err < 0.1, err


def test_expert_ffn_matches_unfused_composition():
    from repro.models.moe import _expert_ffn

    rng = _rng(8)
    e, c, d, f = 3, 8, 16, 32
    p = {
        "w_gate": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, f, d)), jnp.float32),
    }
    xs = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    want = jnp.einsum(
        "ecf,efd->ecd",
        jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"]))
        * jnp.einsum("ecd,edf->ecf", xs, p["w_up"]),
        p["w_down"],
    )
    got = _expert_ffn(p, xs, backend="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_attention_residual_rides_the_output_projection():
    from repro.models import attention as attn
    from repro.models.layers import Initializer

    key = jax.random.key(2)
    p = attn.attention_init(key, 32, 4, 2, 8, Initializer(dtype=jnp.float32))
    x = jnp.asarray(_rng(9).standard_normal((2, 16, 32)), jnp.float32)
    base, _ = attn.attention_apply(
        p, x, n_heads=4, n_kv=2, head_dim=8, backend="xla"
    )
    fused, _ = attn.attention_apply(
        p, x, n_heads=4, n_kv=2, head_dim=8, backend="xla", residual=x
    )
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(base + x), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# the tuner's fused-vs-post-hoc verdict
# ---------------------------------------------------------------------------


def _entry(table_mod, backend, m, k, n, fuse):
    return table_mod.TuneEntry(
        key=table_mod.TuneKey(
            backend=backend, shape_family="dense", m=m, k=k, n=n, g=0,
            dtype="float32", device_kind=table_mod.device_kind(),
        ),
        block=(8, 128, 128), us=1.0, gflops=1.0, fuse_epilogue=fuse,
    )


def test_tuned_fusion_verdict_reaches_the_lane(tmp_path, monkeypatch):
    from repro.tune import table as table_mod

    t = table_mod.TuningTable()
    t.put(_entry(table_mod, "pallas_interpret", 48, 96, 72, False))
    monkeypatch.setattr(ops, "_tuning_table", lambda: t)
    ops.clear_tile_cache()
    try:
        assert (
            ops.fusion_source("pallas_interpret", 48, 96, 72) == "tuned"
        )
        assert ops.fusion_source("pallas_interpret", 8, 8, 8) == "default"
        # the verdict=False shape runs post-hoc; numerics are identical
        a = jnp.asarray(_rng(10).standard_normal((48, 96)), jnp.float32)
        b = jnp.asarray(_rng(11).standard_normal((96, 72)), jnp.float32)
        got = ops.matmul(a, b, backend="pallas_interpret", epilogue="gelu")
        want = jax.nn.gelu(
            ops.matmul(a, b, backend="pallas_interpret", out_dtype=jnp.float32)
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
    finally:
        ops.clear_tile_cache()


def test_fuse_epilogue_survives_json_roundtrip(tmp_path):
    from repro.tune import table as table_mod

    t = table_mod.TuningTable()
    t.put(_entry(table_mod, "pallas", 8, 8, 8, True))
    t.put(_entry(table_mod, "pallas", 16, 8, 8, None))
    path = str(tmp_path / "table.json")
    t.save(path)
    t2 = table_mod.TuningTable.load(path)
    assert t2.lookup_fusion(
        backend="pallas", shape_family="dense", m=8, k=8, n=8, itemsize=4
    ) is True
    assert t2.lookup_fusion(
        backend="pallas", shape_family="dense", m=16, k=8, n=8, itemsize=4
    ) is None


# ---------------------------------------------------------------------------
# the decode-step HLO census (the PR's acceptance metric)
# ---------------------------------------------------------------------------


def test_census_positive_control():
    # The census MUST flag a deliberately-unfused activation pass — if this
    # fails, the zero below is vacuous.
    from repro.core.hlo_census import elementwise_passes

    def unfused(a, b):
        return jnp.tanh(a @ b)

    a = jnp.ones((64, 64))
    txt = jax.jit(unfused).lower(a, a).compile().as_text()
    found = elementwise_passes(txt, min_elems=1024)
    assert found, "census failed to flag a standalone tanh over a GEMM output"
    assert any(f["op"] == "tanh" for f in found)


def test_census_exempts_scoped_passes():
    from repro.core.hlo_census import elementwise_passes

    def scoped(a, b):
        acc = a @ b
        with jax.named_scope("opope_epilogue"):
            return jax.nn.silu(acc)

    a = jnp.ones((64, 64))
    txt = jax.jit(scoped).lower(a, a).compile().as_text()
    assert elementwise_passes(txt, min_elems=1024) == []


@pytest.mark.slow
def test_decode_step_has_zero_standalone_elementwise_passes():
    # THE acceptance criterion of the fused-epilogue refactor: a reduced
    # decode step compiles with no elementwise-compute instruction over a
    # GEMM-sized tensor outside the exempt scopes (epilogue lane, norms,
    # rope, attention core). Residual adds, activations and gating all ride
    # GEMM writebacks now; a regression reintroducing a standalone pass
    # shows up here with its HLO location.
    from repro.configs import ARCHS
    from repro.core.hlo_census import elementwise_passes
    from repro.models import api

    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    _, caches = api.prefill(
        cfg, params, {"tokens": tokens}, max_len=16, cache_dtype=jnp.float32
    )
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(8, jnp.int32)
    step = jax.jit(lambda p, t, c, q: api.decode(cfg, p, t, c, q))
    txt = step.lower(params, tok, caches, pos).compile().as_text()
    found = elementwise_passes(txt, min_elems=2 * cfg.d_model)
    assert found == [], (
        "standalone elementwise passes on the decode hot path:\n"
        + "\n".join(str(f) for f in found)
    )
