"""benchmarks/history.py + the ``repro-stats bench`` regression gate.

The gate's contract: a committed-baseline row and a fresh row from the same
code pass; a synthetically regressed row (an order of magnitude past even
the generous wall-clock tolerances) fails with exit 1; metrics present in
only one row are informational, never fatal.
"""

import json
import os

import pytest

_BENCHMARKS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")


def _history():
    import sys

    sys.path.insert(0, _BENCHMARKS_DIR)
    try:
        import history
    finally:
        sys.path.pop(0)
    return history


def _run_module():
    import sys

    sys.path.insert(0, _BENCHMARKS_DIR)
    try:
        import run as bench_run
    finally:
        sys.path.pop(0)
    return bench_run


META = {"git_commit": "abc123", "device_kind": "cpu", "jax_version": "0.4"}
METRICS = {
    "continuous.tokens_per_step": 1.5,
    "continuous.ttft_p99": 0.080,
    "gflops_tuned/pallas/fp:256x256x256": 12.0,
    "serving.greedy_agreement": 1.0,
}


class TestRows:
    def test_append_and_load_roundtrip(self, tmp_path):
        hist = _history()
        p = hist.append_row("t", METRICS, META, directory=str(tmp_path))
        assert p == hist.history_path("t", str(tmp_path))
        hist.append_row("t", METRICS, META, directory=str(tmp_path))
        rows = hist.load_rows("t", str(tmp_path))
        assert len(rows) == 2
        assert rows[0]["meta"] == META
        assert rows[0]["metrics"] == METRICS

    def test_rows_have_stable_key_order(self, tmp_path):
        hist = _history()
        hist.append_row(
            "t", {"b": 1.0, "a": 2.0}, {"z": "1", "a": "2"},
            directory=str(tmp_path),
        )
        raw = open(hist.history_path("t", str(tmp_path))).read()
        row = json.loads(raw)
        assert list(row["meta"]) == ["a", "z"]
        assert list(row["metrics"]) == ["a", "b"]

    def test_null_metrics_survive(self, tmp_path):
        hist = _history()
        hist.append_row(
            "t", {"ttft_p99": None}, META, directory=str(tmp_path)
        )
        rows = hist.load_rows("t", str(tmp_path))
        assert rows[0]["metrics"]["ttft_p99"] is None


class TestDiff:
    def _row(self, metrics):
        return {"meta": META, "metrics": metrics}

    def test_identical_rows_pass(self):
        hist = _history()
        findings = hist.diff_rows(self._row(METRICS), self._row(METRICS))
        assert all(f.status in ("ok", "untracked") for f in findings)

    def test_synthetic_regression_fails(self):
        """The CI acceptance scenario: ~100x worse wall-clock metrics land
        far beyond even the 10x machine-variance allowance."""
        hist = _history()
        bad = dict(METRICS)
        bad["continuous.ttft_p99"] = METRICS["continuous.ttft_p99"] * 100
        bad["gflops_tuned/pallas/fp:256x256x256"] = (
            METRICS["gflops_tuned/pallas/fp:256x256x256"] / 100
        )
        findings = hist.diff_rows(self._row(METRICS), self._row(bad))
        regressed = {f.metric for f in findings if f.status == "regression"}
        assert regressed == {
            "continuous.ttft_p99",
            "gflops_tuned/pallas/fp:256x256x256",
        }

    def test_deterministic_metrics_gate_tight(self):
        hist = _history()
        bad = dict(METRICS)
        bad["serving.greedy_agreement"] = 0.95  # >1% drop in agreement
        bad["continuous.tokens_per_step"] = 1.35  # 10% drop, 5% allowed
        findings = hist.diff_rows(self._row(METRICS), self._row(bad))
        regressed = {f.metric for f in findings if f.status == "regression"}
        assert "serving.greedy_agreement" in regressed
        assert "continuous.tokens_per_step" in regressed

    def test_wallclock_noise_is_tolerated(self):
        hist = _history()
        noisy = dict(METRICS)
        noisy["continuous.ttft_p99"] = METRICS["continuous.ttft_p99"] * 5
        noisy["gflops_tuned/pallas/fp:256x256x256"] = 12.0 / 5
        findings = hist.diff_rows(self._row(METRICS), self._row(noisy))
        assert not [f for f in findings if f.status == "regression"]

    def test_one_sided_metrics_are_informational(self):
        hist = _history()
        cur = dict(METRICS)
        cur.pop("serving.greedy_agreement")
        cur["brand_new_metric"] = 1.0
        findings = hist.diff_rows(self._row(METRICS), self._row(cur))
        by_metric = {f.metric: f.status for f in findings}
        assert by_metric["serving.greedy_agreement"] == "missing"
        assert by_metric["brand_new_metric"] == "new"
        assert "regression" not in by_metric.values()

    def test_null_current_is_missing_not_regression(self):
        hist = _history()
        cur = dict(METRICS)
        cur["continuous.ttft_p99"] = None  # empty trace this run
        findings = hist.diff_rows(self._row(METRICS), self._row(cur))
        by_metric = {f.metric: f.status for f in findings}
        assert by_metric["continuous.ttft_p99"] == "missing"

    def test_tolerance_directionality(self):
        hist = _history()
        tol_up = hist.Tolerance("x", "higher", 0.1)
        assert tol_up.regressed(100.0, 89.0)
        assert not tol_up.regressed(100.0, 91.0)
        assert not tol_up.regressed(100.0, 500.0)  # improvements never fail
        tol_dn = hist.Tolerance("x", "lower", 0.1)
        assert tol_dn.regressed(100.0, 111.0)
        assert not tol_dn.regressed(100.0, 109.0)
        assert not tol_dn.regressed(100.0, 1.0)


class TestBenchMeta:
    def test_meta_keys_and_order(self):
        meta = _run_module().bench_meta()
        assert list(meta) == ["git_commit", "device_kind", "jax_version"]
        assert all(isinstance(v, str) and v for v in meta.values())
        assert meta["git_commit"] != "unknown"  # we run inside the repo


class TestStatsBenchCLI:
    def _seed_history(self, tmp_path, *rows):
        hist = _history()
        for metrics in rows:
            hist.append_row("serving", metrics, META,
                            directory=str(tmp_path))

    def test_gate_passes_identical_rows(self, tmp_path, capsys):
        from repro.launch.stats import main as stats_main

        self._seed_history(tmp_path, METRICS, METRICS)
        stats_main(["bench", "--dir", str(tmp_path)])
        out = capsys.readouterr().out
        assert "0 regression(s)" in out

    def test_gate_fails_synthetic_regression(self, tmp_path, capsys):
        from repro.launch.stats import main as stats_main

        bad = dict(METRICS)
        bad["continuous.ttft_p99"] = METRICS["continuous.ttft_p99"] * 100
        self._seed_history(tmp_path, METRICS, bad)
        with pytest.raises(SystemExit) as exc:
            stats_main(["bench", "--dir", str(tmp_path)])
        assert exc.value.code == 1
        assert "regression" in capsys.readouterr().out

    def test_warn_only_reports_but_passes(self, tmp_path, capsys):
        from repro.launch.stats import main as stats_main

        bad = dict(METRICS)
        bad["serving.greedy_agreement"] = 0.5
        self._seed_history(tmp_path, METRICS, bad)
        stats_main(["bench", "--dir", str(tmp_path), "--warn-only"])
        assert "1 regression(s)" in capsys.readouterr().out

    def test_current_file_mode(self, tmp_path, capsys):
        """CI feeds the gate a fresh row via --current-file (the synthetic
        regression check works the same way)."""
        from repro.launch.stats import main as stats_main

        self._seed_history(tmp_path, METRICS)
        bad = {"meta": META, "metrics": dict(
            METRICS, **{"continuous.ttft_p99": 99.0}
        )}
        cur = tmp_path / "cur.json"
        cur.write_text(json.dumps(bad))
        with pytest.raises(SystemExit) as exc:
            stats_main(["bench", "--dir", str(tmp_path),
                        "--current-file", str(cur)])
        assert exc.value.code == 1

    def test_commit_prefix_selector(self, tmp_path, capsys):
        hist = _history()
        hist.append_row("serving", METRICS, META, directory=str(tmp_path))
        hist.append_row(
            "serving", METRICS,
            dict(META, git_commit="def456"), directory=str(tmp_path),
        )
        from repro.launch.stats import main as stats_main

        stats_main(["bench", "--dir", str(tmp_path),
                    "--baseline", "abc", "--current", "def456"])
        out = capsys.readouterr().out
        assert "abc123" in out and "def456" in out

    def test_missing_history_is_an_error(self, tmp_path):
        from repro.launch.stats import main as stats_main

        with pytest.raises(SystemExit):
            stats_main(["bench", "--dir", str(tmp_path / "nope")])
