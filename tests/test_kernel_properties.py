"""Hypothesis property tests for the O-POPE GEMM kernels (interpret mode):
the 2-D kernel and the grouped family entry point (grouped ≡ stacked
per-group matmul; q8 grouped error bounded by the per-group scale bound)."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the `test` extra
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops
from repro.kernels.opope_gemm import opope_gemm
from repro.kernels.opope_grouped import opope_gemm_grouped
from repro.kernels.ref import reference_grouped_matmul, reference_matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([128]),  # lane-dim tiles stay 128-aligned
    bk=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_any_shape_any_blocks(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = opope_gemm(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    want = reference_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * k**0.5
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(8, 64),
    k=st.integers(32, 128),
    n=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_preload_linearity(m, k, n, seed):
    """A@B + C == (A@B) + C: the preload path adds exactly once."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    with_pre = opope_gemm(a, b, c, block_m=32, block_n=128, block_k=128,
                          interpret=True)
    without = opope_gemm(a, b, block_m=32, block_n=128, block_k=128,
                         interpret=True)
    np.testing.assert_allclose(
        np.asarray(with_pre), np.asarray(without) + np.asarray(c),
        rtol=1e-5, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# grouped family
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(1, 6),
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 48),
    bm=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_gemm_any_shape_any_blocks(g, m, k, n, bm, bk, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    got = opope_gemm_grouped(
        a, b, block_m=bm, block_n=128, block_k=bk, interpret=True
    )
    want = reference_grouped_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * k**0.5
    )


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(1, 5),
    m=st.integers(1, 32),
    k=st.integers(1, 64),
    n=st.integers(1, 32),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_matmul_equals_stacked_per_group_matmul(g, m, k, n, dtype, seed):
    """The grouped entry point is semantically G independent matmul calls —
    on the same backend family, for every shape and operand dtype."""
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32).astype(dt)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32).astype(dt)
    for backend in ("xla", "pallas_interpret"):
        got = ops.grouped_matmul(a, b, backend=backend)
        want = jnp.stack(
            [ops.matmul(a[i], b[i], backend=backend) for i in range(g)]
        )
        assert got.dtype == want.dtype == dt
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2 if dt == jnp.bfloat16 else 1e-5,
            atol=(2e-2 if dt == jnp.bfloat16 else 1e-5) * max(1.0, k**0.5),
        )


@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(1, 4),
    m=st.integers(1, 24),
    k=st.integers(4, 64),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_q8_error_bounded_by_per_group_scales(g, m, k, n, seed):
    """int8 grouped GEMM error is bounded by each group's OWN scale bound.

    With per-(group, row) scales sa[g, m] and per-(group, column) scales
    sb[g, n], each quantized product deviates by at most
    ``sa/2 * |b| + sb/2 * |a| + sa*sb/4`` — summed over K this is the exact
    deterministic bound the per-group quantization contract promises (no
    group's error depends on any other group's amax).
    """
    rng = np.random.default_rng(seed)
    # mix in a per-group magnitude skew so shared-amax quantization WOULD
    # violate the bound (the property is vacuous on iid operands)
    mags = rng.uniform(0.01, 100.0, size=(g, 1, 1))
    a = jnp.asarray(rng.standard_normal((g, m, k)) * mags, jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)) * mags, jnp.float32)
    got = np.asarray(ops.grouped_matmul(a, b, backend="xla_q8"), np.float64)
    want = np.asarray(reference_grouped_matmul(a, b), np.float64)

    an, bn = np.asarray(a, np.float64), np.asarray(b, np.float64)
    sa = np.maximum(np.abs(an).max(axis=2, keepdims=True), 1e-12) / 127.0
    sb = np.maximum(np.abs(bn).max(axis=1, keepdims=True), 1e-12) / 127.0
    # bound[g,m,n] = sum_k sa[g,m]/2 * |b[g,k,n]| + sb[g,n]/2 * |a[g,m,k]|
    #               + K * sa*sb/4
    bound = (
        0.5 * sa * np.abs(bn).sum(axis=1, keepdims=True)
        + 0.5 * np.abs(an).sum(axis=2, keepdims=True) * sb
        + k * 0.25 * sa * sb
    )
    assert np.all(np.abs(got - want) <= bound * 1.01 + 1e-6)
