"""Hypothesis property tests for the O-POPE GEMM kernel (interpret mode)."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the `test` extra
    from _hypothesis_fallback import given, settings, st

from repro.kernels.opope_gemm import opope_gemm
from repro.kernels.ref import reference_matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([128]),  # lane-dim tiles stay 128-aligned
    bk=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_any_shape_any_blocks(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = opope_gemm(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    want = reference_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * k**0.5
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(8, 64),
    k=st.integers(32, 128),
    n=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_preload_linearity(m, k, n, seed):
    """A@B + C == (A@B) + C: the preload path adds exactly once."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    with_pre = opope_gemm(a, b, c, block_m=32, block_n=128, block_k=128,
                          interpret=True)
    without = opope_gemm(a, b, block_m=32, block_n=128, block_k=128,
                         interpret=True)
    np.testing.assert_allclose(
        np.asarray(with_pre), np.asarray(without) + np.asarray(c),
        rtol=1e-5, atol=1e-5,
    )
