"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.opope_gemm import default_block_shape, opope_gemm, padding_waste
from repro.kernels.ref import reference_matmul

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


SHAPES = [
    (128, 256, 128),
    (64, 512, 128),
    (100, 200, 96),  # unaligned in every dim
    (33, 77, 130),
    (1, 128, 128),  # degenerate rows
    (256, 1, 64),  # K=1
]
DTYPES = [
    # (in, out, tol): bf16 output quantizes to ~2^-8 relative of |result|,
    # which for K=512 sums reaches ~0.15 absolute.
    (jnp.float32, jnp.float32, 1e-4),
    (jnp.bfloat16, jnp.float32, 5e-2),
    (jnp.bfloat16, jnp.bfloat16, 2e-1),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("in_dt,out_dt,tol", DTYPES)
def test_gemm_matches_oracle(m, k, n, in_dt, out_dt, tol):
    a, b = _rand((m, k), in_dt), _rand((k, n), in_dt)
    got = opope_gemm(a, b, block_m=64, block_n=128, block_k=128,
                     out_dtype=out_dt, interpret=True)
    want = reference_matmul(a, b, out_dtype=out_dt)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert _err(got, want) < tol


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (100, 200, 96)])
def test_gemm_c_preload(m, k, n):
    """The paper's accumulator-preload path: O = A@B + C fused."""
    a, b = _rand((m, k), jnp.float32), _rand((k, n), jnp.float32)
    c = _rand((m, n), jnp.float32)
    got = opope_gemm(a, b, c, block_m=64, block_n=128, block_k=128,
                     interpret=True)
    want = reference_matmul(a, b, c)
    assert _err(got, want) < 1e-4


def test_gemm_fp8_widening():
    """FP8 inputs with widening accumulation (paper's FP8->FP16 MAC)."""
    a = _rand((64, 128), jnp.float8_e4m3fn)
    b = _rand((128, 64), jnp.float8_e4m3fn)
    got = opope_gemm(a, b, out_dtype=jnp.bfloat16, block_m=64, block_n=64,
                     block_k=128, interpret=True)
    want = reference_matmul(a, b, out_dtype=jnp.bfloat16)
    assert _err(got, want) < 0.25  # fp8 quantization noise


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (100, 200, 96)])
def test_gemm_bias_row_preload(m, k, n):
    """[N] bias streams as one row per N tile and broadcasts at preload —
    no [M, N] C operand is ever materialized."""
    a, b = _rand((m, k), jnp.float32), _rand((k, n), jnp.float32)
    bias = _rand((n,), jnp.float32)
    got = opope_gemm(a, b, bias, block_m=64, block_n=128, block_k=128,
                     interpret=True)
    want = reference_matmul(a, b, bias)
    assert _err(got, want) < 1e-4


def test_linear_bias_grad_is_column_sum():
    ops.set_default_backend("pallas_interpret")
    try:
        x = _rand((4, 8, 64), jnp.float32)
        w = _rand((64, 48), jnp.float32)
        bias = _rand((48,), jnp.float32)
        f = lambda x, w, b: jnp.sum(ops.linear(x, w, b) ** 2)
        gx, gw, gb = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
        f2 = lambda x, w, b: jnp.sum((jnp.einsum("bsk,kn->bsn", x, w) + b) ** 2)
        gx2, gw2, gb2 = jax.grad(f2, argnums=(0, 1, 2))(x, w, bias)
        assert _err(gx, gx2) < 1e-2
        assert _err(gw, gw2) < 1e-2
        assert _err(gb, gb2) < 1e-3
    finally:
        ops.set_default_backend("auto")


def test_ops_linear_bias_via_preload():
    ops.set_default_backend("pallas_interpret")
    try:
        x = _rand((4, 32, 64), jnp.float32)
        w = _rand((64, 48), jnp.float32)
        bias = _rand((48,), jnp.float32)
        y = ops.linear(x, w, bias)
        want = np.einsum("bsk,kn->bsn", np.asarray(x), np.asarray(w)) + np.asarray(bias)
        assert float(np.max(np.abs(np.asarray(y) - want))) < 1e-4
    finally:
        ops.set_default_backend("auto")


def test_ops_vjp_matches_xla_grads():
    ops.set_default_backend("pallas_interpret")
    try:
        a = _rand((32, 64), jnp.float32)
        w = _rand((64, 48), jnp.float32)
        f = lambda a, w: jnp.sum(ops.matmul(a, w) ** 2)
        ga, gw = jax.grad(f, argnums=(0, 1))(a, w)
        f2 = lambda a, w: jnp.sum((a @ w) ** 2)
        ga2, gw2 = jax.grad(f2, argnums=(0, 1))(a, w)
        assert _err(ga, ga2) < 1e-2 and _err(gw, gw2) < 1e-2
    finally:
        ops.set_default_backend("auto")


def test_xla_backend_bitwise_matches_reference():
    a, b = _rand((64, 128), jnp.bfloat16), _rand((128, 32), jnp.bfloat16)
    got = ops.matmul(a, b, backend="xla")
    want = reference_matmul(a, b)
    assert _err(got, want) == 0.0


def test_padding_waste_mirrors_paper_quantization():
    # aligned: no waste; ragged: waste matches closed form
    assert padding_waste(256, 512, 256, 128, 128, 128) == 0.0
    w = padding_waste(100, 200, 96, 64, 128, 128)
    assert 0 < w < 1
    bm, bn, bk = default_block_shape(1024, 4096, 1024)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
