"""opope_attention / opope_chunked_scan vs their jnp oracles (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the `test` extra
    from _hypothesis_fallback import given, settings, st

from repro.kernels.opope_attention import opope_attention, opope_attention_bhsd
from repro.kernels.opope_scan import opope_chunked_scan
from repro.kernels.ref import reference_attention, reference_chunked_scan

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "s,t,d,causal",
    [
        (128, 128, 64, True),
        (100, 160, 64, True),  # unaligned + cache-continuation offset
        (96, 128, 32, False),
        (77, 77, 64, True),
        (256, 256, 128, True),
    ],
)
def test_attention_matches_oracle(s, t, d, causal):
    q = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((t, d)), jnp.float32)
    got = opope_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_batched_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    got = opope_attention_bhsd(q, k, v, block_q=32, block_k=32, interpret=True)
    want = jax.vmap(jax.vmap(lambda q, k, v: reference_attention(q, k, v)))(
        q, k, v
    )
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    assert err < 5e-2


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(16, 96),
    d=st.sampled_from([32, 64]),
    bq=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_property(s, d, bq, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    got = opope_attention(q, k, v, block_q=bq, block_k=bq, interpret=True)
    want = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("s,d,chunk", [(128, 64, 32), (100, 32, 64), (64, 128, 64)])
def test_chunked_scan_matches_oracle(s, d, chunk):
    decay = jnp.asarray(RNG.uniform(0.2, 0.99, (s, d)), jnp.float32)
    update = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    got = opope_chunked_scan(decay, update, chunk=chunk, interpret=True)
    want = reference_chunked_scan(decay, update)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
