"""Per-architecture smoke tests (reduced configs, CPU).

Every assigned arch: one forward/train step (loss + finite grads, exact
output shapes), one prefill and one decode step. Plus exactness checks:
prefill-state == full-sequence state (mamba), blockwise attention == naive
attention, MoE dispatch equivalence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import api
from repro.models.attention import blockwise_attention
from repro.models.kernels_ref_checks import naive_attention  # noqa: F401  (shared helper)

KEY = jax.random.key(0)


def make_batch(cfg, b, s, key=KEY):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : s - cfg.n_img_tokens]
        batch["labels"] = batch["labels"][:, : s - cfg.n_img_tokens]
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_img_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_train_step(name):
    cfg = ARCHS[name].reduced()
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 32)
    loss, grads = jax.value_and_grad(lambda p: api.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_prefill_decode(name):
    cfg = ARCHS[name].reduced()
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 32)
    logits, caches = api.prefill(cfg, params, batch, max_len=48,
                                 cache_dtype=jnp.float32)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None]
    pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    for i in range(3):
        logits, caches = api.decode(cfg, params, tok, caches, pos + i)
        assert logits.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1)[:, None]


def test_prefill_then_decode_matches_full_forward():
    """Decoding token s+1 after prefill(0..s) == forward over 0..s+1.

    This is the strongest correctness check of the cache machinery: it
    exercises RoPE offsets, cache indexing and state carry for a dense arch.
    """
    from repro.models.transformer import lm_forward, lm_logits

    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab)
    # full forward over all 17 tokens
    hidden, _, _ = lm_forward(params, toks, cfg, mode="train")
    full_logits = lm_logits(params, hidden, cfg)[:, -1]
    # prefill over 16 then decode the 17th
    logits, caches = api.prefill(
        cfg, params, {"tokens": toks[:, :16]}, max_len=32,
        cache_dtype=jnp.float32,
    )
    dec_logits, _ = api.decode(
        cfg, params, toks[:, 16:17], caches, jnp.asarray(16, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_prefill_then_decode_matches_full_forward_ssm():
    """Same consistency check through mamba + moe + attention (jamba)."""
    from repro.models.transformer import lm_forward, lm_logits

    cfg = ARCHS["jamba-v0.1-52b"].reduced()
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(2), (2, 17), 0, cfg.vocab)
    hidden, _, _ = lm_forward(params, toks, cfg, mode="train")
    full_logits = lm_logits(params, hidden, cfg)[:, -1]
    logits, caches = api.prefill(
        cfg, params, {"tokens": toks[:, :16]}, max_len=32,
        cache_dtype=jnp.float32,
    )
    dec_logits, _ = api.decode(
        cfg, params, toks[:, 16:17], caches, jnp.asarray(16, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=5e-2, atol=5e-2
    )


def test_blockwise_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    for window in (None, 16):
        for cap in (None, 30.0):
            got = blockwise_attention(
                q, k, v, causal=True, window=window, attn_softcap=cap,
                q_chunk=16, kv_chunk=16,
            )
            want = naive_attention(q, k, v, causal=True, window=window,
                                   attn_softcap=cap)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
            )


def test_moe_dispatch_modes_agree():
    from repro.models.layers import Initializer
    from repro.models.moe import moe_apply, moe_init

    p = moe_init(jax.random.key(0), 32, 64, 8, Initializer(dtype=jnp.float32))
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    kw = dict(n_experts=8, top_k=2, capacity_factor=8.0, group_size=16)
    y1, a1 = moe_apply(p, x, dispatch="onehot", **kw)
    y2, a2 = moe_apply(p, x, dispatch="sort", **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(jnp.abs(a1 - a2)) < 1e-6


def test_moe_capacity_drops_tokens():
    from repro.models.layers import Initializer
    from repro.models.moe import moe_apply, moe_init

    p = moe_init(jax.random.key(0), 16, 32, 4, Initializer(dtype=jnp.float32))
    x = jax.random.normal(jax.random.key(1), (1, 32, 16))
    full, _ = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=8.0,
                        dispatch="sort", group_size=32)
    tight, _ = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=0.25,
                         dispatch="sort", group_size=32)
    # token dropping must change (reduce) some outputs but keep shape/finite
    assert full.shape == tight.shape
    assert float(jnp.max(jnp.abs(full - tight))) > 1e-6
    assert np.isfinite(np.asarray(tight)).all()
