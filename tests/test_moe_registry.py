"""MoE expert compute on the backend registry.

The routed per-expert SwiGLU runs as grouped O-POPE GEMMs through
``ops.grouped_matmul`` (ISSUE 4 tentpole). These tests pin the contract:

* ``moe._expert_ffn`` contains no direct ``jnp.einsum`` GEMMs — all expert
  compute routes through the registry;
* ``PrecisionPolicy(moe=...)`` measurably changes the expert path (the role
  actually reaches the routed experts, not just the shared-expert MLP);
* dropless MoE decode agrees with teacher forcing now that experts route
  through the registry (cache path and train path share one GEMM substrate);
* a quantized-expert policy (``moe="pallas_q8"``) preserves >= 99% greedy
  token agreement on the trained reduced MoE model from ``quant_bench``.
"""

import inspect
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import import_quant_bench

from repro.configs import ARCHS
from repro.kernels import ops
from repro.models import api
from repro.models import moe as moe_mod
from repro.models.layers import Initializer
from repro.models.moe import moe_apply, moe_init
from repro.quant import PrecisionPolicy

MOE_ARCH = "deepseek-moe-16b"


def test_expert_ffn_has_no_direct_einsum_gemms():
    # The acceptance bar of ISSUE 4: the per-expert GEMMs may not bypass the
    # registry. Routing one-hots/dispatch einsums live elsewhere; the expert
    # FFN itself must be grouped_matmul all the way down.
    src = inspect.getsource(moe_mod._expert_ffn)
    assert "einsum" not in src
    assert "grouped_matmul" in src


def _moe_setup(seed=0, d=32, f=64, e=4):
    p = moe_init(jax.random.key(seed), d, f, e, Initializer(dtype=jnp.float32))
    x = jax.random.normal(jax.random.key(seed + 1), (2, 16, d))
    kw = dict(n_experts=e, top_k=2, capacity_factor=8.0, group_size=16)
    return p, x, kw


@pytest.mark.parametrize("dispatch", ["onehot", "sort"])
def test_policy_moe_role_reaches_expert_ffns(dispatch, monkeypatch):
    p, x, kw = _moe_setup()
    recorded = []
    orig = ops.grouped_matmul

    def recording(a, b, c=None, *, backend=None, **kwargs):
        recorded.append(backend)
        return orig(a, b, c, backend=backend, **kwargs)

    monkeypatch.setattr(ops, "grouped_matmul", recording)
    pol = PrecisionPolicy(rules={"moe": "xla_q8"})
    y_q, _ = moe_apply(p, x, dispatch=dispatch, backend=pol, **kw)
    assert recorded and all(be == "xla_q8" for be in recorded), recorded
    recorded.clear()
    y_fp, _ = moe_apply(p, x, dispatch=dispatch, **kw)
    assert recorded and all(be is None for be in recorded), recorded

    # the policy measurably changes the expert path: nonzero but bounded by
    # the q8 contract (this is what "the role reaches the experts" means
    # numerically — a policy that only touched the shared MLP would be 0 here
    # since this MoE has no shared experts)
    delta = float(jnp.max(jnp.abs(y_q - y_fp)))
    assert delta > 0.0
    assert delta < 0.1 * float(jnp.max(jnp.abs(y_fp)))


def test_expert_backend_override_changes_resolution():
    # a plain backend string routes the experts too (pre-policy behaviour)
    p, x, kw = _moe_setup(seed=3)
    y_xla, _ = moe_apply(p, x, dispatch="sort", backend="xla", **kw)
    y_q8, _ = moe_apply(p, x, dispatch="sort", backend="xla_q8", **kw)
    assert float(jnp.max(jnp.abs(y_xla - y_q8))) > 0.0


def test_dropless_moe_decode_matches_teacher_forcing():
    """Prefill + step decode == full-sequence forward for dropless MoE.

    Dropless capacity makes routing a pure per-token function, and with the
    expert GEMMs now on the registry the cache path and the train path share
    one GEMM substrate — so the two logit streams must agree everywhere, not
    just in argmax.
    """
    from repro.models.transformer import lm_forward, lm_logits

    cfg = ARCHS[MOE_ARCH].reduced()
    assert cfg.moe is not None and cfg.moe.dropless
    params = api.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 20), 0, cfg.vocab)

    hidden, _, _ = lm_forward(params, toks, cfg, mode="train")
    full_logits = lm_logits(params, hidden, cfg)  # [B, S, V]

    logits, caches = api.prefill(
        cfg, params, {"tokens": toks[:, :16]}, max_len=32,
        cache_dtype=jnp.float32,
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 15]), rtol=2e-2, atol=2e-2
    )
    for s in range(16, 20):  # teacher-force the decode path
        logits, caches = api.decode(
            cfg, params, toks[:, s : s + 1], caches, jnp.asarray(s, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, s]),
            rtol=2e-2, atol=2e-2,
        )
        assert np.array_equal(
            np.argmax(np.asarray(logits), -1),
            np.argmax(np.asarray(full_logits[:, s]), -1),
        )


# ---------------------------------------------------------------------------
# quantized experts end to end (trained model, greedy agreement)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained_moe_model():
    cfg = ARCHS[MOE_ARCH].reduced()
    params, loss = import_quant_bench().trained_model(
        cfg, steps=250, seed=0, seq_len=48
    )
    assert loss < 0.5  # the MoE model actually learned the cyclic task
    return cfg, params


@pytest.mark.slow
def test_quantized_experts_greedy_agreement(trained_moe_model):
    """PrecisionPolicy(moe="pallas_q8") >= 99% greedy agreement.

    The policy quantizes exactly the routed expert FFNs (this arch's periods
    are attn+moe; no dense mlp role fires) — on the trained reduced model
    the argmax margins are real, so disagreements measure quantization.
    ``pallas_q8`` resolves through its quantized fallback chain on CPU
    (interpret kernel), never to a full-precision path.
    """
    cfg, params = trained_moe_model
    qb = import_quant_bench()
    prompts = qb.cyclic_prompt_batch(cfg.vocab, n_prompts=8, prompt_len=12, seed=0)
    pol = PrecisionPolicy(rules={"moe": "pallas_q8"}, name="moe-q8")
    with warnings.catch_warnings():
        # CPU hosts degrade pallas_q8 -> pallas_q8_interpret (the quantized
        # family chain); the warning is the expected signal, not a failure.
        warnings.simplefilter("ignore", RuntimeWarning)
        got_fp = qb.greedy_decode(cfg, params, prompts, gen=16)
        got_q = qb.greedy_decode(cfg, params, prompts, gen=16, backend=pol)
    total = got_fp.size
    agree = int((got_fp == got_q).sum())
    assert total >= 100
    assert agree / total >= 0.99, f"{agree}/{total}"
