"""repro.obs: registry semantics, exporters, spans, events — and the
instrumented layers (kernels, serving, training) emitting through them.

The load-bearing claim is the last test class: ALL instrumentation is
host-side Python (executed at trace time inside ``jit``), so the compiled
decode-step HLO carries an identical instruction census whether telemetry
is on or off — ``REPRO_METRICS=0`` provably costs zero device work because
``REPRO_METRICS=1`` already does.
"""

import collections
import json
import re
import warnings

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro import obs
from repro.kernels import ops

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestCounters:
    def test_inc_and_labels(self):
        obs.counter("t.calls", backend="xla").inc()
        obs.counter("t.calls", backend="xla").inc(2)
        obs.counter("t.calls", backend="pallas").inc()
        snap = obs.snapshot()
        assert snap["counters"]["t.calls"]["backend=xla"] == 3.0
        assert snap["counters"]["t.calls"]["backend=pallas"] == 1.0

    def test_counters_only_go_up(self):
        with pytest.raises(ValueError):
            obs.counter("t.calls").inc(-1)

    def test_label_order_is_canonical(self):
        obs.counter("t.c", b="2", a="1").inc()
        obs.counter("t.c", a="1", b="2").inc()
        snap = obs.snapshot()
        assert snap["counters"]["t.c"] == {"a=1,b=2": 2.0}

    def test_metric_name_is_positional_only(self):
        # a label literally called "name" must not collide with the metric
        # name parameter (spans label their histogram by span name)
        obs.counter("t.named", name="x").inc()
        assert obs.snapshot()["counters"]["t.named"]["name=x"] == 1.0


class TestGauges:
    def test_set_and_add(self):
        obs.gauge("t.g").set(4.0)
        obs.gauge("t.g").add(-1.5)
        assert obs.snapshot()["gauges"]["t.g"][""] == 2.5


class TestHistograms:
    def test_summary_stats(self):
        h = obs.histogram("t.h")
        for v in (0.1, 0.2, 0.3, 0.4):
            h.observe(v)
        s = obs.snapshot()["histograms"]["t.h"][""]
        assert s["count"] == 4
        assert s["sum"] == pytest.approx(1.0)
        assert s["mean"] == pytest.approx(0.25)
        assert s["min"] == pytest.approx(0.1)
        assert s["max"] == pytest.approx(0.4)

    def test_cumulative_buckets(self):
        h = obs.histogram("t.b", buckets=[1.0, 2.0, 4.0])
        for v in (0.5, 1.5, 3.0, 100.0):
            h.observe(v)
        s = obs.snapshot()["histograms"]["t.b"][""]
        # snapshot buckets are cumulative counts per le-edge (+Inf last)
        assert s["buckets"] == {"1.0": 1, "2.0": 2, "4.0": 3, "+Inf": 4}

    def test_percentile_linear_interpolation(self):
        # no samples is "no answer", not "0.0 latency"
        assert obs.percentile([], 50) is None
        assert obs.percentile([3.0], 99) == 3.0
        assert obs.percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
        assert obs.percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0
        xs = list(range(101))
        assert obs.percentile(xs, 99) == pytest.approx(99.0)

    def test_reset_drops_everything(self):
        obs.counter("t.c").inc()
        obs.histogram("t.h").observe(1.0)
        obs.reset()
        snap = obs.snapshot()
        assert snap["counters"] == {} and snap["histograms"] == {}


class TestExporters:
    def test_to_json_roundtrips(self):
        obs.counter("t.c", x="1").inc()
        assert json.loads(obs.to_json())["counters"]["t.c"]["x=1"] == 1.0

    def test_prometheus_text(self):
        obs.counter("gemm.calls", backend="xla").inc(2)
        obs.gauge("serve.occupancy").set(0.5)
        obs.histogram("t.h").observe(0.3)
        text = obs.prometheus_text()
        assert 'repro_gemm_calls_total{backend="xla"} 2.0' in text
        assert "repro_serve_occupancy 0.5" in text
        assert "repro_t_h_count 1" in text
        assert 'repro_t_h_bucket{le="+Inf"} 1' in text

    def test_prometheus_from_file_snapshot(self):
        # the CLI renders snapshots other processes dumped: exporter must
        # work from a plain dict, not just the live registry
        obs.counter("t.c").inc()
        snap = json.loads(json.dumps(obs.snapshot()))
        obs.reset()
        assert "repro_t_c_total 1.0" in obs.prometheus_text(snap)


class TestDisabled:
    def test_disabled_fetches_are_null(self):
        prev = obs.set_enabled(False)
        try:
            c = obs.counter("t.off")
            c.inc(5)
            obs.histogram("t.off.h").observe(1.0)
            assert obs.snapshot()["counters"] == {}
        finally:
            obs.set_enabled(prev)

    def test_disabled_span_and_event_are_noops(self):
        prev = obs.set_enabled(False)
        try:
            with obs.span("t.span"):
                pass
            obs.event("t.kind", x=1)
            assert obs.snapshot()["histograms"] == {}
            assert obs.recent_events(10, kind="t.kind") == []
        finally:
            obs.set_enabled(prev)


# ---------------------------------------------------------------------------
# spans, logger, events
# ---------------------------------------------------------------------------


class TestSpans:
    def test_span_records_wall_time(self):
        with obs.span("t.block", phase="x"):
            pass
        s = obs.snapshot()["histograms"]["span.seconds"]["name=t.block,phase=x"]
        assert s["count"] == 1 and s["max"] >= 0.0

    def test_span_propagates_exceptions_but_still_records(self):
        with pytest.raises(RuntimeError):
            with obs.span("t.boom"):
                raise RuntimeError("boom")
        assert obs.snapshot()["histograms"]["span.seconds"]["name=t.boom"][
            "count"
        ] == 1


class TestLogger:
    def test_text_mode(self, capsys, monkeypatch):
        monkeypatch.delenv("REPRO_LOG", raising=False)
        obs.get_logger("serve").info("generated", tokens=128, wall_s=1.25)
        out = capsys.readouterr().out
        assert out == "[serve] generated tokens=128 wall_s=1.25\n"

    def test_json_mode(self, capsys, monkeypatch):
        monkeypatch.setenv("REPRO_LOG", "json")
        obs.get_logger("serve").info("generated", tokens=128)
        rec = json.loads(capsys.readouterr().out)
        assert rec["component"] == "serve"
        assert rec["event"] == "generated" and rec["tokens"] == 128

    def test_raw_passthrough_and_json_wrap(self, capsys, monkeypatch):
        monkeypatch.delenv("REPRO_LOG", raising=False)
        obs.get_logger("tune").raw("wrote 2 entries -> /tmp/t.json")
        assert capsys.readouterr().out == "wrote 2 entries -> /tmp/t.json\n"
        monkeypatch.setenv("REPRO_LOG", "json")
        obs.get_logger("tune").raw("hello world")
        assert json.loads(capsys.readouterr().out)["msg"] == "hello world"


class TestEvents:
    def test_ring_buffer_and_kind_filter(self):
        obs.event("a", i=1)
        obs.event("b", i=2)
        obs.event("a", i=3)
        evts = obs.recent_events(10, kind="a")
        assert [e["i"] for e in evts] == [1, 3]

    def test_jsonl_sink_and_read_back(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        prev = obs.set_event_log(path)
        try:
            obs.event("train_step", step=0, loss=2.5)
            obs.event("train_step", step=1, loss=2.25)
        finally:
            obs.set_event_log(prev)
        evts = obs.read_events(path)
        assert len(evts) == 2 and evts[1]["loss"] == 2.25
        assert obs.read_events(path, n=1)[0]["step"] == 1


# ---------------------------------------------------------------------------
# instrumented layers
# ---------------------------------------------------------------------------


class TestKernelTelemetry:
    def test_gemm_call_counter_labels(self):
        a = jnp.ones((4, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        ops.matmul(a, b, backend="xla")
        snap = obs.snapshot()
        key = "backend=xla,family=fp,fusion=none,shape=dense,tile=heuristic"
        assert snap["counters"]["gemm.calls"][key] == 1.0

    def test_grouped_gemm_call_counter(self):
        a = jnp.ones((2, 4, 16), jnp.float32)
        b = jnp.ones((2, 16, 8), jnp.float32)
        ops.grouped_matmul(a, b, backend="xla")
        fam = obs.snapshot()["counters"]["gemm.calls"]
        assert any("shape=grouped" in k for k in fam)

    def test_degradation_counter_and_event(self):
        # compiled pallas cannot lower on CPU: an explicit request degrades
        # along its chain — and the warning now has a telemetry twin
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            resolved = ops.resolve_backend("pallas")
        assert resolved != "pallas"
        fam = obs.snapshot()["counters"]["gemm.degradations"]
        (key,) = fam
        assert "requested=pallas" in key
        assert f"resolved={resolved}" in key
        assert "reason=backend_unavailable" in key
        evt = obs.recent_events(5, kind="degradation")[-1]
        assert evt["requested"] == "pallas" and evt["hop"] >= 1

    def test_tile_lookup_stats_and_counter(self):
        ops.reset_tile_cache_stats()
        ops._tile_for(1234, 256, 128, 4)
        ops._tile_for(1234, 256, 128, 4)
        st = ops.tile_cache_stats()
        assert st["misses"] >= 1 and st["hits"] >= 1
        fam = obs.snapshot()["counters"]["tile.lookups"]
        assert fam["result=miss"] >= 1 and fam["result=hit"] >= 1

    def test_reset_stats_keeps_memo_warm(self):
        ops._tile_for(1235, 256, 128, 4)
        size = ops.tile_cache_info().currsize
        ops.reset_tile_cache_stats()
        assert ops.tile_cache_stats()["misses"] == 0
        assert ops.tile_cache_info().currsize == size
        ops._tile_for(1235, 256, 128, 4)  # still a hit
        assert ops.tile_cache_stats()["hits"] == 1

    def test_miss_streak_hook_fires_at_threshold_multiples(self):
        fired = []
        ops.on_miss_streak(lambda key, s: fired.append(s), threshold=3)
        ops.reset_tile_cache_stats()
        for i in range(7):
            ops._tile_for(4096 + i, 256, 128, 4)
        assert fired == [3, 6]

    def test_hit_resets_the_streak(self):
        fired = []
        ops.on_miss_streak(lambda key, s: fired.append(s), threshold=3)
        ops.reset_tile_cache_stats()
        ops._tile_for(5000, 256, 128, 4)
        ops._tile_for(5001, 256, 128, 4)
        ops._tile_for(5000, 256, 128, 4)  # hit: streak back to 0
        ops._tile_for(5002, 256, 128, 4)
        assert fired == []
        assert ops.tile_cache_stats()["miss_streak"] == 1

    def test_hook_exceptions_are_swallowed(self):
        def bad(key, streak):
            raise RuntimeError("hook bug")

        ops.on_miss_streak(bad, threshold=1)
        ops.reset_tile_cache_stats()
        assert ops._tile_for(6000, 256, 128, 4)  # must not raise

    def test_default_hook_logs_retune_candidate(self):
        ops.on_miss_streak(None, threshold=2)
        ops.reset_tile_cache_stats()
        ops._tile_for(7000, 256, 128, 4, "dense", 0, "xla")
        ops._tile_for(7001, 256, 128, 4, "dense", 0, "xla")
        evts = obs.recent_events(5, kind="retune_candidate")
        assert evts and evts[-1]["m"] == 7001 and evts[-1]["streak"] == 2
        fam = obs.snapshot()["counters"]["tune.retune_candidates"]
        assert fam["backend=xla,family=dense,reason=miss_streak"] == 1.0


class TestReservoirWindow:
    def test_small_histogram_is_exact(self):
        h = obs.histogram("t.win")
        for v in range(10):
            h.observe(float(v))
        assert h.samples_seen == 10 and h.samples_dropped == 0
        s = obs.snapshot()["histograms"]["t.win"][""]
        assert s["samples_seen"] == 10
        assert s["samples_dropped"] == 0
        assert s["percentile_mode"] == "exact"

    def test_overflow_switches_to_windowed(self):
        h = obs.histogram("t.win.big")
        n = 5000  # past the 4096-sample reservoir
        for v in range(n):
            h.observe(float(v))
        assert h.samples_seen == n
        assert h.samples_dropped == n - 4096
        s = obs.snapshot()["histograms"]["t.win.big"][""]
        assert s["percentile_mode"] == "windowed"
        assert s["samples_dropped"] == n - 4096
        # percentiles now describe the newest window, not all time: the
        # oldest samples (0..903) fell out of the deque
        assert s["p50"] >= n - 4096

    def test_count_sum_minmax_stay_alltime(self):
        h = obs.histogram("t.win.stats", buckets=[10.0])
        for v in range(5000):
            h.observe(float(v))
        s = obs.snapshot()["histograms"]["t.win.stats"][""]
        assert s["count"] == 5000
        assert s["min"] == 0.0 and s["max"] == 4999.0
        assert s["buckets"]["+Inf"] == 5000


class TestHistogramProperties:
    """Property tests for the histogram invariants. Uses hypothesis when the
    container has it; the seeded-numpy fuzz versions always run."""

    def _check_monotone(self, values):
        import numpy as np

        obs.reset()
        h = obs.histogram("t.prop", buckets=[0.1, 1.0, 10.0, 100.0])
        for v in values:
            h.observe(float(v))
        s = obs.snapshot()["histograms"]["t.prop"][""]
        counts = list(s["buckets"].values())
        assert counts == sorted(counts), "cumulative buckets must be monotone"
        assert counts[-1] == len(values), "+Inf bucket counts everything"
        if values:
            assert s["min"] == pytest.approx(float(np.min(values)))
            assert s["max"] == pytest.approx(float(np.max(values)))

    def _check_percentile(self, values, q):
        import numpy as np

        got = obs.percentile(list(values), q)
        if not values:
            assert got is None
            return
        assert got == pytest.approx(
            float(np.percentile(np.asarray(values, float), q,
                                method="linear")),
            rel=1e-9, abs=1e-9,
        )

    def test_monotone_buckets_fuzz(self):
        import numpy as np

        rng = np.random.default_rng(7)
        for trial in range(25):
            n = int(rng.integers(0, 50))
            self._check_monotone((rng.lognormal(0, 3, n)).tolist())

    def test_percentile_matches_numpy_fuzz(self):
        import numpy as np

        rng = np.random.default_rng(11)
        for trial in range(50):
            n = int(rng.integers(0, 40))
            xs = rng.standard_normal(n).tolist()
            self._check_percentile(xs, float(rng.uniform(0, 100)))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=1e-6, max_value=1e6), max_size=64))
    def test_monotone_buckets_hypothesis(self, values):
        self._check_monotone(values)

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.floats(min_value=-1e6, max_value=1e6), max_size=64),
        st.floats(min_value=0, max_value=100),
    )
    def test_percentile_matches_numpy_hypothesis(self, values, q):
        self._check_percentile(values, q)


# ---------------------------------------------------------------------------
# utilization attribution (obs.attr) + the util-gap retune seam
# ---------------------------------------------------------------------------


class TestAttr:
    def _rec(self, **kw):
        from repro.obs import attr

        base = dict(
            shape_family="dense", backend="xla", family="fp",
            m=8, k=16, n=8, g=0,
            a_dtype="float32", b_dtype="float32", out_dtype="float32",
            tile_source="heuristic",
            tile_key=("xla", "dense", 8, 16, 8, 0, 4),
        )
        base.update(kw)
        return attr.GemmRecord(**base)

    def test_shape_bucket_pow2_rounds_m_only(self):
        from repro.obs import attr

        assert attr.shape_bucket(self._rec(m=5)) == "dense:8x16x8"
        assert attr.shape_bucket(self._rec(m=8)) == "dense:8x16x8"
        assert attr.shape_bucket(self._rec(m=9)) == "dense:16x16x8"
        grouped = self._rec(shape_family="grouped", g=4, m=3)
        assert attr.shape_bucket(grouped) == "grouped:4x4x16x8"

    def test_capture_is_fed_by_ops(self):
        from repro.obs import attr

        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        with attr.capture_gemms() as recs:
            ops.matmul(a, b, backend="xla")
        assert len(recs) == 1
        r = recs[0]
        assert (r.m, r.k, r.n) == (8, 16, 8)
        assert r.backend == "xla" and r.family == "fp"
        assert r.a_dtype == "float32"
        # nothing recorded outside the bracket
        ops.matmul(a, b, backend="xla")
        assert len(recs) == 1

    def test_aggregate_folds_per_class(self):
        from repro.obs import attr

        recs = [self._rec(), self._rec(), self._rec(m=64)]
        wl = attr.aggregate(recs)
        assert len(wl) == 2  # m=8 bucket (x2) and m=64 bucket
        e = wl[("xla", "fp", "dense:8x16x8", "heuristic")]
        assert e.calls == 2
        assert e.flops == pytest.approx(2 * (2.0 * 8 * 16 * 8))
        assert e.roofline_s > 0

    def test_observe_step_populates_histograms(self):
        from repro.obs import attr

        wl = attr.aggregate([self._rec()])
        attr.observe_step(wl, 0.01)
        snap = obs.snapshot()
        key = "backend=xla,bucket=dense:8x16x8,family=fp,tile=heuristic"
        assert snap["histograms"]["gemm.roofline_fraction"][key]["count"] == 1
        assert snap["histograms"]["gemm.achieved_gflops"][key]["count"] == 1
        assert snap["counters"]["gemm.device_seconds"][key] == (
            pytest.approx(0.01)
        )
        frac = snap["histograms"]["gemm.roofline_fraction"][key]["max"]
        assert 0 < frac < 1  # 10ms wall for a tiny GEMM: far off roofline

    def test_observe_step_attributes_proportionally(self):
        from repro.obs import attr

        small, big = self._rec(), self._rec(m=64, k=256, n=256)
        wl = attr.aggregate([small, big])
        attr.observe_step(wl, 1.0)
        fam = obs.snapshot()["counters"]["gemm.device_seconds"]
        assert sum(fam.values()) == pytest.approx(1.0)
        big_key = "backend=xla,bucket=dense:64x256x256,family=fp,tile=heuristic"
        assert fam[big_key] > 0.9  # the big GEMM dominates roofline seconds

    def test_observe_step_guards(self):
        from repro.obs import attr

        attr.observe_step({}, 1.0)  # empty workload: no-op
        attr.observe_step(attr.aggregate([self._rec()]), 0.0)  # no wall time
        assert "gemm.roofline_fraction" not in obs.snapshot()["histograms"]


class TestUtilGap:
    KEY = ("xla", "dense", 64, 256, 256, 0, 4)

    def test_fires_at_streak_multiples(self):
        fired = []
        ops.on_util_gap(
            lambda key, s, f: fired.append((key, s, f)),
            threshold=0.5, streak=2,
        )
        ops._note_util_observation(self.KEY, 0.8, "tuned")  # sets best
        for _ in range(5):
            ops._note_util_observation(self.KEY, 0.1, "tuned")  # 0.1 < 0.4
        assert [(s, f) for _, s, f in fired] == [(2, 0.1), (4, 0.1)]
        assert all(k == self.KEY for k, _, _ in fired)
        fam = obs.snapshot()["counters"]["gemm.util_gap_observations"]
        assert fam[""] == 5.0

    def test_good_observation_resets_the_streak(self):
        fired = []
        ops.on_util_gap(lambda k, s, f: fired.append(s), threshold=0.5,
                        streak=2)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops._note_util_observation(self.KEY, 0.1, "tuned")  # streak 1
        ops._note_util_observation(self.KEY, 0.7, "tuned")  # healthy: reset
        ops._note_util_observation(self.KEY, 0.1, "tuned")  # streak 1 again
        assert fired == []

    def test_heuristic_observations_only_reset(self):
        fired = []
        ops.on_util_gap(lambda k, s, f: fired.append(s), threshold=0.5,
                        streak=2)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops._note_util_observation(self.KEY, 0.1, "tuned")  # streak 1
        ops._note_util_observation(self.KEY, 0.1, "heuristic")  # reset only
        ops._note_util_observation(self.KEY, 0.1, "tuned")  # streak 1
        assert fired == []

    def test_best_only_ratchets_up(self):
        fired = []
        ops.on_util_gap(lambda k, s, f: fired.append(s), threshold=0.5,
                        streak=1)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops._note_util_observation(self.KEY, 0.6, "tuned")  # above 0.4: fine
        assert fired == []
        ops._note_util_observation(self.KEY, 0.3, "tuned")  # below 0.4: gap
        assert fired == [1]

    def test_hook_exceptions_are_swallowed(self):
        def bad(key, streak, fraction):
            raise RuntimeError("hook bug")

        ops.on_util_gap(bad, threshold=0.5, streak=1)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops._note_util_observation(self.KEY, 0.01, "tuned")  # must not raise

    def test_default_hook_logs_retune_candidate(self):
        ops.on_util_gap(None, threshold=0.5, streak=2)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops._note_util_observation(self.KEY, 0.1, "tuned")
        ops._note_util_observation(self.KEY, 0.1, "tuned")
        evts = obs.recent_events(5, kind="retune_candidate")
        assert evts and evts[-1]["reason"] == "util_gap"
        assert evts[-1]["streak"] == 2 and evts[-1]["m"] == 64
        fam = obs.snapshot()["counters"]["tune.retune_candidates"]
        assert fam["backend=xla,family=dense,reason=util_gap"] == 1.0

    def test_parameter_validation(self):
        with pytest.raises(ValueError):
            ops.on_util_gap(None, threshold=0.0)
        with pytest.raises(ValueError):
            ops.on_util_gap(None, threshold=1.5)
        with pytest.raises(ValueError):
            ops.on_util_gap(None, streak=0)

    def test_reset_stats_drops_streaks_and_bests(self):
        fired = []
        ops.on_util_gap(lambda k, s, f: fired.append(s), threshold=0.5,
                        streak=1)
        ops._note_util_observation(self.KEY, 0.8, "tuned")
        ops.reset_tile_cache_stats()
        # best forgotten: 0.1 is now the first (and best) observation
        ops._note_util_observation(self.KEY, 0.1, "tuned")
        assert fired == []


# ---------------------------------------------------------------------------
# shadow numerics auditor (obs.audit)
# ---------------------------------------------------------------------------


class TestAudit:
    def test_q8_policy_is_registered(self):
        from repro.obs import audit

        pol = audit.get_policy("q8")
        assert pol is not None and pol.rel_err == pytest.approx(0.05)

    def test_sampling_off_by_default(self):
        from repro.obs import audit

        assert audit.audit_every() == 0
        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        ops.matmul(a, b, backend="xla_q8")
        assert "numerics.audits" not in obs.snapshot()["counters"]

    def test_healthy_q8_audits_clean(self):
        import numpy as np

        from repro.obs import audit

        audit.set_audit_every(1)
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        ops.matmul(a, b, backend="xla_q8")
        snap = obs.snapshot()
        key = "backend=xla_q8,family=q8,shape=dense"
        assert snap["counters"]["numerics.audits"][key] == 1.0
        rel = snap["histograms"]["numerics.rel_err"][key]
        assert rel["count"] == 1
        assert rel["max"] < 0.05  # well under the q8 policy
        assert "numerics.drift" not in snap["counters"]
        assert obs.recent_events(5, kind="numerics_drift") == []

    def test_fp_family_is_never_audited(self):
        from repro.obs import audit

        audit.set_audit_every(1)
        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        ops.matmul(a, b, backend="xla")
        assert "numerics.audits" not in obs.snapshot()["counters"]

    def test_sampling_one_in_n(self):
        import numpy as np

        from repro.obs import audit

        audit.set_audit_every(3)
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
        for _ in range(6):
            ops.matmul(a, b, backend="xla_q8")
        fam = obs.snapshot()["counters"]["numerics.audits"]
        assert fam["backend=xla_q8,family=q8,shape=dense"] == 2.0

    def test_injected_misscaled_backend_trips_drift(self):
        """The acceptance scenario: a q8 backend whose output is 2x wrong
        must produce a numerics_drift event on the sampled call."""
        import numpy as np

        from repro.obs import audit

        def bad_q8(a, b, c, out_dtype):
            out = (a @ b) * 2.0  # mis-applied dequant scale
            if c is not None:
                out = out + c
            return out.astype(out_dtype)

        ops.register_backend(
            "bad_q8", bad_q8, family="q8", grad_backend="xla",
        )
        try:
            audit.set_audit_every(1)
            rng = np.random.default_rng(2)
            a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
            ops.matmul(a, b, backend="bad_q8")
        finally:
            ops._REGISTRY.pop("bad_q8", None)
        snap = obs.snapshot()
        key = "backend=bad_q8,family=q8,shape=dense"
        assert snap["counters"]["numerics.drift"][key] == 1.0
        evt = obs.recent_events(5, kind="numerics_drift")[-1]
        assert evt["backend"] == "bad_q8" and evt["family"] == "q8"
        assert evt["rel_err"] > 0.5  # a 2x output is ~100% off
        assert evt["threshold"] == pytest.approx(0.05)

    def test_nonfinite_output_is_drift_even_in_threshold(self):
        import numpy as np

        from repro.obs import audit

        def nan_q8(a, b, c, out_dtype):
            out = a @ b
            out = out.at[0, 0].set(jnp.nan)
            if c is not None:
                out = out + c
            return out.astype(out_dtype)

        ops.register_backend(
            "nan_q8", nan_q8, family="q8", grad_backend="xla",
        )
        try:
            audit.set_audit_every(1)
            rng = np.random.default_rng(3)
            a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
            ops.matmul(a, b, backend="nan_q8")
        finally:
            ops._REGISTRY.pop("nan_q8", None)
        snap = obs.snapshot()
        key = "backend=nan_q8,family=q8,sentinel=nan,shape=dense"
        assert snap["counters"]["numerics.nonfinite"][key] == 1.0
        assert obs.recent_events(5, kind="numerics_drift")[-1]["nan"] == 1

    def test_grouped_q8_is_audited(self):
        import numpy as np

        from repro.obs import audit

        audit.set_audit_every(1)
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
        ops.grouped_matmul(a, b, backend="xla_q8")
        fam = obs.snapshot()["counters"]["numerics.audits"]
        assert fam["backend=xla_q8,family=q8,shape=grouped"] == 1.0

    def test_tracers_are_skipped_inside_jit(self):
        from repro.obs import audit

        audit.set_audit_every(1)
        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)
        jax.jit(lambda a, b: ops.matmul(a, b, backend="xla_q8"))(
            a, b
        ).block_until_ready()
        # the call traced (gemm.calls fired) but the tracer output was not
        # auditable — no shadow execution, no numerics series
        snap = obs.snapshot()
        assert any("xla_q8" in k for k in snap["counters"]["gemm.calls"])
        assert "numerics.audits" not in snap["counters"]

    def test_q8_step_hlo_identical_with_audit_on(self):
        """Sampling on vs off must not change the compiled artifact — the
        auditor is host-side and tracer-skipped."""
        from repro.obs import audit

        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 8), jnp.float32)

        def lower():
            return (
                jax.jit(lambda a, b: ops.matmul(a, b, backend="xla_q8"))
                .lower(a, b).compile().as_text()
            )

        audit.set_audit_every(0)
        off = _instruction_census(lower())
        audit.set_audit_every(1)
        on = _instruction_census(lower())
        assert sum(off.values()) > 0
        assert on == off

    def test_invalid_env_value_means_off(self, monkeypatch):
        from repro.obs import audit

        audit.set_audit_every(None)
        monkeypatch.setenv(audit.AUDIT_ENV, "banana")
        assert audit.audit_every() == 0
        monkeypatch.setenv(audit.AUDIT_ENV, "8")
        assert audit.audit_every() == 8
        monkeypatch.setenv(audit.AUDIT_ENV, "-3")
        assert audit.audit_every() == 0


class TestServingTelemetry:
    @pytest.fixture(scope="class")
    def report_and_snap(self):
        from repro.configs import get_config
        from repro.models import api
        from repro.serve import ContinuousEngine, poisson_trace

        obs.reset()
        cfg = get_config("chatglm3-6b").reduced()
        params = api.init_params(cfg, jax.random.key(0))
        trace = poisson_trace(
            6, seed=0, vocab=cfg.vocab, prompt_lens=(4, 8), gen_lens=(3, 6)
        )
        eng = ContinuousEngine(
            cfg=cfg, params=params, n_slots=2, max_len=32,
            cache_dtype=jnp.float32,
        )
        report = eng.timed_serve(trace)
        return report, obs.snapshot()

    def test_percentiles_are_sane(self, report_and_snap):
        report, _ = report_and_snap
        # a Poisson trace through a 2-slot pool queues: TTFT spans queueing
        # + prefill and must be positive and ordered
        assert 0 < report.ttft_p50 <= report.ttft_p99
        assert 0 < report.itl_p50 <= report.itl_p99
        assert report.ttft_p99 < report.wall_time_s

    def test_lifecycle_histograms_and_counters(self, report_and_snap):
        report, snap = report_and_snap
        h = snap["histograms"]
        assert h["serve.ttft_seconds"][""]["count"] == 6
        # every generated token beyond each request's first closes an
        # inter-token gap
        assert h["serve.itl_seconds"][""]["count"] == (
            report.generated_tokens - 6
        )
        assert h["serve.step_seconds"][""]["count"] == report.decode_steps
        c = snap["counters"]["serve.requests"]
        assert c["event=admitted"] == 6.0 and c["event=retired"] == 6.0
        assert set(snap["gauges"]) >= {"serve.occupancy", "serve.queue_depth"}

    def test_utilization_attribution_populates(self, report_and_snap):
        """The acceptance criterion: live roofline-fraction histograms fill
        during serving — the decode step traced once (capturing its GEMMs)
        and every subsequent execution attributed its wall time."""
        report, snap = report_and_snap
        h = snap["histograms"]
        assert "gemm.roofline_fraction" in h
        assert "gemm.achieved_gflops" in h
        attributed_steps = sum(
            s["count"] for s in h["gemm.roofline_fraction"].values()
        )
        # first decode tick traces (skipped: its wall bracket includes
        # compile); the rest attribute
        assert attributed_steps >= report.decode_steps - 1 > 0
        dev = snap["counters"]["gemm.device_seconds"]
        assert sum(dev.values()) > 0
        # labels carry the full attribution key set
        some = next(iter(dev))
        for part in ("backend=", "bucket=", "family=", "tile="):
            assert part in some

    def test_repro_stats_top_renders(self, report_and_snap, capsys,
                                     tmp_path):
        import json as _json

        from repro.launch.stats import main as stats_main

        _, snap = report_and_snap
        path = tmp_path / "snap.json"
        path.write_text(_json.dumps(snap))
        stats_main(["top", "--file", str(path), "-n", "5"])
        out = capsys.readouterr().out
        assert "bucket" in out and "device_s" in out
        assert "dense:" in out  # decode GEMM buckets ranked

    def test_repro_stats_top_empty_is_friendly(self, capsys, tmp_path):
        import json as _json

        from repro.launch.stats import main as stats_main

        path = tmp_path / "empty.json"
        path.write_text(_json.dumps(
            {"counters": {}, "gauges": {}, "histograms": {}}
        ))
        stats_main(["top", "--file", str(path)])
        assert "no utilization attribution" in capsys.readouterr().out

    def test_bench_row_carries_percentiles(self, report_and_snap):
        import os
        import sys

        report, _ = report_and_snap
        sys.path.insert(
            0, os.path.join(os.path.dirname(__file__), "..", "benchmarks")
        )
        try:
            from serving_bench import run_continuous
        finally:
            sys.path.pop(0)

        class _Eng:
            def timed_serve(self, requests):
                return report

            def decode_compilations(self):
                return 1

        row = run_continuous(_Eng(), [])
        for k in ("ttft_p50", "ttft_p99", "itl_p50", "itl_p99"):
            assert row[k] == getattr(report, k)


class TestTrainTelemetry:
    def test_per_step_events_with_roofline(self):
        import numpy as np

        from repro.configs import get_config
        from repro.optim.adamw import AdamWConfig
        from repro.train.loop import TrainLoopConfig, train

        cfg = get_config("chatglm3-6b").reduced()

        def batch_fn(step):
            rng = np.random.default_rng(step)
            toks = rng.integers(0, cfg.vocab, (2, 17))
            return {
                "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            }

        train(
            cfg, AdamWConfig(), TrainLoopConfig(total_steps=3, log_every=0),
            batch_fn, log=lambda m: None,
        )
        evts = obs.recent_events(10, kind="train_step")
        assert [e["step"] for e in evts] == [0, 1, 2]
        for e in evts:
            assert e["tokens"] == 32
            assert e["tokens_per_sec"] > 0
            assert e["gflops_per_sec"] > 0
            assert 0 < e["roofline_frac"] < 1
        snap = obs.snapshot()
        assert snap["histograms"]["train.step_seconds"][""]["count"] == 3
        assert snap["gauges"]["train.tokens_per_sec"][""] > 0


# ---------------------------------------------------------------------------
# the zero-cost claim: telemetry adds NO ops to compiled HLO
# ---------------------------------------------------------------------------

_OPCODE = re.compile(r"=\s*[a-z0-9\[\],{}\s]*?([a-z][a-z0-9\-]*)\(")


def _instruction_census(hlo: str) -> collections.Counter:
    return collections.Counter(
        m.group(1) for line in hlo.splitlines() if " = " in line
        for m in [_OPCODE.search(line)] if m
    )


def test_census_helper_positive_control():
    a = jnp.ones((8, 8))
    t1 = jax.jit(lambda x: x @ x).lower(a).compile().as_text()
    t2 = jax.jit(lambda x: jnp.tanh(x @ x)).lower(a).compile().as_text()
    assert _instruction_census(t1) != _instruction_census(t2)


@pytest.mark.slow
def test_metrics_off_decode_step_hlo_is_identical():
    """REPRO_METRICS=0 must be provably free: the jitted decode step lowers
    to the same instruction census with telemetry on and off, because every
    instrument is host-side Python that runs at trace time only."""
    from repro.configs import ARCHS
    from repro.models import api

    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    _, caches = api.prefill(
        cfg, params, {"tokens": tokens}, max_len=16, cache_dtype=jnp.float32
    )
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray(8, jnp.int32)

    def lower():
        step = jax.jit(lambda p, t, c, q: api.decode(cfg, p, t, c, q))
        return step.lower(params, tok, caches, pos).compile().as_text()

    from repro.obs import tracing

    prev = obs.set_enabled(True)
    tracing.set_enabled(True)  # request tracing must be free too
    try:
        on = _instruction_census(lower())
        obs.set_enabled(False)
        tracing.set_enabled(False)
        off = _instruction_census(lower())
    finally:
        obs.set_enabled(prev)
        tracing.set_enabled(None)

    assert sum(on.values()) > 0
    assert on == off, (
        "telemetry changed the compiled decode step: "
        f"on-off={on - off!r} off-on={off - on!r}"
    )
    # and with metrics ON the trace recorded host-side counters — proof the
    # instrumentation ran during the identical-HLO compile
    assert "gemm.calls" in obs.snapshot()["counters"]
