"""Radix prefix-cache invariants + quantized scale-adoption exactness.

Two layers of coverage:

* property tests (hypothesis, skipped gracefully when the ``test`` extra
  isn't installed) over the trie: longest-prefix match, block alignment,
  refcount residency, LRU eviction to capacity;
* deterministic seeded versions of the same invariants plus the quantized
  round-trip: a cached prefix re-quantized under its adopted scale floor
  must reproduce its narrow codes **bitwise** (``cast(q * s / s) == q``),
  so attaching a cached prefix to a fresh slot never adds drift on top of
  the one quantization the cold path already paid.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache
from repro.quant.kvcache import adopt_scale_floor, quantize_kv_rows
from repro.quant.quantize import format_of
from repro.serve import PrefixCache

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st


N_PERIODS = 2
FUSED = 8  # n_kv=2 heads * head_dim=4
N_KV = 2


def _prefill_stack(seed, lb, rows=1, scale=1.0):
    """Standalone prefill cache stack: one KVCache entry + one None slot
    (mirrors a pattern with a non-attention position)."""
    rng = np.random.default_rng(seed)
    shape = (N_PERIODS, rows, lb, FUSED)
    k = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    v = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    return (
        KVCache(k=k, v=v, length=jnp.zeros((rows,), jnp.int32)),
        None,
    )


def _prompt(rng, n, vocab=64):
    return [int(x) for x in rng.integers(0, vocab, n)]


# ---------------------------------------------------------------------------
# trie invariants (property + deterministic)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=2, max_value=8),
)
def test_match_block_aligned_and_capped(seed, plen, bs):
    """match() returns a whole-block prefix length that always leaves >= 1
    prompt token to prefill, regardless of what was inserted."""
    rng = np.random.default_rng(seed)
    trie = PrefixCache(block_size=bs, capacity_tokens=1 << 12)
    toks = _prompt(rng, plen)
    trie.insert(toks, plen, _prefill_stack(seed, plen), 0)
    path, matched = trie.match(toks)
    assert matched % bs == 0
    assert matched <= len(toks) - 1  # never the whole prompt
    assert matched == min((plen // bs) * bs, ((len(toks) - 1) // bs) * bs)
    assert len(path) == matched // bs


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=2, max_value=6),
)
def test_shared_prefix_unique_tails_share_blocks(seed, n_tails):
    """Prompts diverging after a shared prefix match exactly the shared
    whole blocks; inserting them re-creates no shared block (first writer
    wins)."""
    rng = np.random.default_rng(seed)
    bs = 4
    trie = PrefixCache(block_size=bs, capacity_tokens=1 << 12)
    prefix = _prompt(rng, 3 * bs)
    first = prefix + _prompt(rng, 5)
    created = trie.insert(first, len(first), _prefill_stack(seed, 32), 0)
    assert created == len(first) // bs
    for j in range(n_tails):
        p = prefix + [100 + j] * 5  # tails outside the vocab: never shared
        path, matched = trie.match(p)
        assert matched >= len(prefix)
        n0 = trie.n_nodes
        trie.insert(p, len(p), _prefill_stack(seed + j + 1, 32), 0)
        # only the tail's whole blocks are new
        assert trie.n_nodes - n0 == len(p) // bs - matched // bs


def test_trie_deterministic_match_and_insert():
    rng = np.random.default_rng(0)
    trie = PrefixCache(block_size=4, capacity_tokens=1 << 12)
    toks = _prompt(rng, 13)
    stack = _prefill_stack(1, 16)
    assert trie.match(toks) == ([], 0)
    assert trie.misses == 0  # engine-side counter, not bumped by match()
    created = trie.insert(toks, 13, stack, 0)
    assert created == 3 and trie.cached_tokens == 12
    _, matched = trie.match(toks)
    assert matched == 12
    # a 12-token prompt sharing those blocks must keep one token to prefill
    _, matched = trie.match(toks[:12])
    assert matched == 8
    # divergent second block: only the first block matches
    other = toks[:4] + [99] * 9
    _, matched = trie.match(other)
    assert matched == 4
    # re-insert is a no-op (first writer wins)
    assert trie.insert(toks, 13, _prefill_stack(2, 16), 0) == 0


def test_refcount_blocks_eviction_release_enables_it():
    rng = np.random.default_rng(3)
    bs = 4
    trie = PrefixCache(block_size=bs, capacity_tokens=2 * bs)  # 2 blocks max
    a = _prompt(rng, 2 * bs + 1)
    trie.insert(a, len(a), _prefill_stack(0, 16), 0)
    path, matched = trie.match(a)
    assert matched == 2 * bs
    trie.acquire(path)
    # inserting another prompt overflows capacity; a's blocks are pinned, so
    # the sweep can only reclaim b's own (refcount-0) blocks.
    b = [200 + t for t in _prompt(rng, 2 * bs + 1)]
    trie.insert(b, len(b), _prefill_stack(1, 16), 0)
    assert trie.match(a)[1] == 2 * bs  # survived while referenced
    assert trie.match(b)[1] < 2 * bs  # b paid the eviction instead
    assert trie.cached_tokens <= trie.capacity_tokens
    assert trie.evictions > 0
    trie.release(path)
    # with a released (and now LRU after b is refreshed), a's blocks go next
    trie.match(b)  # refresh whatever of b survived
    c = [400 + t for t in _prompt(rng, 2 * bs + 1)]
    trie.insert(c, len(c), _prefill_stack(2, 16), 0)
    assert trie.cached_tokens <= trie.capacity_tokens
    assert trie.match(a)[1] < 2 * bs  # at least one of a's blocks evicted
    # releasing more than acquired is a bug, not a no-op
    with pytest.raises(AssertionError):
        trie.release(path)


def test_gather_fp_roundtrip_exact():
    rng = np.random.default_rng(7)
    trie = PrefixCache(block_size=4, capacity_tokens=1 << 12)
    toks = _prompt(rng, 9)
    stack = _prefill_stack(5, 16)
    trie.insert(toks, 9, stack, 0)
    path, matched = trie.match(toks)
    assert matched == 8
    spans, floors = trie.gather(path)
    assert floors is None and spans[1] is None
    k, v = spans[0]
    np.testing.assert_array_equal(np.asarray(k), np.asarray(stack[0].k[:, 0, :8]))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(stack[0].v[:, 0, :8]))


# ---------------------------------------------------------------------------
# quantized prefix: scale adoption is bitwise-exact
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_scale_adoption_roundtrip_bitwise(seed):
    """cast(q * s / s) == q: re-quantizing a dequantized span under its
    original scale as a floor reproduces the codes exactly whenever the
    floor dominates the fresh calibration."""
    _assert_adoption_roundtrip(seed)


def test_scale_adoption_roundtrip_bitwise_deterministic():
    for seed in (0, 1, 2, 3):
        _assert_adoption_roundtrip(seed)


def _assert_adoption_roundtrip(seed):
    rng = np.random.default_rng(seed)
    span, tail = 8, 4
    kf = jnp.asarray(rng.normal(size=(1, span, FUSED)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(1, span, FUSED)), jnp.float32)
    k_q, v_q, k_s, v_s = quantize_kv_rows(kf, vf, N_KV, fmt="int8")
    # dequantized span + a small-magnitude suffix: fresh amax can't beat the
    # floor, so the adopted scale is exactly the prefix's.
    f = format_of("int8")

    def deq(q, s):
        b, sp, fused = q.shape
        x = q.reshape(b, sp, N_KV, fused // N_KV).astype(jnp.float32)
        return (x * s[:, None, :, None]).reshape(b, sp, fused)

    k_full = jnp.concatenate(
        [deq(k_q, k_s), jnp.full((1, tail, FUSED), 1e-4, jnp.float32)], axis=1
    )
    v_full = jnp.concatenate(
        [deq(v_q, v_s), jnp.full((1, tail, FUSED), 1e-4, jnp.float32)], axis=1
    )
    k_q2, v_q2, k_s2, v_s2 = quantize_kv_rows(
        k_full, v_full, N_KV, fmt="int8",
        k_scale_floor=k_s, v_scale_floor=v_s,
    )
    np.testing.assert_array_equal(np.asarray(k_s2), np.asarray(k_s))
    np.testing.assert_array_equal(np.asarray(v_s2), np.asarray(v_s))
    np.testing.assert_array_equal(
        np.asarray(k_q2[:, :span]), np.asarray(k_q)
    )
    np.testing.assert_array_equal(
        np.asarray(v_q2[:, :span]), np.asarray(v_q)
    )
    assert f.dtype == k_q2.dtype


def test_quant_trie_gather_floors_and_codes():
    """End-to-end through the quantized trie: gather's floors are the span
    scales, and re-quantizing the gathered (dequantized) span under those
    floors reproduces the stored narrow codes bitwise."""
    rng = np.random.default_rng(11)
    trie = PrefixCache(
        block_size=4, capacity_tokens=1 << 12, kv_format="int8", n_kv=N_KV
    )
    toks = _prompt(rng, 9)
    stack = _prefill_stack(9, 16, scale=3.0)
    trie.insert(toks, 9, stack, 0)
    path, matched = trie.match(toks)
    assert matched == 8
    spans, floors = trie.gather(path)
    assert floors is not None and floors[1] is None
    (k, v), (k_fl, v_fl) = spans[0], floors[0]
    assert k_fl.shape == (N_PERIODS, N_KV)
    # floor adoption: quantize the gathered span per period under the floor
    f = format_of("int8")
    for p in range(N_PERIODS):
        k_q2, _, k_s2, _ = quantize_kv_rows(
            k[p][None], v[p][None], N_KV, fmt="int8",
            k_scale_floor=k_fl[p][None], v_scale_floor=v_fl[p][None],
        )
        want = jnp.concatenate([n.payload[0][0][p] for n in path], axis=0)
        np.testing.assert_array_equal(np.asarray(k_q2[0]), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(k_s2[0]), np.asarray(k_fl[p]))


def test_adopt_scale_floor_broadcast():
    s = jnp.asarray([[0.5, 2.0], [1.0, 4.0]], jnp.float32)  # [P=2, n_kv=2]
    out = adopt_scale_floor(s, 3)
    assert out.shape == (2, 3, 2)
    np.testing.assert_array_equal(np.asarray(out[:, 1]), np.asarray(s))


def test_quant_trie_requires_n_kv():
    with pytest.raises(ValueError):
        PrefixCache(kv_format="int8")
