"""Mixed-precision subsystem: quant numerics, registry behaviour, policy
wiring, and quantized-KV serving agreement.

Covers the contracts the quant package advertises:

* quantize/dequantize round-trip error bounds per format;
* q8 matmul error vs the fp32 ``ref.py`` contract, and bit-agreement between
  ``xla_q8`` and the Pallas q8 kernel (int32 accumulation is exact, so the
  two paths may differ only by fp32 scale-multiply rounding);
* quantized backends resolve through the registry, degrade inside the
  quantized family, and backpropagate through their full-precision
  grad backend;
* ``PrecisionPolicy`` role wiring through the model stack;
* greedy-decode token agreement between fp32-KV and quantized-KV continuous
  serving on the reduced test model (trained first — argmax agreement on an
  untrained model measures dice rolls, not quantization).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.kernels import ops
from repro.kernels.ref import reference_matmul
from repro.quant import (
    PrecisionPolicy,
    QuantKVCache,
    mlp_q8_policy,
    quantize,
    quantize_kv,
)


# ---------------------------------------------------------------------------
# quantize / dequantize round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "fmt,rel_bound",
    [
        # int8: half a step of amax/127; fp8: half-ulp ~ 2^-(mantissa+1),
        # asserted with a 2x cushion at 2^-mantissa of amax.
        ("int8", 0.5 / 127.0),
        ("fp8_e4m3", 2.0**-3),
        ("fp8_e5m2", 2.0**-2),
    ],
)
def test_roundtrip_error_bound(fmt, rel_bound):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((48, 33)), jnp.float32)
    qt = quantize(x, fmt)
    err = jnp.max(jnp.abs(qt.dequantize() - x))
    amax = jnp.max(jnp.abs(x))
    assert float(err) <= float(amax) * rel_bound * 1.0001
    assert qt.q.dtype == quant.FORMATS[fmt].dtype
    assert qt.fmt.name == fmt


def test_per_channel_beats_per_tensor_on_skewed_scales():
    # A small-magnitude channel next to a large one: per-tensor scaling
    # crushes the small channel into a handful of int8 steps; per-channel
    # scaling gives every channel its own full range.
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    x = x * jnp.asarray([0.01, 0.1, 1, 10, 100, 0.5, 5, 50])[None, :]
    err_t = jnp.abs(quantize(x, "int8").dequantize() - x)[:, 0].max()
    err_c = jnp.abs(quantize(x, "int8", axis=1).dequantize() - x)[:, 0].max()
    assert float(err_c) < float(err_t) / 10


def test_calibrated_scale_covers_all_batches():
    batches = [jnp.full((4, 4), v, jnp.float32) for v in (1.0, 3.0, 2.0)]
    scale = quant.calibrate_scale(batches, "int8")
    assert float(scale) == pytest.approx(3.0 / 127.0)
    # margin leaves headroom
    scale_m = quant.calibrate_scale(batches, "int8", margin=1.25)
    assert float(scale_m) == pytest.approx(1.25 * 3.0 / 127.0)


def test_zero_tensor_quantizes_to_zero():
    x = jnp.zeros((8, 8), jnp.float32)
    qt = quantize(x, "int8")
    out = qt.dequantize()
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# q8 matmul vs the fp32 reference contract
# ---------------------------------------------------------------------------


def _operands(m=96, k=128, n=64, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return a, b


def test_q8_matmul_error_vs_fp32_reference():
    a, b = _operands()
    want = reference_matmul(a, b)
    got = ops.matmul(a, b, backend="xla_q8")
    # Per-element error bound: |C_err| <= sum_k |a*db| + |da*b| + |da*db|
    # ~ K * (amax*sb/2 + sa/2*bmax). Empirically ~1% of the column norms;
    # assert a conservative 3% of the output's max magnitude.
    tol = 0.03 * float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) < tol


def test_pallas_q8_matches_xla_q8_bitwise_on_accumulator():
    # int32 accumulation is associative -> both paths compute the same sums;
    # only the fp32 scale multiply can round differently (allow 1 ulp-ish).
    a, b = _operands(m=40, k=96, n=72, seed=3)
    x = ops.matmul(a, b, backend="xla_q8")
    p = ops.matmul(a, b, backend="pallas_q8_interpret")
    np.testing.assert_allclose(np.asarray(x), np.asarray(p), rtol=1e-6, atol=1e-5)


def test_q8_bias_rides_the_accumulator():
    a, b = _operands(m=16, k=64, n=48, seed=4)
    bias = jnp.asarray(np.random.default_rng(5).standard_normal(48), jnp.float32)
    no_bias = ops.matmul(a, b, backend="xla_q8")
    with_bias = ops.matmul(a, b, bias, backend="xla_q8")
    np.testing.assert_allclose(
        np.asarray(with_bias), np.asarray(no_bias + bias[None, :]), rtol=1e-6
    )
    pl = ops.matmul(a, b, bias, backend="pallas_q8_interpret")
    np.testing.assert_allclose(
        np.asarray(pl), np.asarray(with_bias), rtol=1e-6, atol=1e-5
    )


def test_q8_gradients_run_full_precision():
    # The registry's grad_backend rule: backward of a q8 forward == backward
    # of the fp32 path, bit for bit (same ops on the same saved residuals).
    a, b = _operands(m=24, k=48, n=32, seed=6)
    g_q = jax.grad(lambda a: ops.matmul(a, b, backend="xla_q8").sum())(a)
    g_f = jax.grad(lambda a: ops.matmul(a, b, backend="xla").sum())(a)
    np.testing.assert_array_equal(np.asarray(g_q), np.asarray(g_f))
    assert ops.grad_backend_of("xla_q8") == "xla"
    assert ops.grad_backend_of("pallas_q8") == "xla"
    assert ops.grad_backend_of("xla") == "xla"


# ---------------------------------------------------------------------------
# registry behaviour
# ---------------------------------------------------------------------------


def _force_unavailable(monkeypatch, *names):
    for name in names:
        b = ops._REGISTRY[name]
        monkeypatch.setitem(
            ops._REGISTRY, name, dataclasses.replace(b, available=lambda: False)
        )


def test_quant_backends_registered_and_resolve():
    for name in ("xla_q8", "pallas_q8", "pallas_q8_interpret"):
        assert name in ops.registered_backends()
    assert ops.resolve_backend("xla_q8") == "xla_q8"


def test_pallas_q8_degrades_inside_the_quant_family(monkeypatch):
    # An unavailable quantized backend must degrade to another QUANTIZED
    # backend (never silently to full precision).
    _force_unavailable(monkeypatch, "pallas_q8")
    with pytest.warns(RuntimeWarning, match="degrading to 'pallas_q8_interpret'"):
        assert ops.resolve_backend("pallas_q8") == "pallas_q8_interpret"
    _force_unavailable(monkeypatch, "pallas_q8_interpret")
    with pytest.warns(RuntimeWarning, match="degrading to 'xla_q8'"):
        assert ops.resolve_backend("pallas_q8") == "xla_q8"


def test_tile_selection_memo_is_bounded():
    ops.clear_tile_cache()
    try:
        for i in range(ops._TILE_CACHE_CAP + 64):
            ops._tile_for(8 * (i + 1), 128, 128, 4)
        info = ops.tile_cache_info()
        assert info.currsize <= ops._TILE_CACHE_CAP
        assert info.maxsize == ops._TILE_CACHE_CAP
    finally:
        ops.clear_tile_cache()


# ---------------------------------------------------------------------------
# precision policy wiring
# ---------------------------------------------------------------------------


def test_policy_rejects_unknown_roles():
    with pytest.raises(ValueError, match="unknown roles"):
        PrecisionPolicy(rules={"flux_capacitor": "xla_q8"})


def test_policy_role_resolution():
    pol = mlp_q8_policy()
    assert pol.backend_for("mlp") in ("xla_q8", "pallas_q8")
    assert pol.backend_for("attn_qkv") is None  # attention stays full-width
    assert pol.backend_for("router") is None  # routing stays full-width
    table = pol.describe()
    assert set(table) == set(quant.ROLES)


def test_policy_through_model_loss_is_close_to_fp32():
    from repro.configs import get_config
    from repro.models import api

    cfg = get_config("chatglm3-6b").reduced()
    params = api.init_params(cfg, jax.random.key(0))
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "labels": jnp.ones((2, 16), jnp.int32),
    }
    l_fp = float(api.loss_fn(cfg, params, batch))
    l_q = float(api.loss_fn(cfg, params, batch, backend=mlp_q8_policy()))
    assert abs(l_fp - l_q) < 0.05 * abs(l_fp) + 1e-3
    # gradients flow (and stay fp32) through the policy path
    g = jax.grad(lambda p: api.loss_fn(cfg, p, batch, backend=mlp_q8_policy()))(
        params
    )
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# quantized KV cache
# ---------------------------------------------------------------------------


def test_quant_kv_roundtrip_error_bound():
    from repro.models.attention import KVCache

    rng = np.random.default_rng(0)
    b, s, hkv, d = 3, 16, 2, 8
    kv = KVCache(
        k=jnp.asarray(rng.standard_normal((b, s, hkv * d)), jnp.float32),
        v=jnp.asarray(rng.standard_normal((b, s, hkv * d)), jnp.float32),
        length=jnp.full((b,), s, jnp.int32),
    )
    qkv = quantize_kv(kv, n_kv=hkv, margin=1.25)
    assert isinstance(qkv, QuantKVCache)
    assert qkv.k.dtype == jnp.int8
    assert qkv.k_scale.shape == (b, hkv)
    # per-(row, head) bound: margin * amax / 127 / 2 per element
    for deq, orig, scale in (
        (qkv.dequant_k(), kv.k, qkv.k_scale),
        (qkv.dequant_v(), kv.v, qkv.v_scale),
    ):
        err = jnp.abs(deq - orig).reshape(b, s, hkv, d)
        bound = (scale * 0.5)[:, None, :, None]
        assert bool(jnp.all(err <= bound * 1.0001))


def test_prefill_into_quant_cache_refuses():
    # Prefill writes raw K/V; filling a QuantKVCache would int8-cast unscaled
    # floats. The attention layer must refuse rather than corrupt silently.
    from repro.models.attention import attention_apply, attention_init
    from repro.models.layers import Initializer

    params = attention_init(
        jax.random.key(0), 32, 2, 2, 16, Initializer(dtype=jnp.float32)
    )
    x = jnp.zeros((1, 4, 32), jnp.float32)
    qc = QuantKVCache.zeros(1, 8, 2, 16)
    with pytest.raises(NotImplementedError, match="prefill into a QuantKVCache"):
        attention_apply(params, x, n_heads=2, n_kv=2, head_dim=16, cache=qc)


def test_q8_block_shape_is_sublane_aligned():
    from repro.quant import q8_block_shape

    for m in (8, 40, 100, 256, 1000):
        bm, bn, bk = q8_block_shape(m, 256, 256)
        assert bm % 32 == 0
        assert bn % 128 == 0 and bk % 128 == 0


def test_quant_kv_append_then_dequant():
    qkv = QuantKVCache.zeros(2, 8, 2, 4)
    qkv = qkv._replace(
        k_scale=jnp.full((2, 2), 0.01, jnp.float32),
        v_scale=jnp.full((2, 2), 0.01, jnp.float32),
        length=jnp.zeros((2,), jnp.int32),
    )
    kf = jnp.full((2, 8), 0.5, jnp.float32)
    kq, vq = qkv.quantize_rows(kf, -kf)
    np.testing.assert_array_equal(np.asarray(kq), 50)
    np.testing.assert_array_equal(np.asarray(vq), -50)


def test_slot_pool_quant_bytes_ratio():
    from repro.configs import get_config
    from repro.serve.cache import SlotPool

    cfg = get_config("chatglm3-6b").reduced()
    fp = SlotPool.create(cfg, 4, 64, jnp.float32)
    q = SlotPool.create(cfg, 4, 64, jnp.float32, kv_format="int8")
    ratio = fp.kv_bytes_per_slot() / q.kv_bytes_per_slot()
    assert ratio >= 3.5


# ---------------------------------------------------------------------------
# fp32-KV vs quantized-KV serving agreement (the subsystem end to end)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained_reduced_model():
    from conftest import import_quant_bench

    from repro.configs import get_config

    cfg = get_config("chatglm3-6b").reduced()
    # seq_len covers every position the serving test decodes at (max 13+24).
    params, loss = import_quant_bench().trained_model(
        cfg, steps=250, seed=0, seq_len=48
    )
    assert loss < 0.5  # the model actually learned the task
    return cfg, params


@pytest.mark.slow
def test_greedy_agreement_fp32_vs_quant_kv_serving(trained_reduced_model):
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import Request

    cfg, params = trained_reduced_model
    rng = np.random.default_rng(0)
    trace = []
    for rid, (plen, gen) in enumerate(
        [(6, 8), (9, 16), (13, 12), (7, 24), (11, 8), (5, 16)]
    ):
        a, s = int(rng.integers(0, cfg.vocab)), int(rng.integers(1, 5))
        trace.append(
            Request(
                rid=rid,
                prompt=[(a + s * t) % cfg.vocab for t in range(plen)],
                max_new_tokens=gen,
            )
        )
    common = dict(
        cfg=cfg, params=params, n_slots=3, max_len=64, cache_dtype=jnp.float32
    )
    rep_fp = ContinuousEngine(**common).serve(trace)
    rep_q = ContinuousEngine(**common, kv_format="int8").serve(trace)
    agree = total = 0
    for rid in rep_fp.outputs:
        a, b = rep_fp.outputs[rid], rep_q.outputs[rid]
        assert len(a) == len(b)
        total += len(a)
        agree += sum(1 for x, y in zip(a, b) if x == y)
    assert total >= 80
    assert agree / total >= 0.99
    assert rep_fp.kv_bytes_per_slot / rep_q.kv_bytes_per_slot >= 3.5
