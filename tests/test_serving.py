"""Continuous-batching serving subsystem: correctness + policy tests.

The load-bearing check: greedy outputs from the continuous engine (requests
joining/leaving a shared slot pool mid-flight, bucketed padded prefill,
per-slot decode positions) match single-request ``ServeEngine`` outputs
token-for-token — and the fused decode step compiles exactly once.
"""

import subprocess
import sys
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import api
from repro.models.attention import KVCache
from repro.serve import (
    ContinuousEngine,
    Request,
    Scheduler,
    ServeEngine,
    SlotPool,
    bucket_length,
    poisson_trace,
    shared_prefix_trace,
)

KEY = jax.random.key(0)


def _trace(cfg, specs, seed=7):
    """specs: [(prompt_len, max_new, arrival), ...]"""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab, p)],
            max_new_tokens=g,
            arrival=a,
        )
        for i, (p, g, a) in enumerate(specs)
    ]


def _reference_outputs(cfg, params, requests, max_len):
    """Each request alone through the lockstep engine (greedy)."""
    eng = ServeEngine(cfg=cfg, params=params, max_len=max_len,
                      cache_dtype=jnp.float32)
    out = {}
    for r in requests:
        toks = eng.generate(
            {"tokens": jnp.asarray([r.prompt], jnp.int32)}, r.max_new_tokens
        )
        out[r.rid] = [int(t) for t in np.asarray(toks[0])]
    return out


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch",
    [
        "chatglm3-6b",  # attention-only: pow2 buckets, padded prefill
        "jamba-v0.1-52b",  # mamba+moe: auto exact-length buckets
    ],
)
def test_continuous_matches_single_request_greedy(arch):
    """Token-for-token match under mid-flight joins/leaves + staggered
    arrivals, with exactly one compiled decode program."""
    cfg = ARCHS[arch].reduced()
    params = api.init_params(cfg, KEY)
    max_len = 48
    specs = [(7, 5, 0), (12, 9, 0), (7, 3, 2), (16, 11, 5), (12, 1, 9)]
    requests = _trace(cfg, specs)
    want = _reference_outputs(cfg, params, requests, max_len)

    eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=2, max_len=max_len,
        cache_dtype=jnp.float32,
    )
    report = eng.serve(requests)
    for r in requests:
        assert report.outputs[r.rid] == want[r.rid], r.rid
    # Requests joined and left a 2-slot pool (5 requests, mixed lengths)
    # without the fused decode step ever recompiling.
    n = eng.decode_compilations()
    if n is not None:
        assert n == 1
    assert report.prefill_batches >= 2
    assert 0 < report.mean_occupancy <= 1.0
    assert report.generated_tokens == sum(g for _, g, _ in specs)


def test_continuous_streams_and_stops_on_eos():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    requests = _trace(cfg, [(7, 12, 0), (12, 12, 0)])
    # Find a token the first request actually emits, then use it as EOS.
    base = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32,
                            cache_dtype=jnp.float32)
    full = base.serve(requests)
    eos = full.outputs[0][2]  # 3rd emitted token of request 0

    streamed = []
    eng = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32,
                           cache_dtype=jnp.float32, eos_id=eos)
    report = eng.serve(
        requests, on_token=lambda rid, tok: streamed.append((rid, tok))
    )
    out0 = report.outputs[0]
    assert out0 == full.outputs[0][: len(out0)]
    assert out0[-1] == eos and len(out0) <= 3
    # every output token was streamed, in order
    for r in requests:
        got = [t for rid, t in streamed if rid == r.rid]
        assert got == report.outputs[r.rid]


def test_continuous_chunked_prefill_matches_greedy():
    """Chunked prefill alone (no prefix cache): token-for-token agreement
    with the monolithic-prefill engine, one compiled decode program."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    specs = [(7, 5, 0), (23, 6, 0), (12, 4, 2), (30, 5, 4)]
    requests = _trace(cfg, specs)
    base = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=48,
                            cache_dtype=jnp.float32)
    want = base.serve(requests).outputs

    eng = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=48,
                           cache_dtype=jnp.float32, prefill_chunk=8)
    report = eng.serve(requests)
    assert report.outputs == want
    n = eng.decode_compilations()
    if n is not None:
        assert n == 1


def test_continuous_prefix_cache_matches_greedy_and_hits():
    """Prefix cache + chunked prefill on a shared-system-prompt trace:
    bitwise-identical greedy tokens vs the features-off engine, cache hits
    observed, decode still compiles exactly once."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    requests = shared_prefix_trace(
        6, seed=3, vocab=cfg.vocab, prefix_len=48, tail_lens=(5, 9),
        gen_lens=(4, 6), mean_interarrival=1.0,
    )
    base = ContinuousEngine(cfg=cfg, params=params, n_slots=3, max_len=96,
                            cache_dtype=jnp.float32)
    want = base.serve(requests).outputs

    eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=3, max_len=96,
        cache_dtype=jnp.float32, prefill_chunk=16, prefix_cache=True,
        prefix_block=16,
    )
    report = eng.serve(requests)
    assert report.outputs == want  # bitwise greedy agreement, cache on vs off
    n = eng.decode_compilations()
    if n is not None:
        assert n == 1  # joins resumed from cache never recompiled decode
    stats = eng.prefix_cache_stats()
    assert stats["hits"] > 0 and stats["misses"] >= 1
    assert stats["cached_tokens"] > 0


def test_continuous_quant_pool_prefix_cache_serves():
    """Quantized slot pool + quantized prefix trie: the run completes with
    hits and the cold request (no cached prefix exists yet) matches the
    cache-off engine exactly — later requests adopt the prefix's original
    scales, which legitimately differ from a fresh whole-prompt
    calibration, so their tokens are compared only for shape."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    requests = shared_prefix_trace(
        4, seed=5, vocab=cfg.vocab, prefix_len=32, tail_lens=(5, 7),
        gen_lens=(4,), mean_interarrival=2.0,
    )
    base = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=64,
                            cache_dtype=jnp.float32, kv_format="int8")
    want = base.serve(requests).outputs

    eng = ContinuousEngine(
        cfg=cfg, params=params, n_slots=2, max_len=64,
        cache_dtype=jnp.float32, kv_format="int8",
        prefill_chunk=16, prefix_cache=True, prefix_block=16,
    )
    report = eng.serve(requests)
    assert report.outputs[0] == want[0]  # cold request: identical path
    assert {r: len(t) for r, t in report.outputs.items()} == {
        r: len(t) for r, t in want.items()
    }
    assert eng.prefix_cache_stats()["hits"] > 0


def test_chunked_prefill_env_knobs_and_validation():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    with pytest.raises(ValueError):
        ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32,
                         prefill_chunk=12)  # not a power of two
    env = {"REPRO_PREFILL_CHUNK": "16", "REPRO_PREFIX_CACHE": "1"}
    old = {k: os.environ.get(k) for k in env}
    os.environ.update(env)
    try:
        eng = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32)
        assert eng.prefill_chunk == 16 and eng.prefix_cache
    finally:
        for k, v in old.items():
            os.environ.pop(k, None) if v is None else os.environ.update({k: v})
    # prefix cache alone implies a default chunk width
    eng = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32,
                           prefix_cache=True)
    assert eng.prefill_chunk is not None
    # recurrent mixers can't resume mid-prompt: both features disable
    with pytest.warns(RuntimeWarning, match="attention-only"):
        eng = ContinuousEngine(
            cfg=ARCHS["jamba-v0.1-52b"].reduced(), params=None,
            n_slots=2, max_len=32, prefill_chunk=8, prefix_cache=True,
        )
    assert eng.prefill_chunk is None and not eng.prefix_cache


def test_attr_fallback_recaptures_untraced_step():
    """A compiled step whose trace ran while metrics were off must not
    silently attribute zero GEMM-seconds forever: the engine re-captures
    its workload via jax.eval_shape and counts on gemm.attr_fallback."""
    from repro import obs

    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = ContinuousEngine(cfg=cfg, params=params, n_slots=2, max_len=32,
                           cache_dtype=jnp.float32)
    requests = _trace(cfg, [(7, 4, 0), (12, 4, 1)])
    prev = obs.set_enabled(False)
    try:
        eng.serve(requests)  # traces + compiles with capture recording off
    finally:
        obs.set_enabled(prev)
    assert eng._prefill_workloads == {}  # nothing attributed while off

    obs.reset()
    eng.serve(_trace(cfg, [(7, 4, 0), (12, 4, 1)], seed=11))
    assert ("decode",) in eng._prefill_workloads  # re-captured via eval_shape
    snap = obs.snapshot()["counters"].get("gemm.attr_fallback", {})
    assert sum(snap.values()) >= 1


def test_decode_at_matches_decode_lockstep():
    cfg = ARCHS["qwen2.5-32b"].reduced()  # qkv_bias: bias-preload decode path
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, caches = api.prefill(cfg, params, {"tokens": toks}, max_len=32,
                                 cache_dtype=jnp.float32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    l_lock, _ = api.decode(cfg, params, tok, caches, jnp.asarray(16, jnp.int32))
    l_slot, _ = api.decode_at(cfg, params, tok, caches,
                              jnp.full((2,), 16, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_lock), np.asarray(l_slot))


def test_prefill_bucketed_matches_exact_prefill():
    """Right-padding + per-row last-token gather == unpadded prefill."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    t7 = jax.random.randint(jax.random.key(2), (1, 7), 0, cfg.vocab)
    t12 = jax.random.randint(jax.random.key(3), (1, 12), 0, cfg.vocab)
    toks = np.zeros((2, 16), np.int32)
    toks[0, :7] = np.asarray(t7[0])
    toks[1, :12] = np.asarray(t12[0])
    lb, _ = api.prefill_bucketed(
        cfg, params, jnp.asarray(toks), jnp.asarray([7, 12], jnp.int32),
        cache_dtype=jnp.float32,
    )
    for row, t in ((0, t7), (1, t12)):
        le, _ = api.prefill(cfg, params, {"tokens": t}, max_len=t.shape[1],
                            cache_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lb[row]), np.asarray(le[0]), rtol=1e-5, atol=1e-5
        )


def test_serve_engine_temperature_key_plumbing():
    """Satellite regression: sampling is deterministic per key and the first
    token responds to the key (it is sampled from a fresh split, not the
    parent key that step 0 re-splits)."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = ServeEngine(cfg=cfg, params=params, max_len=24,
                      cache_dtype=jnp.float32, temperature=1.0)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)}
    a = np.asarray(eng.generate(batch, 8, key=jax.random.key(5)))
    b = np.asarray(eng.generate(batch, 8, key=jax.random.key(5)))
    c = np.asarray(eng.generate(batch, 8, key=jax.random.key(6)))
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------


def test_slot_pool_lease_bookkeeping():
    cfg = ARCHS["chatglm3-6b"].reduced()
    pool = SlotPool.create(cfg, n_slots=3, max_len=16, dtype=jnp.float32)
    assert pool.n_free == 3 and pool.occupancy == 0.0
    slots = pool.allocate(["a", "b"])
    assert slots == [0, 1] and pool.n_free == 1
    assert pool.owner_of(0) == "a" and pool.active_slots() == [0, 1]
    assert pool.release(0) is True
    assert pool.n_free == 2 and pool.owner_of(0) is None
    assert pool.allocate(["c"]) == [0]  # recycled lowest slot first
    with pytest.raises(RuntimeError):
        pool.allocate(["d", "e", "f"])  # only 1 free
    # releasing a free (never- or already-released) slot is an idempotent
    # no-op — the evict sweep may race a same-tick retire — but an
    # out-of-range slot is a caller bug and still raises.
    assert pool.release(2) is False  # never leased
    assert pool.release(0) is True
    assert pool.release(0) is False  # double release: no-op, slot not re-freed
    assert pool.n_free == 2
    with pytest.raises(KeyError):
        pool.release(17)  # out of range


def test_slot_pool_join_scatters_only_target_slots():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    pool = SlotPool.create(cfg, n_slots=3, max_len=16, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.key(4), (1, 8), 0, cfg.vocab)
    _, pre = api.prefill_bucketed(
        cfg, params, toks, jnp.asarray([8], jnp.int32), cache_dtype=jnp.float32
    )
    pool.allocate(["r0"])  # slot 0 leased to someone else
    slots = pool.allocate(["r1"])
    assert slots == [1]
    pool.join(pre, slots)
    for pc, fc in zip(pool.caches, pre):
        if isinstance(pc, KVCache):
            got = np.asarray(pc.k[:, 1, :8])
            np.testing.assert_array_equal(got, np.asarray(fc.k[:, 0]))
            # untouched slots stay zero
            assert not np.asarray(pc.k[:, 0]).any()
            assert not np.asarray(pc.k[:, 2]).any()


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------


def test_bucket_length_rounding():
    assert bucket_length(3) == 8  # floor
    assert bucket_length(8) == 8
    assert bucket_length(9) == 16
    assert bucket_length(17) == 32
    assert bucket_length(17, exact=True) == 17
    assert bucket_length(17, maximum=24) == 24  # clamped, still >= n
    assert bucket_length(30, maximum=24) == 30  # never below the prompt


def _mk_sched(cfg, reqs, **kw):
    s = Scheduler(cfg, **kw)
    for r in reqs:
        s.submit(r)
    return s


def test_scheduler_fifo_bucketed_admission():
    cfg = ARCHS["chatglm3-6b"].reduced()
    reqs = [
        Request(rid=0, prompt=[1] * 7, max_new_tokens=4),   # bucket 8
        Request(rid=1, prompt=[1] * 12, max_new_tokens=4),  # bucket 16
        Request(rid=2, prompt=[1] * 6, max_new_tokens=4),   # bucket 8
        Request(rid=3, prompt=[1] * 15, max_new_tokens=4),  # bucket 16
    ]
    sched = _mk_sched(cfg, reqs)
    # Head-of-line is rid 0 (bucket 8); rid 2 rides along, 1/3 keep position.
    b1 = sched.next_batch(4, now=0)
    assert [r.rid for r in b1] == [0, 2]
    b2 = sched.next_batch(1, now=0)  # only one slot free
    assert [r.rid for r in b2] == [1]
    b3 = sched.next_batch(4, now=0)
    assert [r.rid for r in b3] == [3]
    assert sched.next_batch(4, now=0) == []


def test_scheduler_unadmittable_head_falls_through_to_deepest_bucket():
    """Starvation regression: an un-admittable head-of-line request must not
    pin arrived requests of other buckets behind it while slots sit free.
    Admission falls through to the deepest non-empty admissible bucket; the
    blocked head keeps its queue position."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    reqs = [
        Request(rid=0, prompt=[1] * 30, max_new_tokens=4),  # bucket 32 (head)
        Request(rid=1, prompt=[1] * 6, max_new_tokens=4),   # bucket 8
        Request(rid=2, prompt=[1] * 12, max_new_tokens=4),  # bucket 16
        Request(rid=3, prompt=[1] * 7, max_new_tokens=4),   # bucket 8
    ]
    sched = _mk_sched(cfg, reqs)
    blocked = lambda r: len(r.prompt) <= 16  # head (30) not admissible
    b1 = sched.next_batch(4, now=0, admissible=blocked)
    assert [r.rid for r in b1] == [2]  # deepest admissible bucket (16) first
    b2 = sched.next_batch(4, now=0, admissible=blocked)
    assert [r.rid for r in b2] == [1, 3]
    # head becomes admissible again: strict FIFO resumes
    b3 = sched.next_batch(4, now=0)
    assert [r.rid for r in b3] == [0]


def test_scheduler_no_starvation_ticks():
    """Simulated engine tick loop: at every tick with a free slot and at
    least one arrived admissible request, admission must make progress —
    the free-slots-while-admissible-queue-waits tick count stays zero."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    rng = np.random.default_rng(2)
    reqs = [
        Request(
            rid=i, prompt=[1] * int(rng.choice([6, 12, 25, 30])),
            max_new_tokens=2, arrival=int(rng.integers(0, 6)),
        )
        for i in range(12)
    ]
    sched = _mk_sched(cfg, reqs)
    free = 3
    in_flight = []  # (rid, ticks_left)
    admissible = lambda r: len(r.prompt) <= 16  # long prompts never admit
    n_admissible = sum(1 for r in reqs if admissible(r))
    starved_ticks = 0
    done = 0
    for now in range(200):
        while free > 0:
            batch = sched.next_batch(free, now, admissible=admissible)
            if not batch:
                break
            free -= len(batch)
            in_flight.extend((r.rid, 2) for r in batch)
        waiting = sum(
            1 for r in sched._queue if r.arrival <= now and admissible(r)
        )
        if free > 0 and waiting:
            starved_ticks += 1
        nxt = []
        for rid, left in in_flight:
            if left - 1 == 0:
                free += 1
                done += 1
            else:
                nxt.append((rid, left - 1))
        in_flight = nxt
    assert starved_ticks == 0
    assert done == n_admissible  # every admissible request ran to completion


def test_scheduler_arrival_gating_and_eviction():
    cfg = ARCHS["chatglm3-6b"].reduced()
    reqs = [
        Request(rid=0, prompt=[1] * 8, max_new_tokens=2, arrival=3),
        Request(rid=1, prompt=[1] * 8, max_new_tokens=5, arrival=0),
    ]
    sched = _mk_sched(cfg, reqs, eos_id=99)
    assert sched.next_batch(2, now=2) == [reqs[1]]  # rid 0 not arrived yet
    batch = sched.next_batch(2, now=3)
    assert batch == [reqs[0]]
    sched.admit([reqs[1]], [0], now=0)
    sched.admit([reqs[0]], [1], now=3)
    assert not sched.record_token(1, 7, now=1)
    assert sched.record_token(1, 99, now=2)  # EOS evicts before budget
    assert sched.states[1].done and sched.states[1].tokens == [7, 99]
    assert not sched.record_token(0, 5, now=4)
    assert sched.record_token(0, 6, now=5)  # max_new_tokens evicts
    assert sched.drained


def test_scheduler_exact_buckets_for_recurrent_families():
    assert Scheduler(ARCHS["jamba-v0.1-52b"].reduced()).exact_buckets
    assert Scheduler(ARCHS["xlstm-125m"].reduced()).exact_buckets
    assert not Scheduler(ARCHS["chatglm3-6b"].reduced()).exact_buckets


def test_poisson_trace_deterministic_and_sorted():
    a = poisson_trace(8, seed=3, mean_interarrival=2.0)
    b = poisson_trace(8, seed=3, mean_interarrival=2.0)
    assert [(r.prompt, r.arrival, r.max_new_tokens) for r in a] == [
        (r.prompt, r.arrival, r.max_new_tokens) for r in b
    ]
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))


# ---------------------------------------------------------------------------
# benchmark acceptance: continuous strictly beats static on a mixed trace
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serving_bench_smoke_continuous_wins(tmp_path):
    out = tmp_path / "BENCH_serving.json"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks", "serving_bench.py"),
         "--smoke", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=repo,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    result = json.loads(out.read_text())
    c, s = result["continuous"], result["static"]
    assert result["speedup_tokens_per_step"] > 1.0
    assert result["occupancy_gain"] > 0.0
    # tokens/step and occupancy are deterministic; tokens/sec is wall clock
    # on a tiny smoke trace, so on a loaded machine the continuous engine's
    # win can be eaten by scheduling noise — require same order of
    # magnitude only, the strict win is asserted on the step-count metric.
    assert c["tokens_per_sec"] > 0.7 * s["tokens_per_sec"]
    # None when this JAX version hides the jit cache size
    assert c["decode_compilations"] in (None, 1)
    assert c["useful_tokens"] == s["useful_tokens"]  # same trace, same work
