"""Sharding rules + HLO census unit tests (no multi-device needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import ARCHS
from repro.core.hlo_census import census_hlo
from repro.distributed.sharding import guard_spec, param_pspec


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) and .axis_names are consulted."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestGuard:
    def test_divisible_kept(self):
        assert guard_spec(MESH, (64, 32), P("data", "model")) == P("data", "model")

    def test_indivisible_dropped(self):
        assert guard_spec(MESH, (40, 32), P("model", "data")) == P(None, "data")

    def test_tuple_axes(self):
        assert guard_spec(POD, (64, 32), P(("pod", "data"), None)) == P(
            ("pod", "data"), None
        )
        assert guard_spec(POD, (31, 32), P(("pod", "data"), None)) == P(None, None)


class TestParamRules:
    def test_attention_weights_2d_sharded(self):
        spec = param_pspec(MESH, "blocks/0/attn/wq/w", (28, 4096, 4096))
        assert spec == P(None, "data", "model")

    def test_qwen_heads_never_model_sharded(self):
        # fused qkv out dim 40*128=5120 divides 16 -> fine to shard
        spec = param_pspec(MESH, "blocks/0/attn/wq/w", (64, 5120, 5120))
        assert spec == P(None, "data", "model")

    def test_whisper_vocab_unsharded(self):
        spec = param_pspec(MESH, "embed/table", (51865, 512))
        assert spec == P(None, "data")

    def test_expert_ep_when_divisible(self):
        spec = param_pspec(MESH, "blocks/0/moe/w_gate", (28, 64, 2048, 1408))
        assert spec == P(None, "model", "data", None)

    def test_expert_tp_fallback_grok(self):
        """E=8 < 16: the model axis must land on d_ff, not vanish."""
        spec = param_pspec(MESH, "blocks/0/moe/w_gate", (64, 8, 6144, 32768))
        assert spec == P(None, None, "data", "model")
        spec = param_pspec(MESH, "blocks/0/moe/w_down", (64, 8, 32768, 6144))
        assert spec == P(None, None, "model", "data")

    def test_norms_replicated(self):
        spec = param_pspec(MESH, "blocks/0/norm_mixer/scale", (28, 4096))
        assert all(e is None for e in tuple(spec))

    def test_every_arch_every_param_is_legal(self):
        """All rules produce evenly-divisible specs for every arch."""
        import functools

        from repro.models import api

        for name, cfg in ARCHS.items():
            shapes = jax.eval_shape(
                functools.partial(api.init_params, cfg), jax.random.key(0)
            )
            leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
            for path, leaf in leaves:
                pstr = "/".join(
                    str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                    for k in path
                )
                for mesh in (MESH, POD):
                    spec = param_pspec(mesh, pstr, leaf.shape)
                    for dim, entry in zip(leaf.shape, tuple(spec)):
                        if entry is None:
                            continue
                        axes = entry if isinstance(entry, tuple) else (entry,)
                        n = int(np.prod([mesh.shape[a] for a in axes]))
                        assert dim % n == 0, (name, pstr, leaf.shape, spec)


class TestHloCensus:
    def test_loop_trip_multiplication(self):
        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            c, _ = jax.lax.scan(body, x, w)
            return c.sum()

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 32), jnp.float32),
            jax.ShapeDtypeStruct((7, 32, 32), jnp.float32),
        ).compile()
        cen = census_hlo(comp.as_text())
        want = 7 * 2 * 8 * 32 * 32  # 7 iterations x one [8,32]@[32,32]
        assert abs(cen.flops - want) / want < 0.01
        assert cen.max_trip == 7

    def test_loop_free_matches_cost_analysis(self):
        def g(x, w):
            return jnp.sum(jnp.tanh(x @ w))

        comp = jax.jit(g).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 96), jnp.float32),
        ).compile()
        ca = compat.normalize_cost_analysis(comp)
        cen = census_hlo(comp.as_text())
        assert abs(cen.flops - ca["flops"]) / ca["flops"] < 0.05

    def test_known_train_step_accounting(self):
        """fwd+bwd+remat of a scanned MLP ~ 4x fwd FLOPs (within 15%)."""
        L, B, D, F = 4, 8, 64, 256

        def loss(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w[0]) @ w[1], None
            c, _ = jax.lax.scan(jax.checkpoint(body), x, ws)
            return jnp.sum(c * c)

        def step(ws, x):
            return jax.grad(loss)(ws, x)

        comp = jax.jit(step).lower(
            (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
             jax.ShapeDtypeStruct((L, F, D), jnp.float32)),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ).compile()
        cen = census_hlo(comp.as_text())
        fwd = L * 2 * (B * D * F + B * F * D)
        assert 2.5 * fwd < cen.flops < 4.6 * fwd
