"""Substrate tests: optimizer math, checkpoint fault tolerance, data, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.data import MarkovLMDataset, Prefetcher, make_batch_fn
from repro.models import api
from repro.optim import AdamWConfig, apply_updates, cosine_lr, init_opt_state
from repro.serve import ServeEngine
from repro.train import TrainLoopConfig, train


class TestOptimizer:
    def test_adamw_matches_reference_impl(self):
        """One step vs a hand-written numpy AdamW."""
        cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                          weight_decay=0.1, clip_norm=None)
        p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
        g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
        st = init_opt_state(p, cfg)
        new_p, new_st, metrics = apply_updates(p, g, st, cfg)

        lr = float(cosine_lr(cfg, jnp.asarray(1)))
        m = 0.1 * np.asarray(g["w"])
        v = 0.05 * np.asarray(g["w"]) ** 2
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.95)
        want = np.asarray(p["w"]) - lr * (
            mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"])
        )
        np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
        assert int(new_st.step) == 1

    def test_clipping_bounds_update(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
        p = {"w": jnp.zeros((4,), jnp.float32)}
        g = {"w": jnp.full((4,), 1e6, jnp.float32)}
        st = init_opt_state(p, cfg)
        _, _, metrics = apply_updates(p, g, st, cfg)
        assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip

    def test_bf16_moments_supported(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
        st = init_opt_state(p, cfg)
        assert st.mu["w"].dtype == jnp.bfloat16
        g = {"w": jnp.ones((8, 8), jnp.bfloat16) * 0.1}
        new_p, new_st, _ = apply_updates(p, g, st, cfg)
        assert new_st.mu["w"].dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(new_p["w"], np.float32)).all()


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
        save(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        back = restore(str(tmp_path), 7, like=jax.eval_shape(lambda: tree))
        np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))

    def test_partial_write_invisible(self, tmp_path):
        """A .tmp directory (simulated crash mid-write) is never resumed."""
        tree = {"a": jnp.ones(3)}
        save(str(tmp_path), 5, tree)
        os.makedirs(tmp_path / "step_00000009.tmp")
        (tmp_path / "step_00000009.tmp" / "a.npy").write_bytes(b"garbage")
        assert latest_step(str(tmp_path)) == 5

    def test_async_writer_single_flight(self, tmp_path):
        w = AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (10, 20, 30):
            w.save(s, {"x": jnp.full((4,), float(s))})
        w.wait()
        assert latest_step(str(tmp_path)) == 30
        # GC keeps only the newest `keep`
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(steps) == 2

    def test_restore_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            restore(str(tmp_path), 1, like={"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


class TestData:
    def test_deterministic_and_restart_safe(self):
        ds = MarkovLMDataset(vocab=64, seq_len=16, batch=4, seed=3)
        a = ds.batch_at(5)
        b = ds.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch_at(6)
        assert not np.array_equal(a["tokens"], c["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])

    def test_prefetcher(self):
        ds = MarkovLMDataset(vocab=64, seq_len=8, batch=2, seed=0)
        pf = Prefetcher(make_batch_fn(ds), start_step=0, depth=2)
        try:
            s0, b0 = pf.get()
            s1, b1 = pf.get()
            assert (s0, s1) == (0, 1)
            assert b0["tokens"].shape == (2, 8)
        finally:
            pf.close()


class TestTrainLoopFaultTolerance:
    def test_learns_and_resumes_after_injected_failure(self, tmp_path):
        cfg = get_config("chatglm3-6b").reduced()
        ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=32, batch=8, seed=0)
        opt = AdamWConfig(peak_lr=1e-2, warmup_steps=10, total_steps=120)
        loop = TrainLoopConfig(total_steps=120, ckpt_every=40,
                               ckpt_dir=str(tmp_path), log_every=0,
                               fail_at_step=90)
        with pytest.raises(RuntimeError, match="injected failure"):
            train(cfg, opt, loop, make_batch_fn(ds), log=lambda *_: None)
        # restart: resumes from step 80 checkpoint and finishes
        loop2 = TrainLoopConfig(total_steps=120, ckpt_every=40,
                                ckpt_dir=str(tmp_path), log_every=0)
        res = train(cfg, opt, loop2, make_batch_fn(ds), log=lambda *_: None)
        assert res.resumed_from == 80
        assert res.losses[-1] < 4.0  # learned well below ln(256)=5.55

    def test_straggler_watchdog_flags_slow_step(self, tmp_path):
        import time

        cfg = get_config("xlstm-125m").reduced()
        ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=16, batch=2, seed=0)
        opt = AdamWConfig(total_steps=40)

        calls = {"n": 0}
        base = make_batch_fn(ds)

        def slow_batch(step):
            calls["n"] += 1
            if step == 30:
                time.sleep(1.0)  # synthetic stall
            return base(step)

        loop = TrainLoopConfig(total_steps=40, ckpt_dir=None, log_every=0,
                               watchdog_factor=3.0)
        res = train(cfg, opt, loop, slow_batch, log=lambda *_: None)
        assert res.straggler_steps >= 1


class TestElasticRescale:
    def test_checkpoint_restores_across_device_counts(self, tmp_path):
        """Save on this topology, restore into a resharded placement —
        host-side full arrays make the checkpoint mesh-agnostic."""
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save(str(tmp_path), 1, tree)
        like = jax.eval_shape(lambda: tree)
        back = restore(str(tmp_path), 1, like=like)  # default placement
        np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


class TestServing:
    def test_greedy_deterministic(self):
        cfg = get_config("chatglm3-6b").reduced()
        params = api.init_params(cfg, jax.random.key(1))
        eng = ServeEngine(cfg=cfg, params=params, max_len=48,
                          cache_dtype=jnp.float32)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
        a = eng.generate(batch, 8)
        b = eng.generate(batch, 8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (2, 8)

    def test_temperature_sampling_varies(self):
        cfg = get_config("chatglm3-6b").reduced()
        params = api.init_params(cfg, jax.random.key(1))
        eng = ServeEngine(cfg=cfg, params=params, max_len=48,
                          cache_dtype=jnp.float32, temperature=1.0)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
        a = eng.generate(batch, 8, key=jax.random.key(1))
        b = eng.generate(batch, 8, key=jax.random.key(2))
        assert not np.array_equal(np.asarray(a), np.asarray(b))
