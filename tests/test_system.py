"""End-to-end behaviour: train a reduced model through the full stack and
serve from its checkpoint — the paper's GEMM path under everything."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import get_config
from repro.data import MarkovLMDataset, make_batch_fn
from repro.models import api
from repro.optim import AdamWConfig, init_opt_state
from repro.serve import ServeEngine
from repro.train import TrainLoopConfig, train


def test_train_then_serve_roundtrip(tmp_path):
    cfg = get_config("chatglm3-6b").reduced()
    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=32, batch=8, seed=0)
    opt = AdamWConfig(peak_lr=1e-2, warmup_steps=20, total_steps=200)
    loop = TrainLoopConfig(total_steps=200, ckpt_every=100,
                           ckpt_dir=str(tmp_path), log_every=0)
    res = train(cfg, opt, loop, make_batch_fn(ds), log=lambda *_: None)
    assert res.losses[-1] < res.losses[0] - 2.0  # learned the Markov stream

    # restore params from the final checkpoint and serve
    step = latest_step(str(tmp_path))
    params_like = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.key(0))
    )
    opt_like = jax.eval_shape(
        lambda: init_opt_state(params_like, AdamWConfig())
    )
    state = restore(str(tmp_path), step,
                    like={"params": params_like, "opt": opt_like})
    eng = ServeEngine(cfg=cfg, params=state["params"], max_len=64,
                      cache_dtype=jnp.float32)
    prompt = jnp.asarray(ds.batch_at(0)["tokens"][:2, :16])
    toks = eng.generate({"tokens": prompt}, 16)
    assert toks.shape == (2, 16)
    # a trained model should follow the Markov chain: generated tokens must
    # be among the successors of their predecessors far above chance
    succ = ds._succ
    prev = np.concatenate([np.asarray(prompt[:, -1:]), np.asarray(toks[:, :-1])], 1)
    hits = np.mean([
        toks[i, j] in succ[prev[i, j]]
        for i in range(2) for j in range(16)
    ])
    assert hits > 0.5, hits  # chance level is branch/vocab = 4/256
