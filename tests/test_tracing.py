"""Request-lifecycle tracing: recorder semantics, Chrome export, the live
scrape surface, and — load-bearing — the engine integration invariant that
each request's contiguous pre-decode phases sum *exactly* to its TTFT
sample, which is what makes the exported timeline a trustworthy TTFT
decomposition rather than a second, drifting clock.
"""

import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import ARCHS
from repro.models import api
from repro.obs import http as obs_http
from repro.obs import tracing
from repro.serve import ContinuousEngine, Request

KEY = jax.random.key(0)


def _trace(cfg, specs, seed=7):
    """specs: [(prompt_len, max_new, arrival), ...]"""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab, p)],
            max_new_tokens=g,
            arrival=a,
        )
        for i, (p, g, a) in enumerate(specs)
    ]


# ---------------------------------------------------------------------------
# recorder semantics
# ---------------------------------------------------------------------------


def test_recorder_phase_chain_is_contiguous_and_closed():
    tracing.begin_request(101, 0, 1.0)
    tracing.begin_phase(101, "prefill", 1.5)
    tracing.begin_phase(101, "decode", 2.0)
    tracing.end_request(101, "eos", 3.0)

    snap = tracing.snapshot()
    assert len(snap["requests"]) == 1
    rec = snap["requests"][0]
    assert rec["uid"] == 101 and rec["retire_reason"] == "eos"
    names = [p["name"] for p in rec["phases"]]
    assert names == ["queue", "prefill", "decode"]
    # Contiguity by construction: each phase closes where the next opens,
    # and retirement closes the tail — the phases tile [arrival, retire].
    for prev, nxt in zip(rec["phases"], rec["phases"][1:]):
        assert prev["t1"] == nxt["t0"]
    assert rec["phases"][-1]["t1"] == 3.0 == rec["retired_ts"]
    assert tracing.active_requests() == []


def test_recorder_instants_slices_and_annotations():
    tracing.begin_request(7, 3, 0.0)
    tracing.set_slot(7, 2)
    tracing.annotate(7, prefix_tokens=32)
    tracing.instant(7, "admitted", 0.5, bucket=64, fallthrough=False)
    tracing.slice_event(7, "chunk", 0.6, 0.7, offset=0, end=16)

    active = tracing.active_requests(now=1.0)
    assert len(active) == 1
    a = active[0]
    assert a["slot"] == 2 and a["meta"]["prefix_tokens"] == 32
    assert a["phase"] == "queue" and a["age_s"] == pytest.approx(1.0)
    rec = tracing.snapshot()["requests"][0]
    assert rec["instants"][0] == {
        "name": "admitted", "ts": 0.5, "bucket": 64, "fallthrough": False,
    }
    assert rec["slices"][0]["offset"] == 0 and rec["slices"][0]["end"] == 16


def test_recorder_retired_ring_is_bounded():
    rec = tracing.TraceRecorder(cap=2)
    for uid in (1, 2, 3):
        rec.begin_request(uid, uid, float(uid))
        rec.end_request(uid, "budget", float(uid) + 0.5)
    uids = [r["uid"] for r in rec.snapshot()["requests"]]
    assert uids == [2, 3]  # oldest dropped first


def test_recorder_instant_cap_counts_drops(monkeypatch):
    monkeypatch.setattr(tracing, "_MAX_INSTANTS", 3)
    tracing.begin_request(9, 0, 0.0)
    for i in range(5):
        tracing.instant(9, "token", float(i))
    rec = tracing.snapshot()["requests"][0]
    assert len(rec["instants"]) == 3
    assert rec["meta"]["instants_dropped"] == 2


def test_recorder_disabled_is_a_noop():
    tracing.set_enabled(False)
    assert not tracing.enabled()
    tracing.begin_request(5, 0, 0.0)
    tracing.instant(5, "admitted", 0.1)
    tracing.end_request(5, "eos", 0.2)
    assert tracing.snapshot()["requests"] == []
    # tracing also rides the registry hard-off switch
    tracing.set_enabled(None)
    prev = obs.set_enabled(False)
    try:
        assert not tracing.enabled()
    finally:
        obs.set_enabled(prev)


def test_request_uids_are_monotonic_and_survive_rid_reuse():
    a = Request(rid=0, prompt=[1], max_new_tokens=1)
    b = Request(rid=0, prompt=[1], max_new_tokens=1)  # same rid, new uid
    c = Request(rid=1, prompt=[1], max_new_tokens=1)
    assert a.uid < b.uid < c.uid


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------


def _one_retired_one_active():
    tracing.begin_request(1, 0, 0.0)
    tracing.set_slot(1, 0)
    tracing.begin_phase(1, "prefill", 0.2)
    tracing.begin_phase(1, "decode", 0.4)
    tracing.end_request(1, "budget", 1.0)
    tracing.begin_request(2, 1, 0.5)  # still queued


def test_chrome_trace_layout_and_validation():
    _one_retired_one_active()
    doc = tracing.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {"ph": "M", "pid": 1, "name": "process_name",
            "args": {"name": "repro.serve"}} in evs
    begins = [e for e in evs if e["ph"] == "b"]
    ends = [e for e in evs if e["ph"] == "e"]
    assert {e["id"] for e in begins} == {1, 2}
    assert [e["id"] for e in ends] == [1]  # uid 2 is still open
    # the queue phase rides the queue track; later phases ride the slot's
    phases = {e["name"]: e for e in evs if e.get("cat") == "phase"
              and e["args"]["uid"] == 1}
    assert phases["queue"]["tid"] == 0
    assert phases["prefill"]["tid"] == 1 and phases["decode"]["tid"] == 1
    assert phases["queue"]["dur"] == pytest.approx(0.2e6)
    assert tracing.validate_chrome_trace(doc) == 2


def test_validate_chrome_trace_rejects_malformed_docs():
    with pytest.raises(ValueError, match="missing or empty"):
        tracing.validate_chrome_trace({"traceEvents": []})
    base = {"pid": 1, "tid": 0, "ts": 0.0, "cat": "request", "name": "r"}
    with pytest.raises(ValueError, match="closed without open"):
        tracing.validate_chrome_trace(
            {"traceEvents": [{**base, "ph": "e", "id": 1}]}
        )
    with pytest.raises(ValueError, match="opened twice"):
        tracing.validate_chrome_trace(
            {"traceEvents": [{**base, "ph": "b", "id": 1},
                             {**base, "ph": "b", "id": 1}]}
        )
    with pytest.raises(ValueError, match="invalid dur"):
        tracing.validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "p", "ts": 0.0, "dur": -1.0}]}
        )
    with pytest.raises(ValueError, match="no phase slices"):
        tracing.validate_chrome_trace(
            {"traceEvents": [{**base, "ph": "b", "id": 1, "ts": 0.0},
                             {**base, "ph": "e", "id": 1, "ts": 1.0}]}
        )


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 48)
    import jax.numpy as jnp

    return ContinuousEngine(
        cfg=cfg, params=params, cache_dtype=jnp.float32, **kw
    )


def test_engine_chunked_phases_sum_to_ttft():
    """The acceptance invariant: per request, queue + prefix_attach +
    chunk_prefill durations equal the first-token instant's ``ttft_s`` —
    the same value observed into ``serve.ttft_seconds``."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = _engine(cfg, params, prefill_chunk=8, prefix_cache=True,
                  prefix_block=8)
    reqs = _trace(cfg, [(12, 3, 0), (12, 4, 0), (20, 3, 2), (6, 3, 5)])
    eng.serve(reqs)

    snap = tracing.snapshot()
    assert {r["uid"] for r in snap["requests"]} == {r.uid for r in reqs}
    for rec in snap["requests"]:
        assert rec["retire_reason"] == "budget"
        names = [p["name"] for p in rec["phases"]]
        assert names == ["queue", "prefix_attach", "chunk_prefill", "decode"]
        ft = next(i for i in rec["instants"] if i["name"] == "first_token")
        pre = sum(p["t1"] - p["t0"] for p in rec["phases"][:-1])
        assert pre == pytest.approx(ft["ttft_s"], abs=1e-9)
    assert tracing.validate_chrome_trace(tracing.chrome_trace(snap)) == 4
    # retirement emitted one structured event per request, keyed by uid
    retired = obs.recent_events(kind="request_retired")
    assert {e["uid"] for e in retired} == {r.uid for r in reqs}
    for e in retired:
        assert e["reason"] == "budget" and e["tokens"] >= 1
        assert "slot" in e and "rid" in e


def test_engine_monolithic_phases_and_report_fields():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = _engine(cfg, params)  # monolithic prefill
    reqs = _trace(cfg, [(8, 3, 0), (8, 3, 0), (5, 4, 3)])
    rep = eng.serve(reqs)

    for rec in tracing.snapshot()["requests"]:
        names = [p["name"] for p in rec["phases"]]
        assert names == ["queue", "prefill", "decode"]
        ft = next(i for i in rec["instants"] if i["name"] == "first_token")
        pre = sum(p["t1"] - p["t0"] for p in rec["phases"][:-1])
        assert pre == pytest.approx(ft["ttft_s"], abs=1e-9)
    assert rep.goodput is None  # no SLO configured: not 100%, *no answer*
    assert rep.queue_p50 is not None and rep.queue_p99 is not None
    assert rep.attach_p50 is None  # chunked-path phase, monolithic run
    assert 1 <= rep.slot_hwm <= 2
    # phase histograms landed in the registry
    hists = obs.snapshot()["histograms"]
    assert "serve.queue_seconds" in hists
    assert "serve.ttft_seconds" in hists


def test_engine_goodput_against_slos():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = _engine(cfg, params, slo_ttft_ms=60_000.0, slo_itl_ms=60_000.0)
    reqs = _trace(cfg, [(8, 3, 0), (8, 3, 1)])
    assert eng.serve(reqs).goodput == 1.0  # CI-box generous: all good

    eng.slo_ttft_ms = 1e-7  # 0.1 us: nothing meets it
    assert eng.serve(_trace(cfg, [(8, 3, 0), (8, 3, 1)])).goodput == 0.0


def test_engine_tracing_off_still_reports_latency():
    """Tracing is observability; the report's percentiles are product.
    REPRO_TRACE=0 must leave the report intact and the buffer empty."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    tracing.set_enabled(False)
    eng = _engine(cfg, params)
    rep = eng.serve(_trace(cfg, [(8, 3, 0), (8, 3, 0)]))
    assert rep.ttft_p50 is not None and rep.queue_p50 is not None
    assert tracing.snapshot()["requests"] == []


def test_fallthrough_admission_stamps_queue_exit_and_starved_head():
    """Satellite: with the chunk pipeline full behind a long head, a short
    arrival is admitted via the fall-through bucket — its queue phase must
    close at the fall-through admission (not at the head's), while the
    starved head stays visible in ``/requests`` as a ``queue``-phase entry
    with growing age."""
    cfg = ARCHS["chatglm3-6b"].reduced()
    params = api.init_params(cfg, KEY)
    eng = _engine(cfg, params, n_slots=3, max_len=64, prefill_chunk=8,
                  prefix_cache=False)
    # A (40 tokens, bucket 64) fills the one-deep chunk pipeline; B (20
    # tokens, bucket 32) becomes the un-admissible head (suffix > chunk);
    # C (6 tokens, bucket 8) fits one chunk and falls through past B.
    a, b, c = _trace(cfg, [(40, 4, 0), (20, 3, 0), (6, 4, 0)])

    head_sightings = []

    def on_token(rid, tok):
        for entry in tracing.active_requests():
            if entry["uid"] == b.uid:
                head_sightings.append(entry)

    eng.serve([a, b, c], on_token=on_token)

    by_uid = {r["uid"]: r for r in tracing.snapshot()["requests"]}
    adm_c = next(i for i in by_uid[c.uid]["instants"]
                 if i["name"] == "admitted")
    assert adm_c["fallthrough"] is True and adm_c["bucket"] == 8
    # C's queue phase closed at its own fall-through admission stamp
    queue_c = by_uid[c.uid]["phases"][0]
    assert queue_c["name"] == "queue" and queue_c["t1"] == adm_c["ts"]
    # the head was *not* a fall-through admit once the pipeline drained,
    # and its queue wait strictly exceeds the request that jumped past it
    adm_b = next(i for i in by_uid[b.uid]["instants"]
                 if i["name"] == "admitted")
    assert adm_b["fallthrough"] is False
    assert adm_b["queue_s"] > adm_c["queue_s"]
    # while starved, the head showed up in the live view, queued and aging
    # (later sightings — after the pipeline drains and B is admitted — are
    # in post-queue phases, which is fine; the starvation window is what
    # must have been visible)
    queued = [s for s in head_sightings if s["phase"] == "queue"]
    assert len(queued) >= 2
    ages = [s["age_s"] for s in queued]
    assert ages == sorted(ages) and ages[-1] > ages[0]


# ---------------------------------------------------------------------------
# live scrape surface (obs.http)
# ---------------------------------------------------------------------------


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        return r.status, r.read().decode()


def test_http_scrape_surface_end_to_end():
    obs.counter("serve.tokens").inc(5)
    tracing.begin_request(11, 0, 0.0)
    tracing.set_slot(11, 1)
    tracing.begin_phase(11, "decode", 0.5)
    tracing.end_request(11, "eos", 1.0)
    tracing.begin_request(12, 1, 2.0)  # in flight

    server = obs_http.serve_metrics(port=0)
    assert server.port > 0
    assert obs_http.serve_metrics() is server  # idempotent

    status, body = _get(server.port, "/metrics")
    assert status == 200
    # byte-identical to the CLI's rendering over the same registry state
    assert body == obs.prometheus_text()
    assert "serve_tokens_total 5" in body

    _, body = _get(server.port, "/requests")
    live = json.loads(body)
    assert [r["uid"] for r in live] == [12]
    assert live[0]["phase"] == "queue"

    _, body = _get(server.port, "/trace")
    doc = json.loads(body)
    assert tracing.validate_chrome_trace(doc) == 2

    _, body = _get(server.port, "/")
    assert "/metrics" in body
    with pytest.raises(urllib.error.HTTPError) as exc:
        _get(server.port, "/nope")
    assert exc.value.code == 404

    obs_http.shutdown()
    assert obs_http.current_server() is None
    obs_http.shutdown()  # idempotent


def test_http_maybe_serve_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_METRICS_PORT", raising=False)
    assert obs_http.maybe_serve_from_env() is None
    monkeypatch.setenv("REPRO_METRICS_PORT", "0")  # ephemeral port
    server = obs_http.maybe_serve_from_env()
    assert server is not None and server.port > 0
    status, _ = _get(server.port, "/healthz")
    assert status == 200


# ---------------------------------------------------------------------------
# CLI: repro-stats trace / tail --follow
# ---------------------------------------------------------------------------


def test_stats_trace_converts_raw_dump(tmp_path, capsys):
    from repro.launch import stats

    _one_retired_one_active()
    raw = tmp_path / "raw.json"
    raw.write_text(json.dumps(tracing.snapshot()))
    out = tmp_path / "timeline.json"

    stats.main(["trace", "--file", str(raw), "--out", str(out)])
    doc = json.loads(out.read_text())
    assert tracing.validate_chrome_trace(doc) == 2

    stats.main(["trace", "--file", str(raw), "--summary"])
    table = capsys.readouterr().out
    assert "queue_ms" in table and "budget" in table


def test_follow_events_streams_appended_lines(tmp_path):
    path = tmp_path / "events.jsonl"
    path.write_text(json.dumps({"kind": "first"}) + "\n")
    stop = threading.Event()
    got = []

    def consume():
        for e in obs.follow_events(
            str(path), poll_interval=0.02, stop=stop.is_set
        ):
            got.append(e)

    t = threading.Thread(target=consume)
    t.start()
    deadline = time.time() + 5.0
    while not got and time.time() < deadline:
        time.sleep(0.02)
    with open(path, "a") as f:  # appended mid-follow, including a partial
        f.write(json.dumps({"kind": "second"}) + "\n")
        f.write('{"kind": "thi')
        f.flush()
        time.sleep(0.1)
        f.write('rd"}\n')
    while len(got) < 3 and time.time() < deadline:
        time.sleep(0.02)
    stop.set()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert [e["kind"] for e in got] == ["first", "second", "third"]
